"""CLI over JSONL traces: summarize or convert to a Chrome trace.

    PYTHONPATH=src python -m repro.obs summary TRACE.jsonl --top 5
    PYTHONPATH=src python -m repro.obs chrome TRACE.jsonl -o trace.json

``summary`` prints markdown (the CI bench job appends it to the step
summary); ``chrome`` writes Perfetto/``chrome://tracing`` JSON.
"""
from __future__ import annotations

import argparse
import sys

from .chrome import export_chrome_trace, load_jsonl
from .summary import summary_table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="summarize or convert a repro.obs JSONL trace")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ap_s = sub.add_parser("summary", help="markdown totals + slowest waves")
    ap_s.add_argument("trace", help="JSONL trace file (JsonlTracker output)")
    ap_s.add_argument("--top", type=int, default=5,
                      help="how many slowest waves to list (default 5)")
    ap_c = sub.add_parser("chrome", help="convert to Chrome trace JSON")
    ap_c.add_argument("trace", help="JSONL trace file (JsonlTracker output)")
    ap_c.add_argument("-o", "--out", default="trace.chrome.json",
                      help="output path (default trace.chrome.json)")
    args = ap.parse_args(argv)

    events = load_jsonl(args.trace)
    if args.cmd == "summary":
        print(summary_table(events, top=args.top))
    else:
        doc = export_chrome_trace(events, args.out)
        print(f"wrote {args.out} ({len(doc['traceEvents'])} trace events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
