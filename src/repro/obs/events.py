"""The observability event schema (one schema, every executor).

Every executor reports through the same structured per-wave events, so a
trace reads identically whether the program ran staged, sharded, on the
host threads, or through the DES — the reproduction's analogue of the
paper's §6 measurement methodology, where per-core timestamped counters
(busy/idle/flush breakdowns, per-controller load) are what actually
locate the contention and locality effects.

An :class:`Event` is ``(kind, ts, data)``: ``ts`` is seconds since the
tracker started (monotonic clock) and ``data`` is a flat JSON-safe dict
whose required keys are fixed per kind by :data:`EVENT_FIELDS`.  The
schema is versioned (:data:`EVENT_SCHEMA`) and pinned by
``tests/test_obs.py`` — extending an event is adding *optional* keys;
removing or renaming a required key is a schema bump.

Kinds:

* ``trace_header``   — first record of a JSONL trace file; carries the
  schema version string.
* ``wave_open``      — a wavefront starts dispatching: task and group
  counts, which executor.
* ``wave_close``     — the wavefront drained: dispatch wall time, how
  many dispatches it took, and the *measured* tile movement deltas
  (``TileTraffic`` snapshots around the wave, so per-wave
  ``bytes_moved``/``bytes_staged`` sum exactly to ``RuntimeStats``).
* ``dispatch``       — one batched (or single) dispatch: function name,
  task count, dispatch mode (``jit``/``vmap``/``shard_map``/
  ``vmap_device``/``pallas``) and its wall time.
* ``kernel_dispatch``— the wave-kernel backend decided how one group
  dispatches (emitted only under ``kernel_backend="pallas"``):
  ``backend`` is ``"pallas"`` (fused grid) or ``"xla"`` (fallback), and
  ``reason`` names why a fallback was taken (``"single_task"``,
  ``"non_rectangular"``, ``"mixed_dtype"``, ``"grid_overflow"``, ...;
  empty on the pallas path).
* ``queue_depth``    — a per-device (or per-worker) queue depth changed;
  the tracker keeps the live map, which the sharded executor feeds back
  into ``placement.rebalance_owners``.
* ``owner_override`` — the contention-aware owner override spilled tasks.
* ``tile_cache``     — one host worker's pinned-tile-cache hit/miss
  counters (reported at shutdown).
* ``sim_predict``    — the DES barrier's predicted makespan vs the
  configured serial cost of the same tasks (``sim.sequential_time``).
* ``dep_msg``        — the sharded dependence manager moved messages over
  one home's MPB channel (``msg`` is ``dep_query``/``dep_grant``/
  ``release``).  One event per *logical* descriptor, independent of how
  descriptors were packed into envelopes.
* ``dep_batch``      — one multi-descriptor envelope crossed a home's
  MPB ring: which manager, the direction (``post`` master->manager,
  ``grant`` manager->master), how many descriptors it carried and the
  32-byte MPB lines it occupied.
* ``pump_idle``      — a dependence pump thread found every inbox it
  services empty and parked (``dep_pump="threaded"`` only): the first
  home the thread services and its cumulative idle-wait count.
* ``manager_admit``  — one per-home manager admitted a footprint slice:
  which manager, the admitted task, how many dependences its grant
  carried, and the channel depth at send time.
* ``stats``          — the runtime's final :class:`RuntimeStats` as its
  schema-tagged dict (``RuntimeStats.to_dict``), emitted at shutdown.
* ``admission_admit`` / ``admission_defer`` / ``admission_reject`` /
  ``admission_release`` — the serving admission controller
  (``repro.serve``) decided one request's fate against the in-flight
  byte budget: the request id, its footprint bytes, and the in-flight
  total after the decision; rejects carry a ``reason``
  (``"budget"``/``"oversize"``/``"closed"``), releases carry the
  request's latency.
* ``ckpt_save`` / ``ckpt_restore`` — one epoch-tagged tile checkpoint
  of the serving session's shared ``BlockArray`` state committed to
  (or was restored from) disk: epoch, array/tile counts, total bytes.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

__all__ = ["EVENT_SCHEMA", "EVENT_FIELDS", "Event", "validate_event"]

EVENT_SCHEMA = "repro-obs/1"

# kind -> required data keys.  Emitters may add optional keys; removing
# a required key is a schema bump.
EVENT_FIELDS: dict[str, frozenset] = {
    "trace_header": frozenset({"schema"}),
    "wave_open": frozenset({"wave", "executor", "tasks", "groups"}),
    "wave_close": frozenset({"wave", "executor", "tasks", "wall_s",
                             "dispatches", "tile_moves", "bytes_moved",
                             "bytes_staged"}),
    "dispatch": frozenset({"wave", "executor", "fn", "tasks", "mode",
                           "wall_s"}),
    "kernel_dispatch": frozenset({"wave", "executor", "fn", "tasks",
                                  "backend", "reason"}),
    "queue_depth": frozenset({"channel", "depth"}),
    "owner_override": frozenset({"wave", "spilled"}),
    "tile_cache": frozenset({"worker", "hits", "misses"}),
    "sim_predict": frozenset({"tasks", "predicted_s", "sequential_s"}),
    "dep_msg": frozenset({"manager", "msg", "count"}),
    "dep_batch": frozenset({"manager", "direction", "descriptors",
                            "lines"}),
    "pump_idle": frozenset({"manager", "waits"}),
    "manager_admit": frozenset({"manager", "task", "deps", "depth"}),
    "stats": frozenset({"stats"}),
    "admission_admit": frozenset({"request", "bytes", "in_flight_bytes"}),
    "admission_defer": frozenset({"request", "bytes", "in_flight_bytes",
                                  "queued"}),
    "admission_reject": frozenset({"request", "bytes", "in_flight_bytes",
                                   "reason"}),
    "admission_release": frozenset({"request", "bytes", "in_flight_bytes",
                                    "latency_s"}),
    "ckpt_save": frozenset({"epoch", "arrays", "tiles", "bytes"}),
    "ckpt_restore": frozenset({"epoch", "arrays", "tiles", "bytes"}),
}


@dataclass(frozen=True)
class Event:
    """One structured observation: ``kind`` names the schema entry,
    ``ts`` is seconds since tracker start, ``data`` the payload."""
    kind: str
    ts: float
    data: dict = field(default_factory=dict)

    def to_record(self) -> dict:
        """The flat JSONL representation (``kind``/``ts`` + payload)."""
        return {"kind": self.kind, "ts": self.ts, **self.data}

    def to_json(self) -> str:
        return json.dumps(self.to_record(), sort_keys=True)

    @classmethod
    def from_record(cls, rec: dict) -> "Event":
        rec = dict(rec)
        kind = rec.pop("kind")
        ts = rec.pop("ts", 0.0)
        return cls(kind=kind, ts=float(ts), data=rec)


def validate_event(ev: Event) -> list[str]:
    """Schema problems with ``ev`` (empty list = valid)."""
    bad: list[str] = []
    required = EVENT_FIELDS.get(ev.kind)
    if required is None:
        return [f"unknown event kind {ev.kind!r}"]
    missing = required - set(ev.data)
    if missing:
        bad.append(f"{ev.kind}: missing required fields {sorted(missing)}")
    if not isinstance(ev.ts, (int, float)) or ev.ts < 0:
        bad.append(f"{ev.kind}: ts must be a non-negative number, "
                   f"got {ev.ts!r}")
    return bad
