"""Opt-in ``jax.profiler`` trace-context hook.

When ``RuntimeConfig(profile_waves=True)``, the staged/sharded executors
wrap every wave dispatch in :func:`trace_span` — a
``jax.profiler.TraceAnnotation`` — so a device profile captured with
``jax.profiler.trace()`` (or TensorBoard) shows which XLA executions
belong to which wave.  Disabled (the default) the span is a shared
no-op context manager and costs nothing; if the installed jax has no
TraceAnnotation the hook degrades to the same no-op instead of failing.
"""
from __future__ import annotations

import contextlib

__all__ = ["trace_span", "profiler_available"]

_NULL = contextlib.nullcontext()


def _annotation_cls():
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:
        return None


def profiler_available() -> bool:
    """True when the installed jax exposes ``profiler.TraceAnnotation``."""
    return _annotation_cls() is not None


def trace_span(label: str, enabled: bool = True):
    """A context manager naming ``label`` in the jax profiler timeline;
    a no-op when ``enabled`` is False or the profiler is unavailable."""
    if not enabled:
        return _NULL
    cls = _annotation_cls()
    if cls is None:
        return _NULL
    return cls(label)
