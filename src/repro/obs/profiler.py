"""Opt-in ``jax.profiler`` trace-context hook.

When ``RuntimeConfig(profile_waves=True)``, the staged/sharded executors
wrap every wave dispatch in :func:`trace_span` — a
``jax.profiler.TraceAnnotation`` — so a device profile captured with
``jax.profiler.trace()`` (or TensorBoard) shows which XLA executions
belong to which wave.  Disabled (the default) the span is a shared
no-op context manager and costs nothing; if the installed jax has no
TraceAnnotation the hook degrades to the same no-op instead of failing.

:func:`profile_session` is the *session* side of the same story: the
annotations only land in a trace file if someone started a profiler
session around the run.  The benchmark driver (``benchmarks.run
--profile-dir``) and the nightly job use it to bracket app runs with
``jax.profiler.start_trace``/``stop_trace`` so ``profile_waves`` spans
end up in uploaded artifacts instead of requiring a hand-started
TensorBoard session.  Degrades to a no-op when jax lacks the API.
"""
from __future__ import annotations

import contextlib

__all__ = ["trace_span", "profile_session", "profiler_available"]

_NULL = contextlib.nullcontext()


def _annotation_cls():
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:
        return None


def profiler_available() -> bool:
    """True when the installed jax exposes ``profiler.TraceAnnotation``."""
    return _annotation_cls() is not None


def trace_span(label: str, enabled: bool = True):
    """A context manager naming ``label`` in the jax profiler timeline;
    a no-op when ``enabled`` is False or the profiler is unavailable."""
    if not enabled:
        return _NULL
    cls = _annotation_cls()
    if cls is None:
        return _NULL
    return cls(label)


@contextlib.contextmanager
def profile_session(logdir: str | None):
    """Bracket a region with a ``jax.profiler`` trace session writing to
    ``logdir``; yields True when a session actually started.

    No-op (yields False) when ``logdir`` is falsy or the installed jax
    lacks ``start_trace``/``stop_trace`` — callers never need to guard.
    ``stop_trace`` runs even if the body raises, so partial sessions
    still flush their trace files for upload."""
    if not logdir:
        yield False
        return
    try:
        from jax.profiler import start_trace, stop_trace
    except Exception:
        yield False
        return
    start_trace(str(logdir))
    try:
        yield True
    finally:
        stop_trace()
