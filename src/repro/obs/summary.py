"""Human summaries of a trace: totals and the slowest waves.

Feeds the CLI (``python -m repro.obs summary TRACE.jsonl --top 5``) and
the CI step-summary table — the markdown output renders directly in a
GitHub job summary.
"""
from __future__ import annotations

from .events import Event

__all__ = ["slowest_waves", "mode_latency", "summary_table"]


def slowest_waves(events: list[Event], top: int = 5) -> list[Event]:
    """The ``top`` slowest ``wave_close`` events, slowest first (ties
    break on wave order so the result is deterministic)."""
    waves = [e for e in events if e.kind == "wave_close"]
    waves.sort(key=lambda e: (-e.data["wall_s"], e.data["wave"]))
    return waves[:top]


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over pre-sorted values (pure python — the
    trace CLI must not pull numpy in for a table)."""
    rank = max(int(-(-q * len(sorted_vals) // 100)), 1)   # ceil, >= 1
    return sorted_vals[rank - 1]


def mode_latency(events: list[Event]) -> dict[str, dict]:
    """Per-dispatch-mode latency histogram from ``dispatch`` events:
    ``mode -> {count, total_s, p50_s, p99_s}``, modes sorted by name.

    This is the before/after axis for dispatch-path work (e.g. jit vs
    vmap vs shard_map): the same trace answers "where did the wall time
    go" per mode, with tail latency (p99) next to the median."""
    by_mode: dict[str, list[float]] = {}
    for e in events:
        if e.kind == "dispatch":
            by_mode.setdefault(e.data["mode"], []).append(e.data["wall_s"])
    out: dict[str, dict] = {}
    for mode in sorted(by_mode):
        walls = sorted(by_mode[mode])
        out[mode] = {
            "count": len(walls),
            "total_s": sum(walls),
            "p50_s": _percentile(walls, 50),
            "p99_s": _percentile(walls, 99),
        }
    return out


def summary_table(events: list[Event], top: int = 5) -> str:
    """A markdown summary: one totals line plus a top-``top`` slowest
    waves table."""
    kinds: dict[str, int] = {}
    for e in events:
        kinds[e.kind] = kinds.get(e.kind, 0) + 1
    waves = [e for e in events if e.kind == "wave_close"]
    wall = sum(e.data["wall_s"] for e in waves)
    moved = sum(e.data["bytes_moved"] for e in waves)
    staged = sum(e.data["bytes_staged"] for e in waves)
    lines = [f"**trace**: {len(events)} events · {len(waves)} waves · "
             f"{kinds.get('dispatch', 0)} dispatches · "
             f"{wall:.4f} s dispatch wall · {moved} B moved · "
             f"{staged} B staged", ""]
    if waves:
        lines.append(f"| wave | executor | tasks | dispatches | wall s | "
                     f"moved B | staged B |")
        lines.append("|---|---|---|---|---|---|---|")
        for e in slowest_waves(events, top):
            d = e.data
            lines.append(
                f"| {d['wave']} | {d['executor']} | {d['tasks']} | "
                f"{d['dispatches']} | {d['wall_s']:.4f} | "
                f"{d['bytes_moved']} | {d['bytes_staged']} |")
    modes = mode_latency(events)
    if modes:
        lines.append("")
        lines.append("| mode | dispatches | total s | p50 s | p99 s |")
        lines.append("|---|---|---|---|---|")
        for mode, h in modes.items():
            lines.append(
                f"| {mode} | {h['count']} | {h['total_s']:.4f} | "
                f"{h['p50_s']:.4f} | {h['p99_s']:.4f} |")
    return "\n".join(lines)
