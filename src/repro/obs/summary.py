"""Human summaries of a trace: totals and the slowest waves.

Feeds the CLI (``python -m repro.obs summary TRACE.jsonl --top 5``) and
the CI step-summary table — the markdown output renders directly in a
GitHub job summary.
"""
from __future__ import annotations

from .events import Event

__all__ = ["slowest_waves", "summary_table"]


def slowest_waves(events: list[Event], top: int = 5) -> list[Event]:
    """The ``top`` slowest ``wave_close`` events, slowest first (ties
    break on wave order so the result is deterministic)."""
    waves = [e for e in events if e.kind == "wave_close"]
    waves.sort(key=lambda e: (-e.data["wall_s"], e.data["wave"]))
    return waves[:top]


def summary_table(events: list[Event], top: int = 5) -> str:
    """A markdown summary: one totals line plus a top-``top`` slowest
    waves table."""
    kinds: dict[str, int] = {}
    for e in events:
        kinds[e.kind] = kinds.get(e.kind, 0) + 1
    waves = [e for e in events if e.kind == "wave_close"]
    wall = sum(e.data["wall_s"] for e in waves)
    moved = sum(e.data["bytes_moved"] for e in waves)
    staged = sum(e.data["bytes_staged"] for e in waves)
    lines = [f"**trace**: {len(events)} events · {len(waves)} waves · "
             f"{kinds.get('dispatch', 0)} dispatches · "
             f"{wall:.4f} s dispatch wall · {moved} B moved · "
             f"{staged} B staged", ""]
    if waves:
        lines.append(f"| wave | executor | tasks | dispatches | wall s | "
                     f"moved B | staged B |")
        lines.append("|---|---|---|---|---|---|---|")
        for e in slowest_waves(events, top):
            d = e.data
            lines.append(
                f"| {d['wave']} | {d['executor']} | {d['tasks']} | "
                f"{d['dispatches']} | {d['wall_s']:.4f} | "
                f"{d['bytes_moved']} | {d['bytes_staged']} |")
    return "\n".join(lines)
