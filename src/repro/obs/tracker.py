"""The Tracker protocol and its sinks.

A tracker is where the runtime's structured events go.  The protocol is
four methods (:meth:`emit`, :meth:`queue`, :meth:`queue_depths`,
:meth:`close`) plus an ``enabled`` flag the hot path guards on — with
the :class:`NullTracker` (the default) no event object is ever even
constructed, so observability off means observability free.

Sinks:

* :class:`InMemoryTracker` — events in a list; what tests assert on.
* :class:`JsonlTracker`    — one JSON record per line in a trace file
  (first line is the ``trace_header``); the CI bench job uploads one of
  these per run, and ``python -m repro.obs`` summarizes or converts it.
* :class:`ConsoleTracker`  — aggregates while running, prints a compact
  summary (totals + slowest waves) at :meth:`close`.

``TaskRuntime`` owns the tracker: ``RuntimeConfig(tracker=...)`` accepts
a spec string (``"memory"``, ``"console"``, ``"jsonl"``,
``"jsonl:PATH"``, ``"none"``) or a ready :class:`TrackerBase` instance —
instances are caller-owned (several runtimes may share one trace file)
and are *not* closed at runtime shutdown; spec-built trackers are.

Beyond recording, the tracker closes a control loop: it maintains the
live per-channel queue depth (workers for the host executor, owner homes
for the sharded one), and ``ShardedExecutor`` feeds that map into
``placement.rebalance_owners`` as the background load the contention
threshold is measured against.
"""
from __future__ import annotations

import threading
import time
from typing import Protocol, runtime_checkable

from .events import EVENT_SCHEMA, Event

__all__ = ["Tracker", "TrackerBase", "NullTracker", "NULL_TRACKER",
           "InMemoryTracker", "JsonlTracker", "ConsoleTracker",
           "make_tracker", "validate_spec", "TRACKER_SPECS"]

TRACKER_SPECS = ("none", "off", "memory", "console", "jsonl")


@runtime_checkable
class Tracker(Protocol):
    """What the runtime requires of an event sink."""

    enabled: bool

    def emit(self, kind: str, **data) -> None:
        """Record one structured event."""
        ...

    def queue(self, channel: int, delta: int) -> None:
        """Adjust a channel's live queue depth and record the new value."""
        ...

    def queue_depths(self) -> dict[int, int]:
        """The live depth per channel (empty when nothing is queued)."""
        ...

    def close(self) -> None:
        ...


class NullTracker:
    """The disabled tracker: ``enabled`` is False and every method is a
    no-op.  Hot paths guard event *construction* on ``enabled``, so with
    this sink no event dict is ever built — zero overhead, guarded by a
    test rather than a wall-clock gate."""

    enabled = False

    def emit(self, kind: str, **data) -> None:
        pass

    def queue(self, channel: int, delta: int) -> None:
        pass

    def queue_depths(self) -> dict[int, int]:
        return {}

    def close(self) -> None:
        pass


NULL_TRACKER = NullTracker()


class TrackerBase:
    """Shared machinery: monotonic timestamps relative to construction,
    the live queue-depth map, and a lock around :meth:`_record` (host
    worker shutdown and the master thread may interleave emits)."""

    enabled = True

    def __init__(self):
        self._t0 = time.perf_counter()
        self._depths: dict[int, int] = {}
        self._lock = threading.Lock()
        self._closed = False

    def emit(self, kind: str, **data) -> None:
        ev = Event(kind=kind, ts=time.perf_counter() - self._t0, data=data)
        with self._lock:
            if not self._closed:
                self._record(ev)

    def queue(self, channel: int, delta: int) -> None:
        ch = int(channel)
        with self._lock:
            # depth read-modify-write under the same lock _record uses:
            # emits arrive from the master, host workers, and dependence
            # pump threads concurrently
            depth = self._depths.get(ch, 0) + int(delta)
            self._depths[ch] = depth
        self.emit("queue_depth", channel=ch, depth=depth)

    def queue_depths(self) -> dict[int, int]:
        with self._lock:
            return dict(self._depths)

    def _record(self, ev: Event) -> None:
        raise NotImplementedError

    def close(self) -> None:
        with self._lock:
            if not self._closed:
                self._closed = True
                self._on_close()

    def _on_close(self) -> None:
        pass


class InMemoryTracker(TrackerBase):
    """Events in a list — the sink tests assert against."""

    def __init__(self):
        super().__init__()
        self.events: list[Event] = []

    def _record(self, ev: Event) -> None:
        self.events.append(ev)

    def events_of(self, kind: str) -> list[Event]:
        return [e for e in self.events if e.kind == kind]


class JsonlTracker(TrackerBase):
    """One JSON record per line in ``path``; the first line is the
    ``trace_header`` carrying the schema version.  The file truncates on
    construction (one tracker = one trace)."""

    def __init__(self, path):
        super().__init__()
        self.path = path
        self.records_written = 0
        self._fh = open(path, "w", encoding="utf-8")
        self.emit("trace_header", schema=EVENT_SCHEMA)

    def _record(self, ev: Event) -> None:
        self._fh.write(ev.to_json() + "\n")
        self.records_written += 1

    def _on_close(self) -> None:
        self._fh.close()


class ConsoleTracker(TrackerBase):
    """The summary sink: aggregates while running, prints at close.

    Wave lines and the final counters come from the same records every
    other sink sees; the ``stats`` event payload is the schema-tagged
    ``RuntimeStats.to_dict()`` — one serialization schema shared between
    the tracker summary and ``RuntimeStats.to_json``."""

    def __init__(self, top: int = 5, out=None):
        super().__init__()
        self.top = top
        self._out = out
        self.kind_counts: dict[str, int] = {}
        self._waves: list[Event] = []
        self._stats: dict | None = None

    def _record(self, ev: Event) -> None:
        self.kind_counts[ev.kind] = self.kind_counts.get(ev.kind, 0) + 1
        if ev.kind == "wave_close":
            self._waves.append(ev)
        elif ev.kind == "stats":
            self._stats = ev.data["stats"]

    def _on_close(self) -> None:
        n = sum(self.kind_counts.values())
        wall = sum(e.data["wall_s"] for e in self._waves)
        moved = sum(e.data["bytes_moved"] for e in self._waves)
        staged = sum(e.data["bytes_staged"] for e in self._waves)
        lines = [f"[obs] {n} events across "
                 f"{self.kind_counts.get('wave_close', 0)} waves / "
                 f"{self.kind_counts.get('dispatch', 0)} dispatches: "
                 f"{wall:.4f} s dispatch wall, "
                 f"{moved} B moved, {staged} B staged"]
        slowest = sorted(self._waves, key=lambda e: -e.data["wall_s"])
        if slowest:
            lines.append("[obs] slowest waves: " + ", ".join(
                f"#{e.data['wave']} {e.data['wall_s']:.4f}s "
                f"({e.data['tasks']} tasks, {e.data['executor']})"
                for e in slowest[:self.top]))
        if self._stats is not None:
            s = self._stats
            lines.append(f"[obs] final stats ({s.get('schema')}): "
                         f"{s.get('tasks_spawned')} tasks, "
                         f"{s.get('deps_found')} deps, "
                         f"{s.get('tile_moves')} tile moves")
        print("\n".join(lines), file=self._out)


def validate_spec(spec: str) -> str:
    """Raise ValueError unless ``spec`` names a known tracker sink."""
    if spec in TRACKER_SPECS or spec.startswith("jsonl:"):
        return spec
    raise ValueError(
        f"tracker spec must be one of {TRACKER_SPECS} or 'jsonl:PATH', "
        f"got {spec!r}")


def make_tracker(spec, default_path: str = "trace.jsonl"):
    """Resolve a ``RuntimeConfig.tracker`` value.

    Returns ``(tracker, owned)``: ``owned`` tells the runtime whether it
    should close the tracker at shutdown (spec-built sinks: yes; a
    caller-provided instance: no — the caller may be sharing it across
    runtimes and closes it itself)."""
    if spec is None or spec in ("none", "off"):
        return NULL_TRACKER, False
    if isinstance(spec, str):
        validate_spec(spec)
        if spec == "memory":
            return InMemoryTracker(), True
        if spec == "console":
            return ConsoleTracker(), True
        if spec == "jsonl":
            return JsonlTracker(default_path), True
        return JsonlTracker(spec.split(":", 1)[1]), True
    if isinstance(spec, Tracker):
        return spec, False
    raise TypeError(f"tracker must be a spec string, a Tracker instance "
                    f"or None, got {type(spec).__name__}")
