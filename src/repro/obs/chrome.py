"""Chrome-trace (Perfetto JSON) export of an event stream.

Turns a list of :class:`~repro.obs.events.Event` (or a JSONL trace file)
into the ``chrome://tracing`` / https://ui.perfetto.dev JSON array
format: waves and dispatches become complete ("X") duration events,
queue depths become counter ("C") tracks, and everything else becomes
instant ("i") markers — so the per-wave timeline the runtime measured
can be *looked at*, which is how the paper's §6 idle/app/flush
breakdowns were found in the first place.

Timestamps: events carry end-of-span ``ts`` (seconds since tracker
start) and a ``wall_s`` duration; Chrome wants start timestamps in
microseconds, so spans are emitted at ``(ts - wall_s) * 1e6`` clamped at
zero.  The output list is sorted by timestamp (tested monotonic).
"""
from __future__ import annotations

import json

from .events import Event

__all__ = ["load_jsonl", "chrome_trace", "export_chrome_trace"]

_PID = 0
_TID_WAVES = 0
_TID_DISPATCH = 1
_TID_MARKS = 2


def load_jsonl(path) -> list[Event]:
    """Parse a :class:`~repro.obs.tracker.JsonlTracker` trace file."""
    events = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(Event.from_record(json.loads(line)))
    return events


def _span(name: str, tid: int, end_ts: float, wall_s: float,
          args: dict) -> dict:
    start_us = max(0.0, (end_ts - wall_s)) * 1e6
    return {"name": name, "ph": "X", "pid": _PID, "tid": tid,
            "ts": start_us, "dur": max(0.0, wall_s) * 1e6, "args": args}


def chrome_trace(events: list[Event]) -> dict:
    """The Chrome trace document for ``events`` (a dict with a
    ``traceEvents`` list, ready for ``json.dump``)."""
    out: list[dict] = []
    for tid, name in ((_TID_WAVES, "waves"), (_TID_DISPATCH, "dispatches"),
                      (_TID_MARKS, "markers")):
        out.append({"name": "thread_name", "ph": "M", "pid": _PID,
                    "tid": tid, "ts": 0.0,
                    "args": {"name": name}})
    for ev in events:
        if ev.kind == "trace_header":
            continue
        if ev.kind == "wave_close":
            d = ev.data
            out.append(_span(f"wave {d['wave']} [{d['executor']}]",
                             _TID_WAVES, ev.ts, d["wall_s"], dict(d)))
        elif ev.kind == "dispatch":
            d = ev.data
            out.append(_span(f"{d['fn']} x{d['tasks']} [{d['mode']}]",
                             _TID_DISPATCH, ev.ts, d["wall_s"], dict(d)))
        elif ev.kind == "queue_depth":
            d = ev.data
            out.append({"name": f"queue[{d['channel']}]", "ph": "C",
                        "pid": _PID, "tid": _TID_MARKS, "ts": ev.ts * 1e6,
                        "args": {"depth": d["depth"]}})
        else:
            out.append({"name": ev.kind, "ph": "i", "pid": _PID,
                        "tid": _TID_MARKS, "ts": ev.ts * 1e6, "s": "t",
                        "args": dict(ev.data)})
    out.sort(key=lambda e: e["ts"])
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(events_or_path, out_path) -> dict:
    """Write the Chrome trace JSON for ``events_or_path`` (an event list
    or a JSONL trace file path) to ``out_path``; returns the document."""
    events = (load_jsonl(events_or_path)
              if isinstance(events_or_path, (str, bytes)) or
              hasattr(events_or_path, "__fspath__") else events_or_path)
    doc = chrome_trace(events)
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
        f.write("\n")
    return doc
