"""``repro.obs`` — wave-level observability for the task runtime.

The runtime is instrumented at one emit point (``TaskRuntime`` owns the
tracker, every executor reports through it) with a single structured
event schema (:mod:`~repro.obs.events`): wave open/close with dispatch
wall time and measured tile movement, per-dispatch timings and modes,
live per-channel queue depth, owner overrides, host-worker tile-cache
counters, and the DES's predicted-vs-configured cost.  Sinks are
pluggable (:mod:`~repro.obs.tracker`): in-memory for tests, JSONL trace
files for CI artifacts, a console summary for quickstarts.  Traces
export to Chrome/Perfetto JSON (:mod:`~repro.obs.chrome`) and an opt-in
``jax.profiler`` annotation ties waves to device profiles
(:mod:`~repro.obs.profiler`).

Enable per runtime::

    with TaskRuntime(executor="staged", tracker="console") as rt:
        ...

or hand in a sink to keep::

    trk = InMemoryTracker()
    with TaskRuntime(executor="sharded", tracker=trk) as rt:
        ...
    waves = trk.events_of("wave_close")

See docs/OBSERVABILITY.md for the event schema and trace workflow.
"""
from .chrome import chrome_trace, export_chrome_trace, load_jsonl
from .events import EVENT_FIELDS, EVENT_SCHEMA, Event, validate_event
from .profiler import profile_session, profiler_available, trace_span
from .summary import mode_latency, slowest_waves, summary_table
from .tracker import (NULL_TRACKER, ConsoleTracker, InMemoryTracker,
                      JsonlTracker, NullTracker, Tracker, TrackerBase,
                      make_tracker, validate_spec)

__all__ = [
    "EVENT_FIELDS", "EVENT_SCHEMA", "Event", "validate_event",
    "Tracker", "TrackerBase", "NullTracker", "NULL_TRACKER",
    "InMemoryTracker", "JsonlTracker", "ConsoleTracker",
    "make_tracker", "validate_spec",
    "chrome_trace", "export_chrome_trace", "load_jsonl",
    "slowest_waves", "mode_latency", "summary_table",
    "trace_span", "profile_session", "profiler_available",
]
