"""Checkpoint save/restore with a JSON manifest and elastic resharding.

Layout::

    <dir>/step_<k>/manifest.json     # tree structure, shapes, dtypes, meta
    <dir>/step_<k>/arr_<i>.npy       # one file per leaf
    <dir>/step_<k>/_COMMITTED        # written last -> crash-safe commit

Restore places leaves onto the *current* mesh with the *current* sharding
rules — the checkpoint stores logical arrays, not device layouts, so a run
checkpointed on a (16, 16) mesh restarts unmodified on (8, 16) or one pod
instead of two (elastic scaling / failed-pod recovery).  ``async_save``
snapshots to host memory synchronously and writes in a daemon thread, so
training resumes after one device->host copy.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, meta: dict | None
                    = None, async_save: bool = False):
    """Serialize a pytree of arrays.  Returns the checkpoint path (or the
    writer thread when ``async_save``)."""
    paths, leaves, _ = _leaves_with_paths(tree)
    # snapshot to host first (cheap on CPU; device->host copy on TPU)
    host_leaves = [np.asarray(x) for x in leaves]

    def write():
        out = os.path.join(directory, f"step_{step:08d}")
        tmp = out + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append(
                {"path": p, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "file": f"arr_{i}.npy"})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(out, ignore_errors=True)
        os.replace(tmp, out)
        return out

    if async_save:
        t = threading.Thread(target=write, daemon=True,
                             name=f"ckpt-writer-{step}")
        t.start()
        return t
    return write()


# ---------------------------------------------------------------------------
# epoch-tagged BlockArray tile checkpoints (the serving session's shared
# state).  Layout mirrors the step checkpoints above, per home::
#
#     <dir>/epoch_<e>/manifest.json    # array geometry, homes, meta
#     <dir>/epoch_<e>/home_<h>.npz     # "<name>|i,j" -> tile (npy inside)
#     <dir>/epoch_<e>/_COMMITTED       # written last -> crash-safe commit
#
# Tiles are snapshotted to host memory synchronously (one device->host
# copy), then each home's file is written by its own daemon thread —
# the per-home split matches the runtime's memory-controller homes, so
# a multi-process descendant can write each shard where it lives.
# ``np.savez`` stores raw npy records: the round-trip is bit-identical.

def _tile_key(name: str, idx: tuple[int, ...]) -> str:
    return f"{name}|{','.join(str(i) for i in idx)}"


def save_tiles(directory: str, epoch: int, arrays: dict, *,
               meta: dict | None = None, async_save: bool = False):
    """Checkpoint the tiles of named ``BlockArray``s at one epoch.

    ``arrays`` maps a stable name to a BlockArray; the same names (and
    geometries) must be passed to :func:`restore_tiles`.  Returns the
    committed path, or the committing thread when ``async_save`` (join
    it — or call ``latest_epoch`` — before trusting the epoch on disk).
    """
    per_home: dict[int, dict[str, np.ndarray]] = {}
    manifest: dict[str, Any] = {"epoch": epoch, "meta": meta or {},
                                "arrays": {}}
    for name, ba in arrays.items():
        manifest["arrays"][name] = {
            "shape": list(ba.shape), "block_shape": list(ba.block_shape),
            "dtype": str(np.dtype(ba.dtype)),
            "tiles": int(np.prod(ba.grid))}
        for idx in ba.block_indices():
            home = ba.home.get(idx, 0)
            per_home.setdefault(home, {})[_tile_key(name, idx)] = \
                np.asarray(ba.get_tile(idx))
    manifest["homes"] = sorted(per_home)

    def write():
        out = os.path.join(directory, f"epoch_{epoch:08d}")
        tmp = out + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        writers = [threading.Thread(
            target=lambda h=h, tiles=tiles: np.savez(
                os.path.join(tmp, f"home_{h}.npz"), **tiles),
            daemon=True, name=f"ckpt-home-{h}")
            for h, tiles in per_home.items()]
        for t in writers:
            t.start()
        for t in writers:
            t.join()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(out, ignore_errors=True)
        os.replace(tmp, out)
        return out

    if async_save:
        t = threading.Thread(target=write, daemon=True,
                             name=f"ckpt-epoch-{epoch}")
        t.start()
        return t
    return write()


def latest_epoch(directory: str) -> int | None:
    """Newest *committed* tile-checkpoint epoch under ``directory``
    (None when there is none — a crash mid-write leaves no marker)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"epoch_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "_COMMITTED")):
            e = int(m.group(1))
            best = e if best is None else max(best, e)
    return best


def restore_tiles(directory: str, arrays: dict, *,
                  epoch: int | None = None) -> tuple[int, dict]:
    """Load tiles back into registered ``BlockArray``s (the geometry must
    match the manifest); ``epoch=None`` means the latest committed one.
    Writing through ``set_tile`` re-commits each tile to its current home
    device, so restore is elastic across placements.  Returns
    ``(epoch, meta)``."""
    if epoch is None:
        epoch = latest_epoch(directory)
        if epoch is None:
            raise FileNotFoundError(
                f"no committed tile checkpoint under {directory!r}")
    src = os.path.join(directory, f"epoch_{epoch:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    want = set(manifest["arrays"])
    have = set(arrays)
    if want != have:
        raise ValueError(f"checkpoint/arrays mismatch: "
                         f"missing={sorted(want - have)[:4]} "
                         f"extra={sorted(have - want)[:4]}")
    for name, ba in arrays.items():
        spec = manifest["arrays"][name]
        if list(ba.shape) != spec["shape"] or \
                list(ba.block_shape) != spec["block_shape"]:
            raise ValueError(
                f"{name}: geometry {ba.shape}/{ba.block_shape} != "
                f"checkpoint {tuple(spec['shape'])}/"
                f"{tuple(spec['block_shape'])}")
    loaded: dict[str, np.ndarray] = {}
    for h in manifest["homes"]:
        with np.load(os.path.join(src, f"home_{h}.npz")) as z:
            loaded.update({k: z[k] for k in z.files})
    import jax.numpy as jnp
    for name, ba in arrays.items():
        for idx in ba.block_indices():
            tile = loaded[_tile_key(name, idx)]
            ba.set_tile(idx, jnp.asarray(tile, dtype=ba.dtype))
    return epoch, manifest["meta"]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "_COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of ``like_tree`` (abstract or concrete).
    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (elastic restore onto any mesh)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _leaves_with_paths(like_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) - set(by_path)
        extra = set(by_path) - set(paths)
        raise ValueError(f"checkpoint/tree mismatch: missing={sorted(missing)[:4]} "
                         f"extra={sorted(extra)[:4]}")
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for p, like, sh in zip(paths, leaves, shard_leaves):
        arr = np.load(os.path.join(src, by_path[p]["file"]))
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{p}: shape {arr.shape} != {want_shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return treedef.unflatten(out), manifest["meta"], manifest["step"]
