"""Checkpoint save/restore with a JSON manifest and elastic resharding.

Layout::

    <dir>/step_<k>/manifest.json     # tree structure, shapes, dtypes, meta
    <dir>/step_<k>/arr_<i>.npy       # one file per leaf
    <dir>/step_<k>/_COMMITTED        # written last -> crash-safe commit

Restore places leaves onto the *current* mesh with the *current* sharding
rules — the checkpoint stores logical arrays, not device layouts, so a run
checkpointed on a (16, 16) mesh restarts unmodified on (8, 16) or one pod
instead of two (elastic scaling / failed-pod recovery).  ``async_save``
snapshots to host memory synchronously and writes in a daemon thread, so
training resumes after one device->host copy.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *, meta: dict | None
                    = None, async_save: bool = False):
    """Serialize a pytree of arrays.  Returns the checkpoint path (or the
    writer thread when ``async_save``)."""
    paths, leaves, _ = _leaves_with_paths(tree)
    # snapshot to host first (cheap on CPU; device->host copy on TPU)
    host_leaves = [np.asarray(x) for x in leaves]

    def write():
        out = os.path.join(directory, f"step_{step:08d}")
        tmp = out + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "meta": meta or {}, "leaves": []}
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest["leaves"].append(
                {"path": p, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "file": f"arr_{i}.npy"})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(out, ignore_errors=True)
        os.replace(tmp, out)
        return out

    if async_save:
        t = threading.Thread(target=write, daemon=True,
                             name=f"ckpt-writer-{step}")
        t.start()
        return t
    return write()


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "_COMMITTED")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, step: int, like_tree, *,
                       shardings=None):
    """Restore into the structure of ``like_tree`` (abstract or concrete).
    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (elastic restore onto any mesh)."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _leaves_with_paths(like_tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) - set(by_path)
        extra = set(by_path) - set(paths)
        raise ValueError(f"checkpoint/tree mismatch: missing={sorted(missing)[:4]} "
                         f"extra={sorted(extra)[:4]}")
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for p, like, sh in zip(paths, leaves, shard_leaves):
        arr = np.load(os.path.join(src, by_path[p]["file"]))
        want_shape = tuple(like.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{p}: shape {arr.shape} != {want_shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return treedef.unflatten(out), manifest["meta"], manifest["step"]
