"""Checkpointing: save/restore with manifest + elastic resharding, plus
epoch-tagged per-home BlockArray tile checkpoints for the serving layer."""
from .checkpoint import (latest_epoch, latest_step, restore_checkpoint,
                         restore_tiles, save_checkpoint, save_tiles)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "save_tiles", "restore_tiles", "latest_epoch"]
