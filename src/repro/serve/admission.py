"""Admission control for the serving session: bound in-flight bytes.

The controller is the serving analogue of the paper's fixed-size
descriptor pool (§3.3): the runtime never holds more work than a
configured footprint budget.  Every request declares the bytes of the
block regions it will touch; the controller admits while the in-flight
total stays under ``budget_bytes``, and beyond that either queues the
request (``on_saturation="queue"``, FIFO, admitted as releases free
capacity) or rejects it outright (``"reject"``, load shedding).  A
request larger than the whole budget can never run and is always
rejected, so a queue admits in bounded time.

A secondary, latency-oriented bound rides on the live per-worker queue
depths the scheduler (and, when enabled, the ``repro.obs`` tracker)
maintains: with ``max_home_depth > 0`` admission also defers while any
worker ring holds more than that many in-flight tasks — back-pressure
from execution, not just memory.

Every decision is emitted as an ``admission_*`` event through the
session's tracker, and the counters surface as the ``admission_*``
fields of :class:`repro.core.RuntimeStats` (the invariant
``submitted == admitted + rejected`` holds once the session closes).
"""
from __future__ import annotations

from typing import Callable

from repro.obs.tracker import NULL_TRACKER

__all__ = ["AdmissionController", "RequestRejected",
           "ADMIT", "DEFER", "REJECT"]

ADMIT = "admit"
DEFER = "defer"
REJECT = "reject"

_SATURATION = ("queue", "reject")


class RequestRejected(RuntimeError):
    """The admission controller refused a request (budget/oversize)."""


class AdmissionController:
    """Byte-budget admission over declared request footprints."""

    def __init__(self, budget_bytes: int, *, on_saturation: str = "queue",
                 max_home_depth: int = 0,
                 depths_fn: Callable[[], dict] | None = None,
                 obs=NULL_TRACKER):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be >= 1")
        if on_saturation not in _SATURATION:
            raise ValueError(f"on_saturation must be one of {_SATURATION}, "
                             f"got {on_saturation!r}")
        if max_home_depth < 0:
            raise ValueError("max_home_depth must be >= 0 (0 = off)")
        self.budget_bytes = int(budget_bytes)
        self.on_saturation = on_saturation
        self.max_home_depth = int(max_home_depth)
        self._depths_fn = depths_fn
        self.obs = obs
        self.in_flight_bytes = 0
        self.peak_in_flight_bytes = 0
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0
        self.deferred = 0

    # -- decisions ----------------------------------------------------------
    def _saturated_by_depth(self) -> bool:
        if not self.max_home_depth or self._depths_fn is None:
            return False
        depths = self._depths_fn() or {}
        return any(d > self.max_home_depth for d in depths.values())

    def try_admit(self, request: str, nbytes: int) -> str:
        """Decide one arrival: ``"admit"``, ``"defer"`` or ``"reject"``.

        Call exactly once per submitted request; re-admission of a
        deferred request goes through :meth:`admit_deferred` instead so
        the ``submitted`` counter stays one-per-request.
        """
        self.submitted += 1
        if nbytes > self.budget_bytes:
            return self._reject(request, nbytes, "oversize")
        if self.in_flight_bytes + nbytes > self.budget_bytes \
                or self._saturated_by_depth():
            if self.on_saturation == "reject":
                return self._reject(request, nbytes, "budget")
            self.deferred += 1
            if self.obs.enabled:
                self.obs.emit("admission_defer", request=request,
                              bytes=nbytes,
                              in_flight_bytes=self.in_flight_bytes,
                              queued=True)
            return DEFER
        self._admit(request, nbytes)
        return ADMIT

    def has_room(self, nbytes: int) -> bool:
        """Would a deferred request of ``nbytes`` fit right now?"""
        return self.in_flight_bytes + nbytes <= self.budget_bytes \
            and not self._saturated_by_depth()

    def admit_deferred(self, request: str, nbytes: int) -> None:
        """Admit a previously deferred request (caller checked
        :meth:`has_room`)."""
        self._admit(request, nbytes)

    def reject_deferred(self, request: str, nbytes: int,
                        reason: str = "closed") -> None:
        """Resolve a still-queued request as rejected (session close)."""
        self._reject(request, nbytes, reason)

    def _admit(self, request: str, nbytes: int) -> None:
        self.admitted += 1
        self.in_flight_bytes += nbytes
        if self.in_flight_bytes > self.peak_in_flight_bytes:
            self.peak_in_flight_bytes = self.in_flight_bytes
        if self.obs.enabled:
            self.obs.emit("admission_admit", request=request, bytes=nbytes,
                          in_flight_bytes=self.in_flight_bytes)

    def _reject(self, request: str, nbytes: int, reason: str) -> str:
        self.rejected += 1
        if self.obs.enabled:
            self.obs.emit("admission_reject", request=request, bytes=nbytes,
                          in_flight_bytes=self.in_flight_bytes,
                          reason=reason)
        return REJECT

    # -- completion ---------------------------------------------------------
    def release(self, request: str, nbytes: int,
                latency_s: float = 0.0) -> None:
        """An admitted request completed: return its bytes to the budget."""
        self.in_flight_bytes -= nbytes
        assert self.in_flight_bytes >= 0, "released more than admitted"
        if self.obs.enabled:
            self.obs.emit("admission_release", request=request,
                          bytes=nbytes,
                          in_flight_bytes=self.in_flight_bytes,
                          latency_s=latency_s)

    def __repr__(self):
        return (f"<AdmissionController {self.in_flight_bytes}/"
                f"{self.budget_bytes}B in flight, "
                f"{self.admitted}/{self.submitted} admitted>")
