"""Streaming task-graph serving over the BDDT-SCC runtime.

Continuous ingestion instead of batch drain: requests arrive as small
task graphs against shared long-lived ``BlockArray`` state, resolve
through per-request ``TaskFuture`` cones, and an admission controller
bounds the in-flight footprint bytes; shared state checkpoints per home
through ``repro.ckpt`` (epoch-tagged, async, bit-identical restore).

Entry point: :class:`Session` (see ``docs/API.md`` for the quickstart).
"""
from .admission import AdmissionController, RequestRejected
from .session import RequestHandle, ServeConfig, Session, footprint_nbytes

__all__ = ["Session", "ServeConfig", "RequestHandle",
           "AdmissionController", "RequestRejected", "footprint_nbytes"]
