"""Continuous-ingestion serving on top of :class:`~repro.core.TaskRuntime`.

Batch programs build one graph and drain it; a serving loop never
drains.  Requests arrive as *small task graphs* spawned against shared
long-lived ``BlockArray`` state (embedding tables, KV tiles), each
resolving through its own :class:`~repro.core.TaskFuture`s — the
dependence-cone waits and region-scoped ``wait_on`` the batch API
already has are exactly per-request isolation: requests touching
disjoint tiles never serialize behind each other.

::

    from repro import RuntimeConfig
    from repro.serve import ServeConfig, Session

    with Session(RuntimeConfig(executor="staged"),
                 ServeConfig(budget_bytes=1 << 20)) as s:
        kv = s.from_array(kv_init, (1, 64, 64), name="kv")   # shared state
        out = s.zeros((n_slots, 64), (1, 64), name="out", state=False)
        h = s.submit(lambda: lookup(out[i], kv[j]), out[i], kv[j])
        h.wait()                       # this request's cone only
        print(h.latency_s, s.stats().admission_admitted)

``submit`` declares the request's block footprint up front; the
:class:`~repro.serve.admission.AdmissionController` bounds the total
in-flight footprint bytes, queuing or shedding beyond the budget.  The
builder runs only on admission — a deferred request costs nothing until
capacity frees.

Fault tolerance lives at the memory layer: ``checkpoint()`` snapshots
every ``state=True`` array's tiles through ``repro.ckpt.save_tiles``
(epoch-tagged, per-home files, async by default — off the serving
critical path), and ``restore_latest()`` reloads the newest committed
epoch bit-identically after a runtime restart.
"""
from __future__ import annotations

import contextlib
import time
from collections import deque
from typing import Callable, Sequence

from repro.core import TaskRuntime
from repro.core.api import RuntimeConfig, RuntimeStats, TaskFuture
from repro.core.blocks import BlockArray, Region

from .admission import ADMIT, DEFER, AdmissionController, RequestRejected

__all__ = ["ServeConfig", "Session", "RequestHandle"]

_ON_SATURATION = ("queue", "reject")


def footprint_nbytes(regions: Sequence) -> int:
    """Total bytes of the distinct tiles the regions cover (a tile named
    by several regions counts once — the admission unit of one request)."""
    seen: set = set()
    nbytes = 0
    for r in regions:
        if isinstance(r, BlockArray):
            r = r.whole
        if not isinstance(r, Region):
            raise TypeError(f"expected a Region or BlockArray, "
                            f"got {type(r).__name__}")
        per_tile = r.array.tile_nbytes
        for b in r.block_ids:
            if b not in seen:
                seen.add(b)
                nbytes += per_tile
    return nbytes


class ServeConfig:
    """Serving knobs, validated once at session construction.

    * ``budget_bytes``    — in-flight footprint byte budget (admission).
    * ``on_saturation``   — ``"queue"`` (FIFO, admit as capacity frees)
      or ``"reject"`` (shed load beyond the budget).
    * ``max_home_depth``  — also defer while any worker ring holds more
      than this many in-flight tasks (0 = off); read from the live
      queue depths the scheduler/tracker maintain.
    * ``checkpoint_dir``  — where tile checkpoints go (None = no
      checkpointing).
    * ``checkpoint_every``— auto-checkpoint after this many completed
      requests (0 = manual ``checkpoint()`` calls only).
    * ``async_checkpoint``— commit checkpoint epochs on a writer thread,
      off the serving critical path.
    """

    def __init__(self, budget_bytes: int = 1 << 30, *,
                 on_saturation: str = "queue", max_home_depth: int = 0,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int = 0, async_checkpoint: bool = True):
        if budget_bytes < 1:
            raise ValueError("budget_bytes must be >= 1")
        if on_saturation not in _ON_SATURATION:
            raise ValueError(f"on_saturation must be one of "
                             f"{_ON_SATURATION}, got {on_saturation!r}")
        if max_home_depth < 0:
            raise ValueError("max_home_depth must be >= 0 (0 = off)")
        if checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0 (0 = manual)")
        if checkpoint_every and checkpoint_dir is None:
            raise ValueError("checkpoint_every needs a checkpoint_dir")
        self.budget_bytes = int(budget_bytes)
        self.on_saturation = on_saturation
        self.max_home_depth = int(max_home_depth)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        self.async_checkpoint = bool(async_checkpoint)


class RequestHandle:
    """One submitted request: its state, futures, and latency."""

    QUEUED = "queued"
    ADMITTED = "admitted"
    REJECTED = "rejected"
    DONE = "done"

    def __init__(self, session: "Session", name: str, builder: Callable,
                 nbytes: int):
        self._session = session
        self.name = name
        self._builder = builder
        self.nbytes = nbytes
        self.state = self.QUEUED
        self.futures: tuple[TaskFuture, ...] = ()
        self.submit_ts = time.perf_counter()
        self.done_ts: float | None = None

    # -- introspection ------------------------------------------------------
    def done(self) -> bool:
        return self.state == self.DONE

    def rejected(self) -> bool:
        return self.state == self.REJECTED

    @property
    def latency_s(self) -> float | None:
        """Submit-to-completion wall time (None while in flight)."""
        if self.done_ts is None:
            return None
        return self.done_ts - self.submit_ts

    # -- synchronization ----------------------------------------------------
    def wait(self) -> "RequestHandle":
        """Block until this request completed — forces only its own
        tasks' dependence cones, never unrelated in-flight requests."""
        self._session._wait_handle(self)
        return self

    def result(self):
        """Wait, then return the request's task results (one per future,
        in builder order; a single-future request returns it bare)."""
        self.wait()
        results = [f.result() for f in self.futures]
        if not results:
            return None
        return results[0] if len(results) == 1 else results

    def __repr__(self):
        return f"<RequestHandle {self.name} {self.state} {self.nbytes}B>"


class Session:
    """A serving loop over one runtime: submit, admit, resolve, repeat.

    Single-threaded by design (like the paper's master core): ``submit``
    / ``poll`` / ``wait`` are called from the master thread, and the
    executor parallelizes underneath.  Use as a context manager — exit
    drains in-flight requests, resolves still-queued ones as rejected,
    writes a final checkpoint (when configured), and shuts down an
    internally-created runtime.
    """

    def __init__(self, config: RuntimeConfig | None = None,
                 serve: ServeConfig | None = None, *,
                 runtime: TaskRuntime | None = None, **overrides):
        self.serve = serve or ServeConfig()
        if runtime is not None:
            if config is not None or overrides:
                raise ValueError("pass either a ready runtime= or a "
                                 "RuntimeConfig, not both")
            self.rt = runtime
            self._rt_owned = False
        else:
            self.rt = TaskRuntime(config, **overrides)
            self._rt_owned = True
        if self.rt.executor_kind == "sim":
            raise ValueError("executor='sim' is timing-only and never "
                             "computes task values; serve needs a real "
                             "executor")
        obs = self.rt.obs
        depths_fn = self.rt.scheduler.queue_depths
        self.admission = AdmissionController(
            self.serve.budget_bytes, on_saturation=self.serve.on_saturation,
            max_home_depth=self.serve.max_home_depth,
            depths_fn=depths_fn, obs=obs)
        self.rt.admission = self.admission    # stats() surfaces admission_*
        self._state: dict[str, BlockArray] = {}
        self._queue: deque[RequestHandle] = deque()
        self._inflight: list[RequestHandle] = []
        self._req_counter = 0
        self._ckpt_epoch = 0
        self._ckpt_thread = None
        self._completed_since_ckpt = 0
        self._closed = False

    # -- shared state -------------------------------------------------------
    def _track_state(self, ba: BlockArray, name: str | None,
                     state: bool) -> BlockArray:
        if state:
            if name is None:
                raise ValueError("state arrays need an explicit name= "
                                 "(checkpoint identity across restarts)")
            if name in self._state:
                raise ValueError(f"state array {name!r} already registered")
            self._state[name] = ba
        return ba

    def from_array(self, arr, block_shape, name: str | None = None, *,
                   state: bool = True) -> BlockArray:
        """Register shared state (checkpointed under ``name``); pass
        ``state=False`` for per-request scratch arrays."""
        return self._track_state(
            self.rt.from_array(arr, block_shape, name), name, state)

    def zeros(self, shape, block_shape, dtype=None,
              name: str | None = None, *, state: bool = True) -> BlockArray:
        return self._track_state(
            self.rt.zeros(shape, block_shape, dtype, name), name, state)

    def full(self, shape, block_shape, fill, dtype=None,
             name: str | None = None, *, state: bool = True) -> BlockArray:
        return self._track_state(
            self.rt.full(shape, block_shape, fill, dtype, name), name, state)

    # -- request ingestion --------------------------------------------------
    def submit(self, builder: Callable, *footprint,
               name: str | None = None) -> RequestHandle:
        """Submit one request: ``builder`` spawns its task graph when the
        request is admitted (it runs inside the runtime scope and returns
        the request's TaskFuture(s)); ``footprint`` declares the block
        regions the graph will touch — the admission unit.

        Returns immediately with a :class:`RequestHandle` in state
        ``admitted`` (builder ran), ``queued`` (deferred until capacity
        frees) or ``rejected`` (budget shed / oversize).
        """
        if self._closed:
            raise RuntimeError("session is closed")
        if not footprint:
            raise ValueError("a request must declare a non-empty footprint "
                             "(the regions its task graph touches)")
        self._req_counter += 1
        rname = name or f"req-{self._req_counter}"
        handle = RequestHandle(self, rname, builder,
                               footprint_nbytes(footprint))
        decision = self.admission.try_admit(rname, handle.nbytes)
        if decision == ADMIT:
            self._launch(handle)
        elif decision == DEFER:
            self._queue.append(handle)
        else:
            handle.state = RequestHandle.REJECTED
        return handle

    def _launch(self, handle: RequestHandle) -> None:
        with self.rt.scope():
            futures = handle._builder()
        if futures is None:
            futures = ()
        elif isinstance(futures, TaskFuture):
            futures = (futures,)
        handle.futures = tuple(futures)
        handle._builder = None          # release the closure
        handle.state = RequestHandle.ADMITTED
        self._inflight.append(handle)

    # -- completion ---------------------------------------------------------
    def poll(self) -> int:
        """Complete every admitted request whose tasks all finished
        (non-blocking); returns how many completed.  Call between
        arrivals under an eager executor (the host executor exposes a
        non-blocking ``pump`` that polls the worker rings); with lazy
        executors completion is driven by ``wait()``/``drain()``."""
        pump = getattr(self.rt._exec, "pump", None)
        if pump is not None:
            pump()
        done = [h for h in self._inflight
                if all(f.descriptor.is_complete for f in h.futures)]
        for h in done:
            self._complete(h)
        return len(done)

    def _wait_handle(self, handle: RequestHandle) -> None:
        if handle.state == RequestHandle.REJECTED:
            raise RequestRejected(f"request {handle.name} was rejected "
                                  f"({handle.nbytes}B over budget or shed)")
        while handle.state == RequestHandle.QUEUED:
            # queued behind in-flight work: retire the oldest admitted
            # request to free capacity, then re-drain the queue
            if self._inflight:
                self._wait_handle(self._inflight[0])
            else:
                self._drain_queue()
                if not self._inflight and \
                        handle.state == RequestHandle.QUEUED:
                    self._force_admit_front()
        if handle.state == RequestHandle.DONE:
            return
        self.rt._wait_tasks([f.descriptor for f in handle.futures],
                            kind="request")
        self._complete(handle)

    def _complete(self, handle: RequestHandle) -> None:
        handle.done_ts = time.perf_counter()
        handle.state = RequestHandle.DONE
        self._inflight.remove(handle)
        self.admission.release(handle.name, handle.nbytes,
                               latency_s=handle.latency_s)
        self._completed_since_ckpt += 1
        self._drain_queue()
        if self.serve.checkpoint_every and \
                self._completed_since_ckpt >= self.serve.checkpoint_every:
            self.checkpoint()

    def _drain_queue(self) -> None:
        while self._queue and self.admission.has_room(self._queue[0].nbytes):
            handle = self._queue.popleft()
            self.admission.admit_deferred(handle.name, handle.nbytes)
            self._launch(handle)

    def _force_admit_front(self) -> None:
        # depth back-pressure deferred the queue front but nothing is
        # left in flight to wait for — push it through so waits always
        # make progress (the byte budget itself is never exceeded here:
        # with zero bytes in flight any non-oversize request fits)
        handle = self._queue.popleft()
        self.admission.admit_deferred(handle.name, handle.nbytes)
        self._launch(handle)

    def drain(self) -> None:
        """Resolve everything: admitted requests complete, queued ones
        admit as capacity frees."""
        self._drain_queue()
        while self._inflight or self._queue:
            if self._inflight:
                self._wait_handle(self._inflight[0])
                continue
            self._drain_queue()
            if not self._inflight and self._queue:
                self._force_admit_front()

    # -- checkpoint / restore ----------------------------------------------
    @property
    def state_bytes(self) -> int:
        return sum(int(ba.tile_nbytes) * len(ba.home)
                   for ba in self._state.values())

    def checkpoint(self, *, sync: bool | None = None) -> int:
        """Snapshot every state array's tiles as the next epoch (through
        ``repro.ckpt.save_tiles``); returns the epoch number.  Async by
        default — the snapshot to host memory is synchronous, the disk
        commit happens on a writer thread."""
        if self.serve.checkpoint_dir is None:
            raise RuntimeError("no checkpoint_dir configured")
        if not self._state:
            raise RuntimeError("no state arrays registered")
        from repro.ckpt import save_tiles
        self._join_ckpt()
        self._ckpt_epoch += 1
        self._completed_since_ckpt = 0
        async_save = self.serve.async_checkpoint if sync is None \
            else not sync
        result = save_tiles(self.serve.checkpoint_dir, self._ckpt_epoch,
                            self._state, async_save=async_save)
        if async_save:
            self._ckpt_thread = result
        if self.rt.obs.enabled:
            self.rt.obs.emit(
                "ckpt_save", epoch=self._ckpt_epoch,
                arrays=len(self._state),
                tiles=sum(len(ba.home) for ba in self._state.values()),
                bytes=self.state_bytes)
        return self._ckpt_epoch

    def restore_latest(self) -> int | None:
        """Reload the newest committed epoch into the registered state
        arrays (bit-identical tiles); None when no checkpoint exists.
        Future checkpoints continue after the restored epoch."""
        if self.serve.checkpoint_dir is None:
            raise RuntimeError("no checkpoint_dir configured")
        from repro.ckpt import latest_epoch, restore_tiles
        if latest_epoch(self.serve.checkpoint_dir) is None:
            return None
        epoch, _ = restore_tiles(self.serve.checkpoint_dir, self._state)
        self._ckpt_epoch = epoch
        if self.rt.obs.enabled:
            self.rt.obs.emit(
                "ckpt_restore", epoch=epoch, arrays=len(self._state),
                tiles=sum(len(ba.home) for ba in self._state.values()),
                bytes=self.state_bytes)
        return epoch

    def _join_ckpt(self) -> None:
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
            self._ckpt_thread = None

    # -- lifecycle ----------------------------------------------------------
    def stats(self) -> RuntimeStats:
        """The runtime's stats with the ``admission_*`` fields filled."""
        return self.rt.stats()

    def close(self) -> None:
        """Drain admitted work, resolve still-queued requests as
        rejected when shedding (or admit them when queuing), commit the
        final checkpoint, and shut down an owned runtime."""
        if self._closed:
            return
        self.drain()
        self._closed = True
        if self.serve.checkpoint_dir is not None and self._state:
            with contextlib.suppress(RuntimeError):
                self.checkpoint()
            self._join_ckpt()
        if self._rt_owned:
            self.rt.barrier()
            self.rt.shutdown()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        if exc == (None, None, None):
            self.close()
        elif self._rt_owned:
            self.rt.shutdown()

    def __repr__(self):
        return (f"<Session {len(self._inflight)} in flight, "
                f"{len(self._queue)} queued, "
                f"{self.admission.in_flight_bytes}/"
                f"{self.serve.budget_bytes}B>")
