"""Block-level dynamic dependence analysis (the BDDT algorithm, §3.3).

For every block (tile) the analyzer keeps metadata ordering the tasks that
touch it: the last writer and the set of readers since that write.  At spawn
("task initiation") each new task's footprint is walked block-by-block:

  * a READ of block b depends on b's last incomplete writer (RAW);
  * a WRITE of block b depends on b's last incomplete writer (WAW) and on
    every incomplete reader since that write (WAR).

Only tasks whose footprints actually overlap are ordered — the dynamic
analysis "only synchronizes tasks that actually have conflicting memory
footprints", which is the paper's argument for discovering more parallelism
than static synchronization.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

from .blocks import coerce_mode

if TYPE_CHECKING:  # pragma: no cover
    from .graph import TaskDescriptor

BlockId = tuple[int, tuple[int, ...]]  # (array_id, tile index)

__all__ = ["BlockMeta", "DependenceAnalyzer", "BlockId"]


@dataclass
class BlockMeta:
    """Per-block ordering metadata (BDDT keeps this per allocator block)."""
    last_writer: "TaskDescriptor | None" = None
    readers: list["TaskDescriptor"] = field(default_factory=list)


class DependenceAnalyzer:
    """Discovers dependencies of a new task against all previously spawned,
    still-live tasks, block by block."""

    def __init__(self) -> None:
        self._meta: dict[BlockId, BlockMeta] = {}
        # statistics mirrored in the paper's master-cost discussion
        self.blocks_walked = 0
        self.deps_found = 0

    def _meta_for(self, block: BlockId) -> BlockMeta:
        m = self._meta.get(block)
        if m is None:
            m = self._meta[block] = BlockMeta()
        return m

    def analyze(self, task: "TaskDescriptor") -> set["TaskDescriptor"]:
        """Walk the task footprint; return the set of tasks it must wait for
        and update block metadata to order later tasks after this one."""
        deps: set[TaskDescriptor] = set()

        # Pass 1: collect dependencies from current metadata.
        for mode in task.args:
            for block in mode.region.block_ids:
                self.blocks_walked += 1
                m = self._meta_for(block)
                if mode.READS or mode.WRITES:        # RAW / WAW
                    w = m.last_writer
                    if w is not None and not w.is_complete and w is not task:
                        deps.add(w)
                if mode.WRITES:                      # WAR
                    for r in m.readers:
                        if not r.is_complete and r is not task:
                            deps.add(r)

        # Pass 2: publish this task into the metadata (readers first so an
        # INOUT arg does not register a self-dependency).
        for mode in task.args:
            for block in mode.region.block_ids:
                m = self._meta_for(block)
                if mode.WRITES:
                    m.last_writer = task
                    m.readers = []
                elif mode.READS:
                    if task not in m.readers:
                        m.readers.append(task)

        self.deps_found += len(deps)
        return deps

    def tasks_touching(self, blocks, mode: str = "in") -> set["TaskDescriptor"]:
        """Live tasks a *synchronization* on ``blocks`` must wait for —
        the same rules task initiation applies, so ``wait_on(region)`` is
        exactly the paper's automatic sync scoped to a footprint:

        * ``mode="in"``    — pending writers (the data must be produced);
        * ``mode="out"`` / ``"inout"`` — writers *and* readers (the caller
          intends to overwrite, so WAR orderings count too).
        """
        mode = coerce_mode(mode)
        found: set[TaskDescriptor] = set()
        for block in blocks:
            m = self._meta.get(block)
            if m is None:
                continue
            w = m.last_writer
            if w is not None and not w.is_complete:
                found.add(w)
            if mode != "in":
                for r in m.readers:
                    if not r.is_complete:
                        found.add(r)
        return found

    def forget_completed(self, task: "TaskDescriptor") -> None:
        """Drop references to a released task so metadata stays O(live tasks)
        (the paper recycles descriptors from a pre-allocated pool; stale
        pointers must not keep ordering anybody)."""
        for mode in task.args:
            for block in mode.region.block_ids:
                m = self._meta.get(block)
                if m is None:
                    continue
                if m.last_writer is task:
                    # safe to drop: dep checks filter on is_complete anyway
                    m.last_writer = None
                if task in m.readers:
                    m.readers.remove(task)
                if m.last_writer is None and not m.readers:
                    del self._meta[block]
