"""Task descriptors, the task graph, and the master's queues (§3.2).

A spawned task becomes a :class:`TaskDescriptor` that moves through the four
runtime stages of the paper: initiation -> scheduling -> execution -> release.
The master keeps three structures in its private memory: the *ready queue*
(ready, unscheduled), the *completion queue* (executed, dependencies not yet
released) and the *task graph* (waiting on dependencies).  Descriptors come
from a bounded pre-allocated pool and are recycled at release (§3.3).
"""
from __future__ import annotations

import enum
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .blocks import AccessMode, In, InOut, Out

__all__ = ["TaskState", "TaskDescriptor", "TaskGraph", "DescriptorPool",
           "normalize_outputs"]


def normalize_outputs(result, n_out: int, label) -> tuple:
    """Normalize a task function's return value into one value per
    OUT/INOUT argument, validating arity (the §3.5 execution contract,
    shared by ``TaskDescriptor.run`` and both StagedExecutor paths)."""
    if result is None:
        result = ()
    elif n_out == 1:
        result = (result,)
    if len(result) != n_out:
        raise RuntimeError(
            f"task {label}: fn returned {len(result)} values for "
            f"{n_out} OUT/INOUT arguments")
    return tuple(result)


class TaskState(enum.Enum):
    WAITING = "waiting"        # in the task graph, deps unresolved
    READY = "ready"            # ready queue (or MPB slot), not yet executed
    RUNNING = "running"        # being executed by a worker
    EXECUTED = "executed"      # completed, dependencies not yet released
    RELEASED = "released"      # dependencies released, descriptor recycled


@dataclass(eq=False)
class TaskDescriptor:
    """What the master writes into a worker's MPB slot: the spawned function,
    its arguments, a representation of the footprint, and any firstprivate
    values (OmpSs by-value parameters, copied in at initiation)."""
    tid: int
    fn: Callable
    args: tuple[AccessMode, ...]
    name: str = ""
    values: tuple = ()                 # firstprivate, in parameter order
    # dependence bookkeeping
    deps_remaining: int = 0
    dependents: list["TaskDescriptor"] = field(default_factory=list)
    preds: tuple["TaskDescriptor", ...] = ()   # discovered at initiation
    state: TaskState = TaskState.WAITING
    worker: int | None = None
    # instrumentation (used by tests, the DES and the benchmarks)
    spawn_order: int = 0
    exec_order: int | None = None
    # outputs captured at execution (references, not copies — jax arrays
    # are immutable), so a TaskFuture reads this task's values even after
    # later writers overwrite the region; None until executed, and stays
    # None under the timing-only sim executor
    output_values: tuple | None = None

    @property
    def is_complete(self) -> bool:
        return self.state in (TaskState.EXECUTED, TaskState.RELEASED)

    @property
    def inputs(self) -> tuple[AccessMode, ...]:
        return tuple(a for a in self.args if a.READS)

    @property
    def outputs(self) -> tuple[AccessMode, ...]:
        return tuple(a for a in self.args if a.WRITES)

    def run(self, materialize=None) -> None:
        """Task execution (§3.5): call the task function on materialized
        inputs; store the returned values into the OUT/INOUT regions.

        The function receives one array per READS argument, in argument
        order, then the firstprivate values in parameter order, and must
        return one array per WRITES argument, in argument order (a single
        array if there is exactly one).

        ``materialize`` (``region -> array``) overrides how READS regions
        assemble — host workers pass their pinned tile cache's reader so
        repeated reads of unchanged regions skip reassembly.
        """
        from .api import suspend_runtime_scope
        if materialize is None:
            in_vals = [a.region.materialize() for a in self.args if a.READS]
        else:
            in_vals = [materialize(a.region) for a in self.args if a.READS]
        with suspend_runtime_scope():
            result = self.fn(*in_vals, *self.values)
        outs = self.outputs
        result = normalize_outputs(result, len(outs), self.name or self.tid)
        for mode, value in zip(outs, result):
            mode.region.store(value)
        self.output_values = result

    def __repr__(self):
        return (f"<T{self.tid} {self.name or self.fn.__name__} "
                f"{self.state.value}>")


class DescriptorPool:
    """Pre-allocated descriptor pool (§3.3).  ``acquire`` fails when empty —
    the master must then enter polling mode and release completed tasks to
    recycle descriptors, exactly as in the paper."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._live = 0
        self._tid = itertools.count()

    def acquire(self, fn, args, name="",
                values: tuple = ()) -> TaskDescriptor | None:
        if self._live >= self.capacity:
            return None
        self._live += 1
        return TaskDescriptor(tid=next(self._tid), fn=fn, args=tuple(args),
                              name=name, values=tuple(values))

    def release(self, td: TaskDescriptor) -> None:
        td.state = TaskState.RELEASED
        self._live -= 1

    @property
    def free(self) -> int:
        return self.capacity - self._live


class TaskGraph:
    """The master's view of all live tasks plus its ready/completion queues."""

    def __init__(self):
        self.ready: deque[TaskDescriptor] = deque()
        self.completion: deque[TaskDescriptor] = deque()
        self.waiting: set[TaskDescriptor] = set()
        self.n_unreleased = 0          # live tasks not yet released
        self.n_unexecuted = 0          # live tasks not yet executed
        self._exec_counter = itertools.count()

    # -- task initiation ----------------------------------------------------
    def insert(self, td: TaskDescriptor, deps: set[TaskDescriptor]) -> bool:
        """Add a new task given its discovered dependencies.  Returns True if
        the task is immediately ready."""
        self.n_unreleased += 1
        self.n_unexecuted += 1
        td.deps_remaining = len(deps)
        # spawn-order the dependence set: ``deps`` arrives as a set whose
        # iteration order depends on how it was assembled (central walk vs
        # per-home manager grants), and preds/dependents order feeds the
        # ready queues — sorting pins one schedule for both managers
        ordered = sorted(deps, key=lambda t: t.spawn_order)
        td.preds = tuple(ordered)
        for d in ordered:
            d.dependents.append(td)
        if td.deps_remaining == 0:
            td.state = TaskState.READY
            return True
        td.state = TaskState.WAITING
        self.waiting.add(td)
        return False

    # -- task execution accounting -------------------------------------------
    def mark_executed(self, td: TaskDescriptor) -> None:
        td.state = TaskState.EXECUTED
        td.exec_order = next(self._exec_counter)
        self.n_unexecuted -= 1

    # -- task release (§3.6) --------------------------------------------------
    def release(self, td: TaskDescriptor) -> list[TaskDescriptor]:
        """Decrement dependents' counters; return newly-ready tasks."""
        newly_ready = []
        for dep in td.dependents:
            dep.deps_remaining -= 1
            if dep.deps_remaining == 0 and not dep.is_complete:
                # the is_complete guard matters for staged execution,
                # where a whole wave runs before any release: an already-
                # executed dependent must not re-enter the ready queue
                # (it would pin its descriptor + outputs there forever)
                dep.state = TaskState.READY
                self.waiting.discard(dep)
                newly_ready.append(dep)
        td.dependents = []
        td.preds = ()          # keep metadata O(live tasks), as in §3.6
        self.n_unreleased -= 1
        return newly_ready

    @property
    def quiescent(self) -> bool:
        return self.n_unreleased == 0
