"""Pipeline parallelism as a BDDT task graph.

The paper's thesis is that declared footprints + dynamic dependence
analysis give you the schedule for free.  Pipeline-parallel training is a
perfect showcase: forward/backward microbatch steps are *tasks*, stage
activations/gradients are *blocks*, per-stage weight gradients are INOUT
accumulators — run the BDDT analysis over those footprints and the
classic 1F1B schedule *emerges* from greedy backward-first scheduling of
the discovered DAG, bubbles and all.  No pipeline-specific scheduler is
written anywhere.

`derive_pipeline_schedule` builds the DAG with the same
DependenceAnalyzer machinery the tile benchmarks use and extracts a
per-clock timetable; `pipeline_step` executes a timetable SPMD-style over
a mesh axis with `shard_map` + `ppermute` (stage-to-stage activation hops
— cross-pod point-to-point traffic instead of global all-reduce, which is
why the ``pod`` axis of the production mesh is the natural stage axis).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .blocks import BlockArray, In, InOut, Out
from .deps import DependenceAnalyzer
from .graph import DescriptorPool

__all__ = ["derive_pipeline_schedule", "schedule_table", "pipeline_step",
           "PipeTask"]


@dataclass(frozen=True)
class PipeTask:
    kind: str          # "F" | "B"
    stage: int
    micro: int

    def __repr__(self):
        return f"{self.kind}{self.stage}.{self.micro}"


def _noop(*args):  # task body placeholder (schedule derivation only)
    return jnp.zeros((1, 1))


def derive_pipeline_schedule(n_stages: int, n_micro: int
                             ) -> list[list[PipeTask | None]]:
    """Run BDDT dependence analysis over the pipeline's footprints and
    greedily schedule: each stage is a worker; backward tasks take
    priority (1F1B memory behaviour).  Returns the per-clock timetable:
    ``table[t][s]`` is the task stage ``s`` runs at clock ``t`` (None =
    bubble)."""
    analyzer = DependenceAnalyzer()
    pool = DescriptorPool(capacity=4 * n_stages * n_micro + 16)

    # blocks: activations A[s][m], gradients G[s][m], weight grads dW[s]
    acts = BlockArray((n_stages, n_micro), (1, 1), name="A")
    grads = BlockArray((n_stages, n_micro), (1, 1), name="G")
    wgrad = BlockArray((n_stages, 1), (1, 1), name="dW")

    tasks: dict[int, PipeTask] = {}
    edges: dict[int, list[int]] = {}
    indeg: dict[int, int] = {}

    def spawn(kind, s, m, args):
        td = pool.acquire(_noop, args, name=f"{kind}{s}.{m}")
        deps = analyzer.analyze(td)
        tasks[td.tid] = PipeTask(kind, s, m)
        edges[td.tid] = []
        indeg[td.tid] = len(deps)
        for d in deps:
            edges[d.tid].append(td.tid)

    for m in range(n_micro):
        for s in range(n_stages):
            args = [Out(acts[s, m])]
            if s > 0:
                args.append(In(acts[s - 1, m]))
            spawn("F", s, m, args)
    for m in range(n_micro):
        for s in reversed(range(n_stages)):
            args = [In(acts[s, m]), Out(grads[s, m]),
                    InOut(wgrad[s, 0])]        # accumulation serializes
            if s < n_stages - 1:
                args.append(In(grads[s + 1, m]))
            spawn("B", s, m, args)

    # greedy list scheduling: one slot per stage per clock, backward first
    table: list[list[PipeTask | None]] = []
    ready = {tid for tid, d in indeg.items() if d == 0}
    done: set[int] = set()
    while len(done) < len(tasks):
        row: list[PipeTask | None] = [None] * n_stages
        fired = []
        for s in range(n_stages):
            cands = [tid for tid in ready if tasks[tid].stage == s]
            if not cands:
                continue
            # 1F1B: prefer backward, then lowest microbatch id
            cands.sort(key=lambda tid: (tasks[tid].kind != "B",
                                        tasks[tid].micro))
            pick = cands[0]
            row[s] = tasks[pick]
            fired.append(pick)
            ready.discard(pick)
        if not fired:
            raise RuntimeError("pipeline schedule deadlock")
        for tid in fired:
            done.add(tid)
            for nxt in edges[tid]:
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    ready.add(nxt)
        table.append(row)
    return table


def schedule_table(table) -> str:
    """Pretty-print the timetable (stages = rows, clocks = columns)."""
    n_stages = len(table[0])
    lines = []
    for s in range(n_stages):
        cells = [f"{table[t][s]!r:>7s}" if table[t][s] else "      ."
                 for t in range(len(table))]
        lines.append(f"stage{s} |" + "".join(cells))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
def pipeline_step(stage_fwd, stage_bwd, params, micro_inputs, *, mesh,
                  stage_axis: str, n_stages: int):
    """Execute a derived timetable SPMD-style.

    ``stage_fwd(w, x) -> y`` / ``stage_bwd(w, x, g_out) -> (g_in, dw)``
    are the per-stage task bodies; ``params``: (S, ...) stacked stage
    weights sharded over ``stage_axis``; ``micro_inputs``: (M, B, d) fed
    to stage 0.  Activations hop stage-to-stage with ``ppermute`` — the
    MPB descriptor of the paper becomes a point-to-point ICI message.
    Returns the accumulated weight-grad stack (S, ...).
    """
    from jax.sharding import PartitionSpec as P
    table = derive_pipeline_schedule(n_stages, micro_inputs.shape[0])
    n_micro = micro_inputs.shape[0]

    def body(w_s, micros):
        w_s = jax.tree_util.tree_map(lambda a: a[0], w_s)
        sid = jax.lax.axis_index(stage_axis)
        b, d = micros.shape[1], micros.shape[2]
        acts_in = jnp.zeros((n_micro, b, d), micros.dtype)   # received x
        gr_in = jnp.zeros((n_micro, b, d), micros.dtype)     # received g
        dw = jax.tree_util.tree_map(jnp.zeros_like, w_s)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]
        bwd_perm = [(i + 1, i) for i in range(n_stages - 1)]

        for row in table:
            send_fwd = jnp.zeros((b, d), micros.dtype)
            send_bwd = jnp.zeros((b, d), micros.dtype)
            for s, task in enumerate(row):
                if task is None:
                    continue
                is_me = (sid == s)
                m = task.micro
                x = jnp.where(s == 0, micros[m], acts_in[m])
                if task.kind == "F":
                    y = stage_fwd(w_s, x)
                    send_fwd = jnp.where(is_me, y, send_fwd)
                else:
                    g_out = jnp.where(s == n_stages - 1,
                                      jnp.ones((b, d), micros.dtype),
                                      gr_in[m])
                    g_in, dw_m = stage_bwd(w_s, x, g_out)
                    dw = jax.tree_util.tree_map(
                        lambda a, u: a + jnp.where(is_me, u, 0.0),
                        dw, dw_m)
                    send_bwd = jnp.where(is_me, g_in, send_bwd)
            # stage-to-stage hops for everything produced this clock
            recv_f = jax.lax.ppermute(send_fwd, stage_axis, fwd_perm)
            recv_b = jax.lax.ppermute(send_bwd, stage_axis, bwd_perm)
            for s, task in enumerate(row):
                if task is None:
                    continue
                m = task.micro
                if task.kind == "F" and s + 1 < n_stages:
                    acts_in = acts_in.at[m].set(
                        jnp.where(sid == s + 1, recv_f, acts_in[m]))
                if task.kind == "B" and s - 1 >= 0:
                    gr_in = gr_in.at[m].set(
                        jnp.where(sid == s - 1, recv_b, gr_in[m]))
        return jax.tree_util.tree_map(lambda a: a[None], dw)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(stage_axis),
        check_vma=False)(params, micro_inputs)
