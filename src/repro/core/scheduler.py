"""The master core's scheduling logic (§3.4) and task release (§3.6).

The master is in one of two modes:

* **running** — executing the main program.  A spawned, immediately-ready
  task is appended to some worker's MPB queue; if that worker's next slot is
  full the task goes to the master's local ready queue and the main program
  continues — the master *never blocks at a spawn*.
* **polling** — entered at synchronization points (barriers, end of program)
  or when the descriptor pool is exhausted.  The master then (i) drains the
  ready queue, (ii) polls worker queues for completed descriptors, and
  (iii) releases completed tasks' dependencies from the completion queue.

Release is *lazy* (§3.6): completed tasks are collected into the completion
queue and their dependents' counters are only decremented when the master
idles or needs resources, keeping release off the critical path.
"""
from __future__ import annotations

import random
from typing import Callable, Sequence

from repro.obs.tracker import NULL_TRACKER

from .deps import DependenceAnalyzer
from .graph import DescriptorPool, TaskDescriptor, TaskGraph, TaskState
from .mpb import MPBQueue

__all__ = ["MasterScheduler", "POLICIES"]


def _rr_policy(sched: "MasterScheduler", td: TaskDescriptor) -> Sequence[int]:
    """Round-robin over workers, starting after the last one used."""
    n = len(sched.queues)
    start = (sched._rr_last + 1) % n
    sched._rr_last = start
    return [(start + i) % n for i in range(n)]


def _locality_policy(sched: "MasterScheduler", td: TaskDescriptor) -> Sequence[int]:
    """Prefer the worker whose cache most recently produced one of this
    task's input blocks (tile-affinity; the paper's locality discussion in
    §4.1/§6 — tasks with good cache locality scale best)."""
    votes: dict[int, int] = {}
    for mode in td.args:
        if not mode.READS:
            continue
        for block in mode.region.block_ids:
            w = sched.block_last_worker.get(block)
            if w is not None:
                votes[w] = votes.get(w, 0) + 1
    order = sorted(votes, key=votes.get, reverse=True)
    rest = [w for w in _rr_policy(sched, td) if w not in votes]
    return order + rest


def _random_policy(sched: "MasterScheduler", td: TaskDescriptor) -> Sequence[int]:
    order = list(range(len(sched.queues)))
    sched._rng.shuffle(order)
    return order


POLICIES: dict[str, Callable] = {
    "round_robin": _rr_policy,
    "locality": _locality_policy,
    "random": _random_policy,
}

# the canonical choice list lives in api.SchedulingPolicy; this registry
# must implement exactly that list, no more, no less
from .api import SCHEDULING_POLICIES  # noqa: E402  (needs POLICIES above)

assert set(POLICIES) == set(SCHEDULING_POLICIES), \
    "scheduler.POLICIES drifted from api.SchedulingPolicy"


class MasterScheduler:
    """Drives the four task stages over a set of per-worker MPB queues."""

    obs = NULL_TRACKER     # set by TaskRuntime; channel = worker id

    def __init__(self, queues: list[MPBQueue], graph: TaskGraph,
                 pool: DescriptorPool, analyzer: DependenceAnalyzer,
                 policy: str = "round_robin", seed: int = 0):
        self.queues = queues
        self.graph = graph
        self.pool = pool
        self.analyzer = analyzer
        self.policy = POLICIES[policy]
        # sharded dependence manager: ready tasks park in per-home deques
        # owned by the managers (owner-computes); central path keeps the
        # single master-side ready queue
        self._ready_mgr = analyzer if hasattr(analyzer, "push_ready") \
            else None
        # sharded dependence manager: buffered release descriptors are
        # flushed at wave boundaries (end of release_all) — cached here
        # because release_all sits on the polling hot loop
        self._dep_flush = getattr(analyzer, "flush", None)
        self.block_last_worker: dict = {}
        self._rr_last = -1
        self._rng = random.Random(seed)
        # stats
        self.polling_rounds = 0
        self.tasks_scheduled = 0
        # live per-worker in-flight depth, maintained unconditionally
        # (the tracker's ``queue_depths()`` mirrors this only when a
        # tracker is attached); the serving admission controller reads
        # it to bound in-flight work without requiring observability on
        self._depths = [0] * len(queues)

    def queue_depths(self) -> dict[int, int]:
        """Current in-flight tasks per worker MPB ring (dispatched,
        not yet collected) — same shape the obs tracker reports."""
        return {w: d for w, d in enumerate(self._depths) if d}

    # -- running-mode scheduling (§3.4 first half) ---------------------------
    def schedule_running(self, td: TaskDescriptor) -> None:
        """Try exactly one worker (the policy's first choice); on rejection
        park the task in the local ready queue and return — the main program
        resumes immediately."""
        order = self.policy(self, td)
        wid = order[0]
        accepted, collected = self.queues[wid].try_put(td)
        if collected is not None:
            self._collect(collected)
        if accepted:
            self.tasks_scheduled += 1
            self._note_placement(td, wid)
            self._depths[wid] += 1
            if self.obs.enabled:
                self.obs.queue(wid, +1)
        else:
            self._park_ready(td)

    def _park_ready(self, td: TaskDescriptor, front: bool = False) -> None:
        """Park a ready task: in its home manager's deque under the
        sharded manager, else in the master's local ready queue."""
        if self._ready_mgr is not None:
            self._ready_mgr.push_ready(td, front=front)
        elif front:
            self.graph.ready.appendleft(td)
        else:
            self.graph.ready.append(td)

    # -- polling-mode scheduling (§3.4 second half) ----------------------------
    def schedule_polling(self, td: TaskDescriptor) -> bool:
        """Try every worker in policy order; if all queues are full, release
        one completed task and retry once (the paper releases and retries
        the *first* task)."""
        for attempt in range(2):
            for wid in self.policy(self, td):
                accepted, collected = self.queues[wid].try_put(td)
                if collected is not None:
                    self._collect(collected)
                if accepted:
                    self.tasks_scheduled += 1
                    self._note_placement(td, wid)
                    self._depths[wid] += 1
                    if self.obs.enabled:
                        self.obs.queue(wid, +1)
                    return True
            if attempt == 0:
                self.poll_workers()
                if not self.release_one():
                    # nothing completed yet; caller decides whether to spin
                    return False
        return False

    def _note_placement(self, td: TaskDescriptor, wid: int) -> None:
        for mode in td.outputs:
            for block in mode.region.block_ids:
                self.block_last_worker[block] = wid

    # -- polling-mode functions (i)-(iii) ----------------------------------------
    def drain_ready(self) -> None:
        """(i) schedule tasks from the ready queue(s).  Under the sharded
        dependence manager this drains the per-home deques round-robin
        (``pop_ready``); centrally it drains the master's local queue."""
        mgr = self._ready_mgr
        if mgr is not None:
            n = mgr.ready_count
            for _ in range(n):
                td = mgr.pop_ready()
                if td is None:
                    break
                if not self.schedule_polling(td):
                    mgr.push_ready(td, front=True)
                    break
            return
        n = len(self.graph.ready)
        for _ in range(n):
            if not self.graph.ready:
                break
            td = self.graph.ready.popleft()
            if not self.schedule_polling(td):
                self.graph.ready.appendleft(td)
                break

    def poll_workers(self) -> int:
        """(ii) discover descriptors marked completed; move them to the
        completion queue."""
        found = 0
        for q in self.queues:
            for td in q.collect_completed():
                self._collect(td)
                found += 1
        return found

    def _collect(self, td: TaskDescriptor) -> None:
        self.graph.mark_executed(td)
        self.graph.completion.append(td)
        # staged/sequential tds never went through an MPB ring (worker is
        # None); only host-dispatched tasks decrement a worker channel
        if td.worker is not None:
            self._depths[td.worker] -= 1
            if self.obs.enabled:
                self.obs.queue(td.worker, -1)

    def release_one(self) -> bool:
        """(iii) release one completed task's dependencies (lazy, §3.6)."""
        if not self.graph.completion:
            return False
        td = self.graph.completion.popleft()
        for ready in self.graph.release(td):
            self._park_ready(ready)
        self.analyzer.forget_completed(td)
        self.pool.release(td)
        return True

    def release_all(self) -> None:
        """Drain the completion queue, then flush the dependence
        manager's buffered release descriptors — the wave-boundary
        flush of the line batcher.  Grant arrival may be asynchronous
        under ``dep_pump="threaded"``, but the wave order stays pinned:
        admissions complete in spawn order before any task here was
        marked executed, so the release stream (and therefore the
        batcher's flush points) is identical across pump modes."""
        while self.release_one():
            pass
        if self._dep_flush is not None:
            self._dep_flush()

    # -- the polling loop itself --------------------------------------------------
    def polling_step(self) -> None:
        """One iteration of the master's polling mode."""
        self.polling_rounds += 1
        self.drain_ready()
        self.poll_workers()
        self.release_all()
