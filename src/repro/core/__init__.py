"""BDDT-SCC in JAX: block-level dynamic dependence analysis + task runtime.

The paper's primary contribution — an OmpSs-style task-parallel runtime for
non cache-coherent hardware — implemented as:

* :mod:`api`        — the OmpSs front-end: @task footprints, futures, config
* :mod:`blocks`     — the custom block allocator (BlockArray / Region / In-Out-InOut)
* :mod:`deps`       — block-level dynamic dependence analysis (BDDT)
* :mod:`depman`     — home-sharded dependence managers over MPB channels
* :mod:`graph`      — task descriptors, descriptor pool, ready/completion queues
* :mod:`mpb`        — message-passing-buffer SPSC descriptor rings
* :mod:`scheduler`  — the master's running/polling modes + lazy release
* :mod:`executor`   — sequential (oracle) / host (faithful) / staged (TPU) execution
* :mod:`sharded`    — home-aware mesh execution (owner-computes over the repro.dist mesh)
* :mod:`placement`  — memory-controller striping -> block-cyclic device placement
* :mod:`costmodel`  — SCC latency/contention model (Figs 3-4) + TPU roofline
* :mod:`sim`        — discrete-event simulation of the SCC runtime (Figs 5-7)
* :mod:`pipeline`   — pipeline-parallel schedules derived by dependence analysis
"""
from .api import (RuntimeConfig, RuntimeStats, TaskFuture, current_runtime,
                  task)
from .blocks import BlockArray, In, InOut, Out, Region
from .depman import ShardedDependenceManager
from .executor import Executor
from .runtime import TaskRuntime

__all__ = ["TaskRuntime", "BlockArray", "In", "Out", "InOut", "Region",
           "task", "TaskFuture", "RuntimeConfig", "RuntimeStats",
           "Executor", "ShardedDependenceManager", "current_runtime"]
