"""BDDT-SCC in JAX: block-level dynamic dependence analysis + task runtime.

The paper's primary contribution — an OmpSs-style task-parallel runtime for
non cache-coherent hardware — implemented as:

* :mod:`api`        — the OmpSs front-end: @task footprints, futures, config
* :mod:`blocks`     — the custom block allocator (BlockArray / Region / In-Out-InOut)
* :mod:`deps`       — block-level dynamic dependence analysis (BDDT)
* :mod:`depman`     — home-sharded dependence managers over MPB channels
* :mod:`graph`      — task descriptors, descriptor pool, ready/completion queues
* :mod:`mpb`        — message-passing-buffer SPSC descriptor rings
* :mod:`scheduler`  — the master's running/polling modes + lazy release
* :mod:`executor`   — sequential (oracle) / host (faithful) / staged (TPU) execution
* :mod:`sharded`    — home-aware mesh execution (owner-computes over the repro.dist mesh)
* :mod:`placement`  — memory-controller striping -> block-cyclic device placement
* :mod:`costmodel`  — SCC latency/contention model (Figs 3-4) + TPU roofline
* :mod:`sim`        — discrete-event simulation of the SCC runtime (Figs 5-7)
* :mod:`pipeline`   — pipeline-parallel schedules derived by dependence analysis
"""
from .api import (DEP_MANAGERS, DEP_PUMPS, EXECUTORS, KERNEL_BACKENDS,
                  PLACEMENTS, SCHEDULING_POLICIES, STATS_SCHEMA,
                  DepManagerKind, DepPumpKind, ExecutorKind, KernelBackend,
                  PlacementKind, RuntimeConfig, RuntimeStats,
                  SchedulingPolicy, TaskFuture, current_runtime, task,
                  wait_on)
from .blocks import (AccessMode, BlockArray, In, InOut, Out, Region,
                     coerce_mode)
from .depman import ShardedDependenceManager
from .executor import Executor
from .runtime import TaskRuntime

__all__ = [
    # entry points
    "TaskRuntime", "task", "wait_on", "current_runtime",
    # data + footprints
    "BlockArray", "Region", "AccessMode", "In", "Out", "InOut",
    "coerce_mode",
    # configuration + results
    "RuntimeConfig", "RuntimeStats", "STATS_SCHEMA", "TaskFuture",
    # typed configuration choices (one source for every stringly field)
    "ExecutorKind", "DepManagerKind", "DepPumpKind", "SchedulingPolicy",
    "PlacementKind", "KernelBackend", "EXECUTORS", "DEP_MANAGERS",
    "DEP_PUMPS", "SCHEDULING_POLICIES", "PLACEMENTS", "KERNEL_BACKENDS",
    # extension surfaces
    "Executor", "ShardedDependenceManager",
]
