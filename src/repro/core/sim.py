"""Discrete-event simulation of the BDDT-SCC runtime on the SCC.

Replays the exact runtime protocol of §3.3-§3.6 — master spawns with
dependence-analysis cost, running-mode single-attempt scheduling into
bounded MPB rings, polling mode at barriers, lazy collection and release —
against the calibrated hardware model of ``costmodel.py`` (hop-dependent
DRAM latency, per-MC contention, whole-L2 flush/invalidate).  Workloads are
task graphs annotated with per-task flops / bytes / block homes, generated
by ``benchmarks.workloads`` for the paper's five applications.

This is how the reproduction validates the paper's *findings* without SCC
silicon: Fig 5 (scalability curves and their saturation points), Fig 6
(idle / application / flush breakdowns growing with contention), Fig 7
(per-worker load balance), and the master-bottleneck onset.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from .costmodel import (SCCParams, core_core_hops, core_mc_hops,
                        master_core_choice, worker_order)
from .depman import grant_slots
from .executor import ExecutorBase
from .mpb import DESCRIPTORS_PER_LINE, lines_for

__all__ = ["SimTask", "SimResult", "SimExecutor", "FlopcountCost",
           "simulate", "sequential_time", "predict_dep_traffic"]


@dataclass
class SimTask:
    """One task of a workload graph."""
    tid: int
    flops: float
    mem_bytes: float
    homes: tuple[int, ...]            # MCs serving this task's blocks
    deps: tuple[int, ...] = ()        # tids this task waits for
    n_blocks: int = 1                 # footprint size (dep-analysis cost)
    # actual footprint bytes behind each MC in ``homes`` (same order).
    # None = split ``mem_bytes`` evenly (the synthetic-workload default);
    # SimExecutor fills it from real task footprints so the contention
    # model charges each controller for the bytes it really serves — the
    # residency semantics the executors measure, consumed by the DES.
    home_bytes: tuple[float, ...] | None = None
    # footprint blocks behind each home in ``homes`` (same order).  None =
    # split ``n_blocks`` evenly.  Under sharded dependence management the
    # per-home managers walk their slices in parallel, so the spawn charge
    # is the *max* per-manager walk, not the sum — this carries the split.
    home_blocks: tuple[int, ...] | None = None
    # kernel_backend="pallas": this task runs inside a fused wave kernel.
    # ``onchip_bytes`` is the slice of ``mem_bytes`` the fused grid keeps
    # in on-chip memory (the write-back footprint staged MPB-style between
    # grid steps): the DES charges it at MPB line cost instead of
    # contended DRAM, and skips the per-task whole-L2 flush — one wave,
    # one kernel, one flush (amortized to ~0 per task, §3.2).
    fused: bool = False
    onchip_bytes: float = 0.0

    # simulation state (reset per run)
    deps_remaining: int = 0
    dependents: list = field(default_factory=list)


@dataclass
class WorkerState:
    core: int
    mc_hops: list[int]
    queue: list = field(default_factory=list)   # FIFO of queued tasks
    running: object = None
    free_at: float = 0.0
    busy_s: float = 0.0
    flush_s: float = 0.0
    tasks_run: int = 0
    inflight: int = 0


@dataclass
class SimResult:
    total_s: float
    worker_busy_s: list[float]
    worker_flush_s: list[float]
    worker_idle_s: list[float]
    worker_tasks: list[int]
    master_busy_s: float
    tasks: int

    @property
    def breakdown(self) -> dict:
        return {
            "app_s": sum(self.worker_busy_s),
            "flush_s": sum(self.worker_flush_s),
            "idle_s": sum(self.worker_idle_s),
        }


class FlopcountCost:
    """The default ``sim_cost_fn``: exact jaxpr flop/byte accounting of the
    task *body* (``launch/flopcount.py``) combined with the descriptor's
    declared footprint.

    The task function is traced once per (function, input-structure) pair
    on abstract arguments shaped like its READS regions and firstprivate
    values; walking the jaxpr gives exact ``dot_general`` / FFT / reduction
    flops with every loop multiplier applied.  DRAM bytes are the larger of

    * the jaxpr's fusion-adjusted byte estimate (intermediates that
      materialize at dot/reduce boundaries), and
    * the footprint traffic a non-coherent SCC core cannot avoid: every
      READS region fetched from DRAM plus every WRITES region flushed back
      (an ``inout`` region counts for both).

    Results are cached on input *structure* (shapes/dtypes, never values),
    so per-task cost still varies with footprint size but tracing happens
    once per kernel shape.  Bodies that cannot be abstractly traced (rare:
    value-dependent Python control flow) fall back to the old
    footprint-derived estimate of :meth:`SimExecutor._footprint_cost`.
    """

    def __init__(self):
        self._cache: dict[tuple, tuple[float, float] | None] = {}

    @staticmethod
    def _abstract_args(td) -> list:
        import jax

        args = [jax.ShapeDtypeStruct(m.region.shape,
                                     np.dtype(m.region.array.dtype))
                for m in td.args if m.READS]
        for v in td.values:
            dt = jax.dtypes.canonicalize_dtype(np.result_type(v))
            args.append(jax.ShapeDtypeStruct(np.shape(v), dt))
        return args

    def _key(self, td) -> tuple:
        parts: list = [td.fn]
        for m in td.args:
            parts.append((type(m).__name__, m.region.shape,
                          str(m.region.array.dtype)))
        for v in td.values:
            parts.append((np.shape(v), str(np.result_type(v))))
        return tuple(parts)

    def __call__(self, td) -> tuple[float, float]:
        key = self._key(td)
        counted = self._cache.get(key, False)
        if counted is False:
            try:
                from repro.launch.flopcount import count_step
                c = count_step(td.fn, *self._abstract_args(td))
                counted = (float(c["flops"]), float(c["bytes"]))
            except Exception:
                counted = None           # untraceable body
            self._cache[key] = counted
        if counted is None:
            return SimExecutor._footprint_cost(td)
        flops, jaxpr_bytes = counted
        read_b = sum(m.region.nbytes for m in td.args if m.READS)
        write_b = sum(m.region.nbytes for m in td.args if m.WRITES)
        return flops, max(jaxpr_bytes, float(read_b + write_b))


class SimExecutor(ExecutorBase):
    """The DES behind the :class:`~repro.core.executor.Executor` protocol.

    ``TaskRuntime(executor="sim")`` runs a *real task program* —
    footprints, dependence analysis, descriptor pool and all — but
    instead of executing task bodies, the barrier replays the accumulated
    DAG through :func:`simulate` on the calibrated SCC cost model.  Task
    outputs are **not** computed (timing-only); the predicted makespan
    lands in ``RuntimeStats.predicted_total_s`` and the full
    :class:`SimResult` in :attr:`last_result`.

    Per-task costs default to :class:`FlopcountCost` — exact jaxpr flop
    and byte accounting of the traced kernel body plus the footprint's
    unavoidable DRAM traffic; pass ``sim_cost_fn`` in RuntimeConfig to
    override, or ``sim_params`` to run on calibrated
    :class:`~repro.core.costmodel.SCCParams`.
    """

    kind = "sim"

    def __init__(self, graph, scheduler, *, n_workers: int = 4,
                 mpb_slots: int = 16, cost_fn=None,
                 params: SCCParams | None = None,
                 dep_managers: int | None = None,
                 dep_batch_lines: int = 1,
                 kernel_backend: str = "xla"):
        self.graph = graph
        self.scheduler = scheduler
        self.n_workers = n_workers
        self.mpb_slots = mpb_slots
        self.cost_fn = cost_fn or FlopcountCost()
        self.params = params or SCCParams()
        # RuntimeConfig.dep_manager="sharded": charge spawns as manager
        # message traffic + parallel per-home walks instead of one
        # master-side walk (None = the central §3.3 cost); batch_lines>1
        # amortizes the per-descriptor line charge (line packing)
        self.dep_managers = dep_managers
        self.dep_batch_lines = dep_batch_lines
        # RuntimeConfig.kernel_backend="pallas": predict which waves the
        # wave-kernel layer would fuse (same grouping + eligibility the
        # staged executor uses) and charge their write-back traffic at
        # on-chip rather than DRAM cost.  Counters mirror the real
        # executors' RuntimeStats fields, here as predictions.
        self.kernel_backend = kernel_backend
        self.kernel_dispatches = 0
        self.kernel_fallbacks = 0
        self.pending = []
        self.last_result: SimResult | None = None
        # fragments compose sequentially (each sync point serializes the
        # master), so the program's predicted makespan is their sum
        self.predicted_total_s = 0.0
        # residency prediction: cross-home block fetches the footprints
        # imply under owner-computes (the DES never stages data — 32-byte
        # descriptors move through the MPBs, blocks stay at their homes)
        self.predicted_tile_moves = 0

    @staticmethod
    def _footprint_cost(td) -> tuple[float, float]:
        """Footprint-only estimate: bytes = the whole footprint, flops =
        2 x elements touched (a BLAS-1-ish density).  This is the
        fallback :class:`FlopcountCost` uses for bodies that cannot be
        abstractly traced.  A custom cost_fn receives the full descriptor
        — including ``td.values``, the firstprivate parameters — so
        per-task costs can depend on index values (e.g. trailing-submatrix
        size in a factorization)."""
        total_bytes = sum(m.region.nbytes for m in td.args)
        elems = sum(int(np.prod(m.region.shape)) for m in td.args)
        return 2.0 * elems, float(total_bytes)

    def _predict_fused(self) -> set[int]:
        """Replay the staged executor's wavefront layering + grouping over
        the pending batch and ask the wave-kernel eligibility which groups
        would fuse — the DES never executes bodies, so fusion here is a
        schedule-level prediction using the *same* shared contract
        (``wavekernel.group_signature`` / ``wavekernel.eligibility``) the
        real dispatch uses, and can therefore not drift from it."""
        from collections import defaultdict

        from . import wavekernel

        fused: set[int] = set()
        indeg = {td: td.deps_remaining for td in self.pending}
        frontier = [td for td in self.pending if indeg[td] == 0]
        while frontier:
            frontier.sort(key=lambda t: t.spawn_order)
            groups = defaultdict(list)
            for td in frontier:
                groups[wavekernel.group_signature(td)].append(td)
            for g in groups.values():
                if wavekernel.eligibility(g) is None:
                    self.kernel_dispatches += 1
                    fused.update(t.tid for t in g)
                else:
                    self.kernel_fallbacks += 1
            nxt = []
            for td in frontier:
                for dep in td.dependents:
                    if dep in indeg:
                        indeg[dep] -= 1
                        if indeg[dep] == 0:
                            nxt.append(dep)
            frontier = nxt
        return fused

    def _to_sim(self, td, batch_tids: set[int],
                fused_tids: set[int] = frozenset()) -> SimTask:
        flops, mem = self.cost_fn(td)
        owner = 0
        for m in td.args:
            if m.WRITES:
                owner = m.region.array.home.get(m.region.tile_indices[0], 0)
                break
        per_home: dict[int, float] = {}
        per_home_blocks: dict[int, int] = {}
        n_blocks = 0
        for m in td.args:
            n_blocks += len(m.region.block_ids)
            block_bytes = m.region.nbytes / max(len(m.region.tile_indices), 1)
            for idx in m.region.tile_indices:
                h = m.region.array.home.get(idx, 0)
                per_home[h] = per_home.get(h, 0.0) + block_bytes
                per_home_blocks[h] = per_home_blocks.get(h, 0) + 1
                if m.READS and h != owner:
                    self.predicted_tile_moves += 1
        homes = tuple(sorted(per_home)) or (0,)
        fused = td.tid in fused_tids
        # the fused grid stages the write-back footprint on-chip: outputs
        # stream between grid steps instead of flushing to DRAM per task
        onchip = (float(sum(m.region.nbytes for m in td.args if m.WRITES))
                  if fused else 0.0)
        return SimTask(
            tid=td.tid, flops=float(flops), mem_bytes=float(mem),
            homes=homes,
            deps=tuple(p.tid for p in td.preds if p.tid in batch_tids),
            n_blocks=max(n_blocks, 1),
            home_bytes=tuple(per_home.get(h, 0.0) for h in homes) or None,
            home_blocks=tuple(per_home_blocks.get(h, 0)
                              for h in homes) or None,
            fused=fused, onchip_bytes=min(onchip, float(mem)))

    def on_spawn(self, td, ready: bool) -> None:
        self.pending.append(td)

    def barrier(self) -> None:
        if not self.pending:
            return
        batch_tids = {td.tid for td in self.pending}
        fused_tids = (self._predict_fused()
                      if self.kernel_backend == "pallas" else frozenset())
        sim_tasks = [self._to_sim(td, batch_tids, fused_tids)
                     for td in self.pending]
        self.last_result = simulate(sim_tasks, self.n_workers, self.params,
                                    mpb_slots=self.mpb_slots,
                                    dep_managers=self.dep_managers,
                                    dep_batch_lines=self.dep_batch_lines)
        self.predicted_total_s += self.last_result.total_s
        if self.obs.enabled:
            # predicted (parallel DES makespan) vs configured cost (the
            # same tasks serial on the master, no contention/flushes) —
            # the §6 speedup the tracker records per fragment
            self.obs.emit("sim_predict", tasks=len(sim_tasks),
                          predicted_s=self.last_result.total_s,
                          sequential_s=sequential_time(sim_tasks,
                                                       self.params))
        for td in self.pending:
            self.scheduler._collect(td)
        self.scheduler.release_all()
        self.pending.clear()


def sequential_time(tasks: list[SimTask], p: SCCParams,
                    master: int | None = None) -> float:
    """The paper's baseline: the original program on the master core, all
    memory served by the nearest controller, no contention, no flushes."""
    master = master if master is not None else master_core_choice()
    near = min(range(4), key=lambda m: core_mc_hops(master, m))
    h = core_mc_hops(master, near)
    t = 0.0
    for task in tasks:
        t += p.compute_time_s(task.flops)
        t += p.mem_time_s(task.mem_bytes, h, concurrent=1)
    return t


def simulate(tasks: list[SimTask], n_workers: int,
             p: SCCParams = SCCParams(), *, mpb_slots: int = 16,
             placement_aware: bool = True,
             dep_managers: int | None = None,
             dep_batch_lines: int = 1) -> SimResult:
    """Run the master/worker protocol over the task graph.

    ``dep_managers`` switches the spawn/release charges to sharded
    dependence management: N per-home managers (manager ``m`` sits at MC
    ``m % 4``), each walking its slice of the footprint concurrently.  A
    spawn then costs the base initiation plus one dep_query/dep_grant
    round-trip per involved manager plus the *max* per-manager metadata
    walk (they overlap — the distributed-manager win); a release adds one
    message per involved manager.  ``None`` is the paper's central §3.3
    walk on the master.

    ``dep_batch_lines`` mirrors ``RuntimeConfig.dep_batch_lines``: at 1
    every descriptor crosses the mesh in its own 32-byte MPB line (the
    pre-batching wire behavior, one ``mpb_write_s`` per message); above 1
    the master packs ``DESCRIPTORS_PER_LINE`` descriptors per line, so
    the steady-state per-descriptor charge amortizes to
    ``1/DESCRIPTORS_PER_LINE`` of a line write — the same line-packing
    the measured runtime reports as ``dep_lines < dep_messages``.
    """
    master = master_core_choice()
    cores = worker_order(master)[:n_workers]
    workers = [WorkerState(core=c,
                           mc_hops=[core_mc_hops(c, m) for m in range(4)])
               for c in cores]
    mpb_hops = [core_core_hops(master, c) for c in cores]

    # reset graph state
    by_id = {t.tid: t for t in tasks}
    for t in tasks:
        t.deps_remaining = len(t.deps)
        t.dependents = []
    for t in tasks:
        for d in t.deps:
            by_id[d].dependents.append(t)

    # per-MC load: sum of memory-boundedness fractions of active tasks
    # (a compute-bound task barely contends; Fig 4's hammering cores have
    # fraction ~1)
    mc_active = [0.0, 0.0, 0.0, 0.0]
    mem_frac: dict[int, float] = {}

    # event heap: (finish_time, seq, worker_idx, task)
    events: list = []
    seq = 0

    ready: list[SimTask] = [t for t in tasks if t.deps_remaining == 0]
    pending_spawn = list(tasks)       # program order
    spawned = set()
    completion: list[SimTask] = []
    executed: dict[int, float] = {}   # tid -> finish time
    collected: set[int] = set()

    master_t = 0.0
    rr = 0

    def mc_shares(task: SimTask) -> list[float]:
        """Per-MC byte shares, aligned with ``task.homes``: the measured
        footprint split when the task carries one, an even split else."""
        if task.home_bytes and sum(task.home_bytes) > 0:
            total = sum(task.home_bytes)
            return [task.mem_bytes * b / total for b in task.home_bytes]
        share = task.mem_bytes / max(len(task.homes), 1)
        return [share] * len(task.homes)

    def exec_time(w: WorkerState, task: SimTask) -> tuple[float, float]:
        comp = p.compute_time_s(task.flops)
        shares = mc_shares(task)
        # fused wave kernels (kernel_backend="pallas") keep the task's
        # write-back slice on-chip: only the remaining DRAM fraction
        # contends at the controllers; the on-chip slice moves at MPB
        # line cost (local, hop-free, contention-free — §3.2)
        dram = 1.0
        onchip_s = 0.0
        if task.fused and task.mem_bytes > 0 and task.onchip_bytes > 0:
            dram = (task.mem_bytes - task.onchip_bytes) / task.mem_bytes
            onchip_s = (task.onchip_bytes / p.cacheline_bytes) \
                * p.mpb_write_s(0)
        mem0 = sum(p.mem_time_s(sh * dram, w.mc_hops[mc], concurrent=1)
                   for sh, mc in zip(shares, task.homes))
        f = mem0 / max(mem0 + comp + onchip_s, 1e-12)
        mem_frac[task.tid] = f
        mem = 0.0
        for sh, mc in zip(shares, task.homes):
            conc = 1.0 + max(mc_active[mc], 0.0)   # others + me
            mem += p.mem_time_s(sh * dram, w.mc_hops[mc], concurrent=conc)
        # one fused kernel flushes once per wave, not once per task: the
        # per-task whole-L2 flush/invalidate charge disappears
        fl = (0.0 if task.fused
              else p.seconds(p.flush_cycles + p.invalidate_cycles))
        return comp + mem + onchip_s, fl

    def begin(widx: int, task: SimTask, t0: float):
        """Worker starts executing: contention is sampled NOW (queued
        descriptors in the MPB don't touch memory)."""
        nonlocal seq
        w = workers[widx]
        start = max(w.free_at, t0)
        dur, fl = exec_time(w, task)
        for mc in task.homes:
            mc_active[mc] += mem_frac[task.tid]
        w.running = task
        w.free_at = start + dur + fl
        w.busy_s += dur
        w.flush_s += fl
        w.tasks_run += 1
        seq += 1
        heapq.heappush(events, (w.free_at, seq, widx, task))

    def enqueue(widx: int, task: SimTask, t0: float):
        w = workers[widx]
        w.inflight += 1
        if w.running is None:
            begin(widx, task, t0)
        else:
            w.queue.append(task)

    def try_schedule(task: SimTask, t: float, single_attempt: bool) -> bool:
        """Master appends to a worker's MPB ring (§3.4)."""
        nonlocal rr, master_t
        order = range(len(workers))
        if placement_aware:
            # prefer emptier queues, then closer workers (hop cost)
            order = sorted(order, key=lambda i: (workers[i].inflight,
                                                 mpb_hops[i]))
        else:
            order = [(rr + i) % len(workers) for i in range(len(workers))]
            rr += 1
        for widx in order:
            w = workers[widx]
            if w.inflight < mpb_slots:
                master_t += p.seconds(p.schedule_cycles) + \
                    p.mpb_write_s(mpb_hops[widx])
                enqueue(widx, task, master_t)
                return True
            master_t += p.seconds(p.poll_cycles)   # slot check only
            if single_attempt:
                return False
        return False

    def collect_finished(t_now: float):
        """Pop all finish events up to t_now; mark slots completed."""
        while events and events[0][0] <= t_now:
            ft, _, widx, task = heapq.heappop(events)
            w = workers[widx]
            for mc in task.homes:
                mc_active[mc] -= mem_frac[task.tid]
            w.running = None
            if w.queue:
                begin(widx, w.queue.pop(0), ft)
            w.inflight -= 1
            executed[task.tid] = ft
            completion.append(task)

    def manager_slices(task: SimTask) -> dict[int, float]:
        """Per-manager footprint block counts for one task (manager =
        home % dep_managers; even split when the task carries no
        per-home block counts)."""
        slices: dict[int, float] = {}
        blocks = task.home_blocks \
            if task.home_blocks and len(task.home_blocks) == len(task.homes) \
            else None
        for i, h in enumerate(task.homes):
            m = h % dep_managers
            b = blocks[i] if blocks else task.n_blocks / len(task.homes)
            slices[m] = slices.get(m, 0.0) + b
        return slices

    def dep_line_s(m: int, slots: int = 1) -> float:
        """One direction of manager ``m``'s descriptor traffic, charged
        per 32-byte MPB line.  Unbatched (``dep_batch_lines <= 1``) a
        descriptor rides alone — ``lines_for(slots)`` full line writes,
        exactly the pre-batching charge.  Batched, envelopes pack
        ``DESCRIPTORS_PER_LINE`` descriptors per line, so the amortized
        steady-state charge is ``slots/DESCRIPTORS_PER_LINE`` lines."""
        hops = core_mc_hops(master, m % 4)
        if dep_batch_lines <= 1:
            return lines_for(slots) * p.mpb_write_s(hops)
        return (slots / DESCRIPTORS_PER_LINE) * p.mpb_write_s(hops)

    def spawn_cost(task: SimTask) -> float:
        """Master-side initiation charge (§3.3): central = base + one
        walk over the whole footprint; sharded = base + one MPB
        round-trip per involved manager + the slowest per-manager walk
        (the walks overlap across managers)."""
        if not dep_managers:
            return p.seconds(p.spawn_base_cycles +
                             p.dep_block_cycles * task.n_blocks)
        slices = manager_slices(task)
        t = p.seconds(p.spawn_base_cycles)
        for m in slices:
            # dep_query out + dep_grant back, each one descriptor slot
            t += 2.0 * dep_line_s(m)
        t += p.seconds(p.dep_block_cycles * max(slices.values()))
        return t

    def release_all(t: float):
        nonlocal master_t
        while completion:
            task = completion.pop()
            master_t += p.seconds(p.release_cycles)
            if dep_managers:
                # completion fan-out: one release descriptor per manager
                for m in manager_slices(task):
                    master_t += dep_line_s(m)
            for dep in task.dependents:
                dep.deps_remaining -= 1
                if dep.deps_remaining == 0:
                    ready.append(dep)

    # ---- phase 1: main program spawns every task (running mode, §3.4):
    # one scheduling attempt for the newly spawned task only; on rejection
    # it joins the local ready queue and the main program continues --------
    ready.clear()
    for task in pending_spawn:
        master_t += spawn_cost(task)
        spawned.add(task.tid)
        collect_finished(master_t)
        if task.deps_remaining == 0:
            if not try_schedule(task, master_t, single_attempt=True):
                ready.append(task)

    # ---- phase 2: barrier — polling mode (§3.4 / §3.6) ---------------------
    n_total = len(tasks)
    while len(executed) < n_total or ready or completion:
        progressed = False
        collect_finished(master_t)
        release_all(master_t)
        still = []
        for r in ready:
            master_t += p.seconds(p.poll_cycles)
            if try_schedule(r, master_t, single_attempt=False):
                progressed = True
            else:
                still.append(r)
        ready[:] = still
        if not progressed:
            if events:
                # idle until the next completion
                master_t = max(master_t, events[0][0])
                collect_finished(master_t)
                release_all(master_t)
            elif not ready:
                break
        master_t += p.seconds(p.poll_cycles * len(workers))

    total = max([master_t] + [w.free_at for w in workers])
    idle = [max(total - w.busy_s - w.flush_s, 0.0) for w in workers]
    return SimResult(
        total_s=total,
        worker_busy_s=[w.busy_s for w in workers],
        worker_flush_s=[w.flush_s for w in workers],
        worker_idle_s=idle,
        worker_tasks=[w.tasks_run for w in workers],
        master_busy_s=master_t,
        tasks=len(tasks),
    )


def predict_dep_traffic(events: list[tuple], batch_lines: int,
                        grant_deps: dict[int, int] | None = None) -> dict:
    """Replay the descriptor-line batcher's flush policy over a recorded
    logical stream and predict the wire traffic it produces.

    ``events`` is a ``ShardedDependenceManager(record_traffic=True)``
    ``traffic_log``: ``("desc", home, kind, slots, qid)`` per logical
    descriptor posted (``qid`` numbers queries positionally, ``None``
    for releases), ``("sync",)`` per flush-all point (barriers, wave
    boundaries, ``admit_finish``), and ``("flush", home)`` per *measured*
    envelope — which this replay deliberately ignores: it re-derives
    every flush from the policy alone (capacity ``batch_lines *
    DESCRIPTORS_PER_LINE`` slots, flush-per-descriptor at
    ``batch_lines <= 1``, flush-all at syncs), which is what makes the
    returned counts a prediction that can *disagree* with the measured
    ``dep_batches``/``dep_lines`` if either side drifts.

    ``grant_deps`` is the manager's ``traffic_deps`` (query id -> deps in
    its grant); each query-carrying envelope is answered by exactly one
    grant envelope whose slots are ``grant_slots`` per query.

    The flush policy depends only on the logical stream and the config —
    never on consumer timing — so the prediction must reconcile exactly
    for sync *and* threaded pumps; ``tests/test_sim.py`` and the
    spawn-throughput benchmark assert it does.
    """
    grant_deps = grant_deps or {}
    cap = max(1, batch_lines) * DESCRIPTORS_PER_LINE
    buf_slots: dict[int, int] = {}       # home -> buffered slots
    buf_qids: dict[int, list] = {}       # home -> queries in envelope
    out = {"batches_posted": 0, "lines_posted": 0,
           "batches_granted": 0, "lines_granted": 0}

    def flush(home: int) -> None:
        slots = buf_slots.get(home, 0)
        if not slots:
            return
        out["batches_posted"] += 1
        out["lines_posted"] += lines_for(slots)
        qids = buf_qids.get(home)
        if qids:
            gslots = sum(grant_slots(grant_deps.get(q, 0)) for q in qids)
            out["batches_granted"] += 1
            out["lines_granted"] += lines_for(gslots)
        buf_slots[home] = 0
        buf_qids[home] = []

    for ev in events:
        if ev[0] == "desc":
            _, home, kind, slots, qid = ev
            if buf_slots.get(home, 0) and \
                    buf_slots[home] + slots > cap:
                flush(home)
            buf_slots[home] = buf_slots.get(home, 0) + slots
            if kind == "dep_query":
                buf_qids.setdefault(home, []).append(qid)
            if batch_lines <= 1:
                flush(home)
        elif ev[0] == "sync":
            for home in list(buf_slots):
                flush(home)
    for home in list(buf_slots):         # stream ended mid-envelope
        flush(home)
    out["dep_batches"] = out["batches_posted"] + out["batches_granted"]
    out["dep_lines"] = out["lines_posted"] + out["lines_granted"]
    return out
