"""Home-sharded dependence management: per-home managers over MPB channels.

The paper keeps dependence analysis on one master core and pays for it in
master-side spawn cost (§3.3, §5); the related work attacks exactly that
bottleneck by distributing the task manager (Bosch et al., *Asynchronous
Runtime with Distributed Manager*) and by hierarchical dependency-aware
scheduling (Lyberis et al., *Myrmics*).  This module is that refactor:
:class:`ShardedDependenceManager` splits the global
:class:`~repro.core.deps.DependenceAnalyzer` into N :class:`HomeManager` s
— one per block home, the same ``placement.device_assignment`` regions
``DeviceTileStore`` already uses — each owning the block metadata for its
home and admitting the slice of a task's footprint that touches its
region.

Transport is paper-faithful, in two layers:

* **Logical messages** — ``dep_query`` (master -> manager: one per-home
  footprint slice), ``dep_grant`` (manager -> master: the predecessors
  found) and ``release`` (master -> manager: completion fan-out).  These
  are what ``dep_messages`` counts and what the obs layer's ``dep_msg``
  events record — one per logical descriptor, independent of batching.
* **Envelopes on the wire** — the way the paper packs several 16-byte
  descriptors per 32-byte MPB line (§3.2), the master coalesces the
  logical descriptors bound for one home into multi-descriptor
  :class:`DepMessage` envelopes of up to ``dep_batch_lines`` MPB lines.
  An envelope flushes when it fills, at every blocking sync point, and
  at wave boundaries (``MasterScheduler.release_all`` /
  :meth:`ShardedDependenceManager.flush`); a manager answers each
  query-carrying envelope with exactly one grant envelope.  Envelope
  boundaries are decided by the master from the logical stream and the
  configuration alone — never by consumer timing — so the
  ``dep_batches`` / ``dep_lines`` counters are deterministic and
  bit-equal between the sync and threaded pump modes (``sim.py``'s
  ``predict_dep_traffic`` replays the same policy and must agree).

Pumping comes in two modes (``RuntimeConfig.dep_pump``):

* ``"sync"`` — the master services manager inboxes inline at each
  blocking sync point, through the same single non-reentrant
  :meth:`~ShardedDependenceManager._service` loop the threads run.  A
  send under backpressure never services mid-send; it drains grants and
  lets the consumer run (the historical ``_post``-pumps-inside-drain
  reentrancy hazard is structurally gone).
* ``"threaded"`` — each home's manager runs on a pump worker thread
  (homes map ``home % n_threads``); the master is a pure producer that
  posts envelopes and drains grant rings, never executing manager logic
  inline.  Admission is *split-phase*: :meth:`analyze_begin` posts the
  footprint slices, :meth:`admit_finish` collects completed admissions
  in spawn order; the blocking :meth:`analyze` is begin+finish of one
  task.  Quiescing (:meth:`quiesce`) flushes every buffer and waits
  until each manager has consumed exactly the envelopes the master
  posted and every grant is absorbed; :meth:`shutdown` quiesces, stops
  and joins the threads.  Grant-ring overflow still raises (never
  drops): the master drains a home's grants before every post to it, so
  outstanding grant envelopes never exceed the ring depth in a correct
  run, and the manager-side raise is the protocol tripwire.

Determinism is unchanged from the sync path: ``TaskDescriptor.state``
transitions (the ``is_complete`` reads the managers filter on) happen
only on the master, and the master never lets a transition overlap an
in-flight query — blocking callers are blocked, the split-phase driver
retires tasks only after ``admit_finish`` drained every grant.  Per-home
envelope order is master post order, so manager metadata evolves
identically run to run; the grant union is a set, insensitive to arrival
order.  The determinism pins in ``tests/test_depman.py`` and the 60-seed
differential replay in ``tests/test_differential.py`` hold central,
sharded-sync and sharded-threaded to identical schedules, numerics and
dependence counts.

Readiness is sharded too: the manager keeps one ready deque per home
(owner-computes — a task parks at the home of its first output block),
``MasterScheduler.drain_ready`` round-robins over them, and the staged
wave builder consumes the per-home ready sets level by level.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterable

from repro.obs.tracker import NULL_TRACKER

from .blocks import coerce_mode
from .deps import BlockId
from .mpb import DESCRIPTORS_PER_LINE, MPBChannel, lines_for

if TYPE_CHECKING:  # pragma: no cover
    from .graph import TaskDescriptor

__all__ = ["DepMessage", "HomeManager", "ShardedDependenceManager",
           "grant_slots"]

_MSG_KINDS = ("dep_query", "dep_grant", "release")

#: predecessor task ids packed per 16-byte grant descriptor (one header
#: descriptor carries the task correlation; ids pack 4 per slot after)
GRANT_IDS_PER_SLOT = 4


def grant_slots(n_deps: int) -> int:
    """16-byte descriptor slots of one ``dep_grant`` payload: a header
    naming the admitted task plus ``n_deps`` predecessor ids packed
    :data:`GRANT_IDS_PER_SLOT` per slot."""
    return 1 + (n_deps + GRANT_IDS_PER_SLOT - 1) // GRANT_IDS_PER_SLOT


@dataclass(slots=True)
class DepMessage:
    """One envelope on an MPB ring: a batch of packed descriptors.

    * ``dep_batch`` (master -> manager): ``payload`` is a list of
      logical descriptors ``(kind, task, items)`` with ``kind`` in
      ``("dep_query", "release")`` and ``items`` the per-home region
      runs of ``(reads, writes, blocks)``.
    * ``dep_grant`` (manager -> master): ``payload`` is a list of
      ``(task, deps)`` pairs — one per query descriptor of the envelope
      being answered (a manager replies once per query-carrying
      envelope).
    """
    kind: str
    home: int
    task: "TaskDescriptor | None"
    payload: object = None


class _Pending:
    """Master-side split-phase admission record: grants still owed."""

    __slots__ = ("task", "remaining", "deps")

    def __init__(self, task: "TaskDescriptor", remaining: int):
        self.task = task
        self.remaining = remaining
        self.deps: set = set()


class HomeManager:
    """One home's dependence manager: owns the block metadata for every
    block homed in its region and admits footprint slices independently.

    The metadata is the BDDT per-block ordering state (last writer +
    readers since that write, §3.3) kept as two plain dicts — leaner
    than the central analyzer's per-block objects, which is where the
    sharded admission path wins back its messaging overhead.

    Under ``dep_pump="threaded"`` every mutating method runs on the
    home's single pump thread (the counters below are single-writer);
    the master only reads, and only after :meth:`ShardedDependenceManager.quiesce`.
    """

    __slots__ = ("home", "_writer", "_readers", "deps_found",
                 "admissions", "ready", "processed", "busy_s")

    def __init__(self, home: int):
        self.home = home
        self._writer: dict[BlockId, "TaskDescriptor"] = {}
        self._readers: dict[BlockId, list["TaskDescriptor"]] = {}
        self.deps_found = 0             # dependences this manager granted
        self.admissions = 0             # footprint slices admitted
        self.processed = 0              # envelopes consumed (quiesce bound)
        self.busy_s = 0.0               # wall seconds spent servicing
        # per-home ready deque (owner-computes): what drain_ready and the
        # staged wave builder consume
        self.ready: deque["TaskDescriptor"] = deque()

    @property
    def live_blocks(self) -> int:
        """Blocks with live ordering state (leak check surface)."""
        return len(self._writer) + sum(1 for k in self._readers
                                       if k not in self._writer)

    def admit(self, task: "TaskDescriptor",
              items: list) -> set["TaskDescriptor"]:
        """Process one ``dep_query``: the fused collect-then-publish walk
        over this home's slice.  Each region run is visited in argument
        order, so a block touched by several modes of one task sees the
        same sequence of states the central analyzer's two passes produce
        (self-dependences are filtered exactly like the central walk)."""
        writer = self._writer
        readers = self._readers
        deps: set[TaskDescriptor] = set()
        add = deps.add
        wget = writer.get
        rget = readers.get
        for r, w, blocks in items:
            if w:
                for block in blocks:
                    lw = wget(block)
                    if lw is not None and lw is not task \
                            and not lw.is_complete:
                        add(lw)                      # RAW / WAW
                    rl = rget(block)
                    if rl is not None:
                        for t in rl:
                            if t is not task and not t.is_complete:
                                add(t)               # WAR
                        del readers[block]
                    writer[block] = task
            else:
                for block in blocks:
                    lw = wget(block)
                    if lw is not None and lw is not task \
                            and not lw.is_complete:
                        add(lw)                      # RAW
                    rl = rget(block)
                    if rl is None:
                        readers[block] = [task]
                    elif task not in rl:
                        rl.append(task)
        self.admissions += 1
        self.deps_found += len(deps)
        return deps

    def sync(self, blocks: Iterable[BlockId],
             writers_only: bool) -> set["TaskDescriptor"]:
        """The ``tasks_touching`` slice for this home (wait_on support)."""
        found: set[TaskDescriptor] = set()
        for block in blocks:
            w = self._writer.get(block)
            if w is not None and not w.is_complete:
                found.add(w)
            if not writers_only:
                for r in self._readers.get(block, ()):
                    if not r.is_complete:
                        found.add(r)
        return found

    def forget(self, task: "TaskDescriptor", items: list) -> None:
        """Process one ``release``: drop the task's references so block
        state stays O(live tasks) — entries with no live writer and no
        live readers are deleted outright."""
        writer = self._writer
        readers = self._readers
        for _r, _w, blocks in items:
            for block in blocks:
                if writer.get(block) is task:
                    del writer[block]
                rl = readers.get(block)
                if rl is not None:
                    try:
                        rl.remove(task)
                    except ValueError:
                        pass
                    if not rl:
                        del readers[block]


class _PumpWorker(threading.Thread):
    """One pump thread servicing a fixed set of homes.

    Runs the shared :meth:`ShardedDependenceManager._service` loop over
    its homes until stopped; parks on its wake event when every inbox is
    empty (the master sets the event after each post).  Exceptions are
    handed to the master through ``parent._pump_errors`` — the master
    re-raises at its next wait point instead of hanging."""

    def __init__(self, parent: "ShardedDependenceManager",
                 homes: list[int], idx: int):
        super().__init__(name=f"dep-pump-{idx}", daemon=True)
        self.parent = parent
        self.homes = homes
        self.wake = threading.Event()
        self.idle_waits = 0

    def run(self) -> None:  # pragma: no cover - exercised via runtime
        parent = self.parent
        homes = self.homes
        inbox = parent.inbox
        stop = parent._stop
        try:
            while True:
                busy = False
                for h in homes:
                    busy |= parent._service(h)
                if busy:
                    continue
                if stop.is_set():
                    # final sweep already found every inbox empty
                    break
                self.wake.clear()
                # re-check after clearing: a post between the sweep and
                # the clear would otherwise be a lost wakeup
                if any(len(inbox[h]) for h in homes):
                    continue
                self.idle_waits += 1
                if parent.obs.enabled:
                    parent.obs.emit("pump_idle", manager=homes[0],
                                    waits=self.idle_waits)
                self.wake.wait(0.05)
        except BaseException as e:  # noqa: BLE001 - handed to the master
            parent._pump_errors.append(e)
            with parent._cv:
                parent._grants_flag = True
                parent._cv.notify_all()


class ShardedDependenceManager:
    """N per-home managers behind the central analyzer's protocol.

    Drop-in for :class:`~repro.core.deps.DependenceAnalyzer` at every
    runtime touch point (``analyze`` / ``tasks_touching`` /
    ``forget_completed`` / the ``blocks_walked`` / ``deps_found``
    counters), plus the sharded extras the scheduler and wave builder
    consume: per-home ready deques (:meth:`push_ready` /
    :meth:`pop_ready`) and owner routing (:meth:`owner_of`).

    Routing needs each block's home, which lives on its ``BlockArray``;
    the runtime calls :meth:`register_array` for every array it
    registers, so the router is one dict lookup per footprint block.
    The admitted slice of each live task is kept (master-side, O(live
    tasks) — the same lifetime as its descriptor) so completion fan-out
    reuses it instead of re-partitioning the footprint.

    ``batch_lines`` sets the envelope capacity in MPB lines
    (``batch_lines * DESCRIPTORS_PER_LINE`` descriptor slots);
    ``batch_lines=1`` disables coalescing — every logical descriptor
    travels alone, reproducing the pre-batching wire traffic exactly
    (``dep_batches == dep_messages``).  ``pump`` selects ``"sync"`` or
    ``"threaded"`` (see the module docstring); ``pump_threads`` caps the
    thread count (default: one per home, or ``REPRO_DEPMAN_THREADS``
    when set).
    """

    def __init__(self, n_managers: int = 4, channel_slots: int = 16,
                 obs=NULL_TRACKER, batch_lines: int = 1,
                 pump: str = "sync", pump_threads: int | None = None,
                 record_traffic: bool = False):
        if n_managers < 1:
            raise ValueError("need at least one manager")
        if pump not in ("sync", "threaded"):
            raise ValueError(f"pump must be 'sync' or 'threaded', "
                             f"got {pump!r}")
        self.n_managers = n_managers
        self.obs = obs
        self.pump = pump
        self.batch_lines = max(1, int(batch_lines))
        self.managers = [HomeManager(h) for h in range(n_managers)]
        # MPB-style SPSC rings: one inbox (master -> manager) and one
        # grant channel (manager -> master) per home
        self.inbox = [MPBChannel(f"dep/home{h}", channel_slots)
                      for h in range(n_managers)]
        self.grants = [MPBChannel(f"grant/home{h}", channel_slots)
                       for h in range(n_managers)]
        self._homes: dict[int, dict] = {}    # array_id -> tile home map
        self._live_parts: dict = {}          # td -> admitted slices
        # region -> per-home block runs.  Task programs name the same
        # footprint regions over and over (the same tiles every
        # iteration), so the routing walk runs once per distinct region
        # and every later admission is a dict hit.  Invalidated when an
        # array (re)registers, which is when home maps change.
        self._route_cache: dict = {}
        # -- outgoing line batcher (master-side; all counters here are
        # master-written only, so they need no synchronization) ---------
        self._batch_slots = self.batch_lines * DESCRIPTORS_PER_LINE
        self._out: list[list] = [[] for _ in range(n_managers)]
        self._out_slots = [0] * n_managers
        self._posted = [0] * n_managers      # envelopes sent per home
        # -- split-phase admission state (master-side) -------------------
        self._pending: deque[_Pending] = deque()
        self._pending_by_task: dict = {}
        # -- counters ----------------------------------------------------
        # logical messages: queries/releases counted at enqueue, grants
        # counted as the master absorbs them — all master-side, so the
        # totals are exact after any sync point in either pump mode
        self._msgs_posted = 0
        self._grants_received = 0
        self._batches_posted = 0
        self._lines_posted = 0
        self._batches_granted = 0
        self._lines_granted = 0
        # blocks walked during admission routing — mirrors the central
        # analyzer's count so stats stay comparable across managers
        self.blocks_walked = 0
        self._deps_found = 0                 # unioned, master-side
        self._rr_home = 0                    # drain_ready round-robin
        # optional logical-traffic recording for the sim-side
        # reconciliation (``sim.predict_dep_traffic`` replays it)
        self.traffic_log: list | None = [] if record_traffic else None
        self.traffic_deps: dict[int, int] = {}   # query id -> grant deps
        self._rec_next_qid = 0
        self._rec_qid: dict = {}                 # td -> {home: query id}
        # -- threaded pump machinery -------------------------------------
        self._stop = threading.Event()
        self._cv = threading.Condition()
        self._grants_flag = False
        self._pump_errors: list[BaseException] = []
        self._threads: list[_PumpWorker] = []
        self._thread_of: list[_PumpWorker] = []
        if pump == "threaded":
            n_threads = pump_threads
            if n_threads is None:
                try:
                    n_threads = int(os.environ.get(
                        "REPRO_DEPMAN_THREADS", "0")) or n_managers
                except ValueError:
                    n_threads = n_managers
            n_threads = max(1, min(int(n_threads), n_managers))
            by_thread: list[list[int]] = [[] for _ in range(n_threads)]
            for h in range(n_managers):
                by_thread[h % n_threads].append(h)
            self._threads = [_PumpWorker(self, homes, i)
                             for i, homes in enumerate(by_thread)]
            self._thread_of = [None] * n_managers  # type: ignore
            for t in self._threads:
                for h in t.homes:
                    self._thread_of[h] = t
            for t in self._threads:
                t.start()

    # -- routing -------------------------------------------------------------
    def register_array(self, ba) -> None:
        """Learn an array's block -> home map (called at registration,
        after ``placement.assign_homes`` ran)."""
        self._homes[ba.array_id] = ba.home
        self._route_cache.clear()

    def _route(self, region) -> tuple:
        """Per-home block runs of one region: ``(n_blocks, ((home,
        blocks), ...))``, cached by the region's identity (array +
        tile ranges)."""
        key = (region.array.array_id, region.ranges)
        hit = self._route_cache.get(key)
        if hit is None:
            ids = region.block_ids
            hmap = self._homes.get(region.array.array_id)
            if not hmap:
                runs: dict[int, list] = {0: list(ids)}
            else:
                n = self.n_managers
                hget = hmap.get
                runs = {}
                for block in ids:
                    h = hget(block[1], 0) % n
                    blocks = runs.get(h)
                    if blocks is None:
                        runs[h] = [block]
                    else:
                        blocks.append(block)
            hit = (len(ids), tuple(runs.items()))
            self._route_cache[key] = hit
        return hit

    def _partition(self, task: "TaskDescriptor") -> dict[int, list]:
        """Split a footprint into per-home slices of ``(reads, writes,
        blocks)`` region runs, in argument order (the order
        :meth:`HomeManager.admit` replays)."""
        route_get = self._route_cache.get
        route = self._route
        parts: dict[int, list] = {}
        walked = 0
        for mode in task.args:
            region = mode.region
            hit = route_get((region.array.array_id, region.ranges)) \
                or route(region)
            walked += hit[0]
            r, w = mode.READS, mode.WRITES
            for h, blocks in hit[1]:
                lst = parts.get(h)
                if lst is None:
                    parts[h] = [(r, w, blocks)]
                else:
                    lst.append((r, w, blocks))
        self.blocks_walked += walked
        return parts

    # -- the wire: batching, flushing, servicing ------------------------------
    def _enqueue(self, home: int, kind: str, task, items: list) -> None:
        """Buffer one logical descriptor for ``home``; flush on envelope
        capacity (ring pressure — the deterministic trigger: it depends
        on the logical stream and ``batch_lines`` alone)."""
        slots = max(1, len(items))
        if self._out_slots[home] + slots > self._batch_slots \
                and self._out[home]:
            self._flush_home(home)
        self._out[home].append((kind, task, items))
        self._out_slots[home] += slots
        self._msgs_posted += 1
        if self.traffic_log is not None:
            qid = None
            if kind == "dep_query":
                # query ids correlate grant payload sizes positionally
                # (descriptor pools recycle task ids, so tids can't key)
                qid = self._rec_next_qid
                self._rec_next_qid += 1
                self._rec_qid.setdefault(task, {})[home] = qid
            self.traffic_log.append(("desc", home, kind, slots, qid))
        if self.obs.enabled:
            self.obs.emit("dep_msg", manager=home, msg=kind, count=1)
        if self.batch_lines <= 1:
            # batching off: every descriptor travels alone (the
            # pre-batching wire behavior, envelope == logical message)
            self._flush_home(home)

    def _flush_home(self, home: int) -> None:
        """Seal and post one home's buffered envelope.  Backpressure
        never services inline mid-send: the master drains grants (which
        is what frees a correct consumer) and, threaded, waits for the
        pump thread — the single non-reentrant service loop is only ever
        entered from :meth:`_service_all` (sync) or the pump threads."""
        descs = self._out[home]
        if not descs:
            return
        slots = self._out_slots[home]
        self._out[home] = []
        self._out_slots[home] = 0
        # drain this home's grants *before* posting: keeps outstanding
        # grant envelopes <= unanswered query envelopes <= ring depth,
        # so the manager-side overflow raise cannot fire in a correct
        # run (it stays as the protocol tripwire, never a drop)
        self._absorb(home)
        env = DepMessage("dep_batch", home, None, descs)
        ch = self.inbox[home]
        threaded = self.pump == "threaded"
        while not ch.try_send(env):
            if threaded:
                self._wait_for_grants()
                # absorb EVERY home, not just this one: a pump thread
                # stalled on some other home's full grant ring is what
                # may be keeping this home's inbox from draining
                self._absorb_all()
            else:
                self._service(home)
                self._absorb(home)
        self._posted[home] += 1
        self._batches_posted += 1
        nlines = lines_for(slots)
        self._lines_posted += nlines
        if self.traffic_log is not None:
            self.traffic_log.append(("flush", home))
        if self.obs.enabled:
            self.obs.emit("dep_batch", manager=home, direction="post",
                          descriptors=len(descs), lines=nlines)
        if threaded:
            self._thread_of[home].wake.set()

    def flush(self) -> None:
        """Flush every home's buffered envelope (wave boundaries,
        barriers, explicit sync points)."""
        if self.traffic_log is not None:
            # every flush-all is a policy-visible sync point; the
            # sim-side replay (``sim.predict_dep_traffic``) flushes its
            # model buffers here too
            self.traffic_log.append(("sync",))
        for home in range(self.n_managers):
            if self._out[home]:
                self._flush_home(home)

    def _service(self, home: int) -> bool:
        """THE pump loop: drain one manager's inbox, admitting queries
        and dropping released metadata; answer each query-carrying
        envelope with one grant envelope.  Non-reentrant by
        construction — posting paths never call it while a drain is in
        progress, and in threaded mode only the home's pump thread runs
        it.  Returns True when any envelope was consumed."""
        envs = self.inbox[home].recv_all()
        if not envs:
            return False
        t0 = time.perf_counter()
        mgr = self.managers[home]
        grants_ring = self.grants[home]
        for env in envs:
            pairs = []
            for kind, task, items in env.payload:
                if kind == "dep_query":
                    pairs.append((task, mgr.admit(task, items)))
                else:                                # release
                    mgr.forget(task, items)
            if pairs:
                grant = DepMessage("dep_grant", home, None, pairs)
                if not grants_ring.try_send(grant):
                    if self.pump != "threaded":
                        # sync protocol invariant: the master drains
                        # grants before every post AND after every
                        # service, so the ring can never refill past
                        # capacity — a full ring means a lost
                        # dependence set
                        raise RuntimeError(
                            f"dep_grant ring overflow on home {home}")
                    # threaded: the master absorbs this home's grants
                    # on its next post / wait / sync cycle, but may lag
                    # while backpressuring on a different home — wake
                    # it and wait for ring space (backpressure, never a
                    # drop; the master's wait loops absorb ALL homes)
                    while not grants_ring.try_send(grant):
                        with self._cv:
                            self._grants_flag = True
                            self._cv.notify_all()
                        if self._stop.is_set():
                            raise RuntimeError(
                                f"dep_grant ring overflow on home {home}"
                                f" at shutdown")
                        time.sleep(10e-6)
            mgr.processed += 1
        mgr.busy_s += time.perf_counter() - t0
        if self.pump == "threaded":
            # signal any consumption, not just grants: the master's
            # backpressure and quiesce waits also ride this flag (a
            # release-only envelope frees ring space too)
            with self._cv:
                self._grants_flag = True
                self._cv.notify_all()
        return True

    def _absorb(self, home: int) -> None:
        """Master-side: drain one home's grant ring into the pending
        admission records (grants count as logical messages here, so
        every counter stays master-written)."""
        envs = self.grants[home].recv_all()
        if not envs:
            return
        obs_on = self.obs.enabled
        by_task = self._pending_by_task
        for env in envs:
            slots = 0
            for task, got in env.payload:
                rec = by_task.get(task)
                if rec is not None:
                    rec.remaining -= 1
                    if got:
                        rec.deps |= got
                n_deps = len(got)
                slots += grant_slots(n_deps)
                self._grants_received += 1
                if self.traffic_log is not None:
                    homes_of = self._rec_qid.get(task)
                    if homes_of is not None:
                        self.traffic_deps[homes_of.pop(home)] = n_deps
                        if not homes_of:
                            del self._rec_qid[task]
                if obs_on:
                    self.obs.emit("manager_admit", manager=home,
                                  task=task.tid, deps=n_deps,
                                  depth=len(self.inbox[home]))
                    self.obs.emit("dep_msg", manager=home,
                                  msg="dep_grant", count=1)
            self._batches_granted += 1
            nlines = lines_for(slots)
            self._lines_granted += nlines
            if obs_on:
                self.obs.emit("dep_batch", manager=home,
                              direction="grant",
                              descriptors=len(env.payload), lines=nlines)

    def _absorb_all(self) -> None:
        for home in range(self.n_managers):
            self._absorb(home)

    def _check_pump(self) -> None:
        if self._pump_errors:
            err = self._pump_errors[0]
            raise RuntimeError("dependence pump thread failed") from err

    def _wait_for_grants(self, timeout: float = 0.01) -> None:
        """Park until a pump thread signals grant (or envelope)
        progress; bounded wait so a protocol bug surfaces as a slow
        test, not a hang."""
        self._check_pump()
        with self._cv:
            if not self._grants_flag:
                self._cv.wait(timeout)
            self._grants_flag = False

    def _collect_admitted(self) -> list:
        """Pop fully-granted admissions off the left of the pending
        queue — spawn order, the order ``analyze_begin`` was called."""
        out = []
        pend = self._pending
        by_task = self._pending_by_task
        while pend and pend[0].remaining == 0:
            rec = pend.popleft()
            del by_task[rec.task]
            self._deps_found += len(rec.deps)
            out.append((rec.task, rec.deps))
        return out

    # -- split-phase admission -------------------------------------------------
    def analyze_begin(self, task: "TaskDescriptor") -> None:
        """Post a task's footprint slices as ``dep_query`` descriptors
        (non-blocking producer side).  The caller must not complete any
        task (no ``is_complete`` transition) until :meth:`admit_finish`
        returned this task — that ordering is the bit-identity
        contract."""
        parts = self._partition(task)
        self._live_parts[task] = parts
        rec = _Pending(task, len(parts))
        self._pending.append(rec)
        self._pending_by_task[task] = rec
        for home, items in parts.items():
            self._enqueue(home, "dep_query", task, items)

    def admit_finish(self) -> list:
        """Flush buffered queries and wait until *every* pending
        admission is granted; returns ``(task, deps)`` pairs in spawn
        order.  Sync mode services the managers inline here (the only
        sync-mode service site besides quiesce); threaded mode just
        drains grant rings while the pump threads work."""
        self.flush()
        if self.pump == "threaded":
            out: list = []
            while self._pending:
                self._absorb_all()
                done = self._collect_admitted()
                if done:
                    out.extend(done)
                elif self._pending:
                    self._wait_for_grants()
            return out
        self._service_all()
        self._absorb_all()
        return self._collect_admitted()

    def _service_all(self) -> None:
        for home in range(self.n_managers):
            if len(self.inbox[home]):
                self._service(home)

    # -- the DependenceAnalyzer protocol --------------------------------------
    def analyze(self, task: "TaskDescriptor") -> set["TaskDescriptor"]:
        """Blocking admission of one task: route the footprint to its
        home managers, wait for the grant union.  Exactly
        ``analyze_begin`` + ``admit_finish`` of a single task."""
        self.analyze_begin(task)
        pairs = self.admit_finish()
        # single caller discipline: blocking analyze never overlaps
        # another pending admission, so the pair list is exactly ours
        return pairs[-1][1]

    def tasks_touching(self, blocks, mode: str = "in") \
            -> set["TaskDescriptor"]:
        """Same rules as the central analyzer's region sync, routed by
        home (``mode="in"`` waits for writers; ``"out"``/``"inout"`` for
        readers too).  Quiesces first: buffered releases are applied and
        the pump threads drained, so the metadata read is current and
        race-free."""
        self.quiesce()
        mode = coerce_mode(mode)
        n = self.n_managers
        homes = self._homes
        per_home: dict[int, list] = {}
        for block in blocks:
            hmap = homes.get(block[0])
            h = (hmap.get(block[1], 0) if hmap else 0) % n
            per_home.setdefault(h, []).append(block)
        found: set[TaskDescriptor] = set()
        for h, blks in per_home.items():
            found |= self.managers[h].sync(blks,
                                           writers_only=(mode == "in"))
        return found

    def forget_completed(self, task: "TaskDescriptor") -> None:
        """Completion fan-out: one ``release`` descriptor per involved
        home, carrying the slice admitted at initiation.  Buffered — the
        wire envelope goes out with the next flush (wave boundary, ring
        pressure, or sync point); correctness never depends on release
        timing because admission filters on ``is_complete``."""
        parts = self._live_parts.pop(task, None)
        if parts is None:                # never admitted here (defensive)
            return
        for home, items in parts.items():
            self._enqueue(home, "release", task, items)

    # -- quiesce / shutdown ----------------------------------------------------
    def quiesce(self) -> None:
        """Flush every buffer and wait until each manager consumed
        exactly the envelopes the master posted and every grant was
        absorbed.  Requires no admissions outstanding (collect them with
        :meth:`admit_finish` first)."""
        if self._pending:
            raise RuntimeError("quiesce with admissions outstanding — "
                               "drain admit_finish() first")
        self.flush()
        if self.pump == "threaded":
            posted = self._posted
            managers = self.managers
            while True:
                self._absorb_all()
                self._check_pump()
                if all(managers[h].processed == posted[h]
                       for h in range(self.n_managers)):
                    self._absorb_all()
                    break
                self._wait_for_grants()
        else:
            self._service_all()
            self._absorb_all()

    def shutdown(self) -> None:
        """Quiesce, stop and join the pump threads (idempotent; sync
        mode only flushes)."""
        if not self._pump_errors:
            self.quiesce()
        self._stop.set()
        for t in self._threads:
            t.wake.set()
        for t in self._threads:
            t.join(timeout=5.0)
        self._check_pump()

    # -- stats ---------------------------------------------------------------
    @property
    def dep_messages(self) -> int:
        """Logical protocol messages — one per ``dep_query`` /
        ``dep_grant`` / ``release`` descriptor, independent of how they
        were packed into envelopes (bit-compatible with the pre-batching
        counter)."""
        return self._msgs_posted + self._grants_received

    @property
    def dep_batches(self) -> int:
        """Envelopes actually sent over the rings, both directions —
        strictly fewer than ``dep_messages`` whenever batching is on."""
        return self._batches_posted + self._batches_granted

    @property
    def dep_lines(self) -> int:
        """Total 32-byte MPB lines those envelopes occupied (what the
        DES charges; ``sim.predict_dep_traffic`` must reproduce it)."""
        return self._lines_posted + self._lines_granted

    @property
    def pump_wall_s(self) -> float:
        """Wall seconds spent inside manager servicing (per-home
        single-writer accumulators: the pump threads' busy time under
        ``threaded``, the master's inline service time under
        ``sync``)."""
        return sum(m.busy_s for m in self.managers)

    @property
    def deps_found(self) -> int:
        """Unioned master-side count — matches the central analyzer (a
        predecessor granted by two managers counts once)."""
        return self._deps_found

    @property
    def admissions(self) -> list[int]:
        """Per-manager admitted footprint slices (the acceptance-visible
        admission counts; also emitted as ``manager_admit`` events)."""
        return [m.admissions for m in self.managers]

    @property
    def live_blocks(self) -> int:
        """Blocks with live ordering state, summed over homes (quiesces
        first so buffered releases are applied and no pump thread is
        mutating the dicts mid-read)."""
        self.quiesce()
        return sum(m.live_blocks for m in self.managers)

    # -- per-home readiness (owner-computes) -----------------------------------
    def owner_of(self, td: "TaskDescriptor") -> int:
        """A task parks at the home of its first output block (the same
        owner-computes rule ``sharded.owner_home`` dispatches by)."""
        for m in td.args:
            if m.WRITES:
                region = m.region
                hmap = self._homes.get(region.array.array_id)
                if hmap:
                    return hmap.get(region.tile_indices[0], 0) \
                        % self.n_managers
                return 0
        return 0

    def push_ready(self, td: "TaskDescriptor", front: bool = False) -> None:
        q = self.managers[self.owner_of(td)].ready
        if front:
            q.appendleft(td)
        else:
            q.append(td)

    @property
    def ready_count(self) -> int:
        return sum(len(m.ready) for m in self.managers)

    def pop_ready(self) -> "TaskDescriptor | None":
        """Round-robin over the per-home ready deques (fair drain; no
        home starves behind a deep neighbor)."""
        n = self.n_managers
        for i in range(n):
            h = (self._rr_home + i) % n
            q = self.managers[h].ready
            if q:
                self._rr_home = (h + 1) % n
                return q.popleft()
        return None
