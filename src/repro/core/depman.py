"""Home-sharded dependence management: per-home managers over MPB channels.

The paper keeps dependence analysis on one master core and pays for it in
master-side spawn cost (§3.3, §5); the related work attacks exactly that
bottleneck by distributing the task manager (Bosch et al., *Asynchronous
Runtime with Distributed Manager*) and by hierarchical dependency-aware
scheduling (Lyberis et al., *Myrmics*).  This module is that refactor:
:class:`ShardedDependenceManager` splits the global
:class:`~repro.core.deps.DependenceAnalyzer` into N :class:`HomeManager` s
— one per block home, the same ``placement.device_assignment`` regions
``DeviceTileStore`` already uses — each owning the block metadata for its
home and admitting the slice of a task's footprint that touches its
region.

Transport is paper-faithful: the master exchanges small typed messages
(:class:`DepMessage`, kinds ``dep_query`` / ``dep_grant`` / ``release``)
with each manager over bounded MPB-style SPSC rings
(:class:`~repro.core.mpb.MPBChannel`).  One ``dep_query`` carries the
whole per-home slice of a footprint — a few ``(reads, writes, blocks)``
region runs, a handful of 32-byte MPB lines on the wire; the manager
answers with one ``dep_grant`` naming the predecessor tasks it found, and
completion fan-out sends one ``release`` per involved home.  Under
CPython the master pumps manager inboxes synchronously (single-threaded),
but the protocol is the SPSC-plus-fences discipline that runs managers on
their own cores on SCC — and the DES (``sim.py``) charges exactly this
message traffic, with the per-home metadata walks overlapping instead of
serializing on the master.

Semantics are bit-compatible with the central analyzer: block metadata is
partitioned by home (each block has exactly one owner), so the union of
per-home dependence grants equals the central analyzer's dependence set
for every task — the determinism pin in ``tests/test_depman.py`` holds
central and sharded to identical wave schedules and numerics on all
benchmark apps.

Readiness is sharded too: the manager keeps one ready deque per home
(owner-computes — a task parks at the home of its first output block),
``MasterScheduler.drain_ready`` round-robins over them, and the staged
wave builder consumes the per-home ready sets level by level.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterable

from repro.obs.tracker import NULL_TRACKER

from .blocks import coerce_mode
from .deps import BlockId
from .mpb import MPBChannel

if TYPE_CHECKING:  # pragma: no cover
    from .graph import TaskDescriptor

__all__ = ["DepMessage", "HomeManager", "ShardedDependenceManager"]

_MSG_KINDS = ("dep_query", "dep_grant", "release")


@dataclass(slots=True)
class DepMessage:
    """One typed manager message: a few MPB lines on the wire.

    * ``dep_query``  (master -> manager): ``payload`` is the task's
      per-home footprint slice — region runs of ``(reads, writes,
      blocks)``.
    * ``dep_grant``  (manager -> master): ``payload`` is the set of
      predecessor tasks the manager's metadata ordered the task after.
    * ``release``    (master -> manager): ``payload`` is the released
      task's slice (as admitted); the manager drops its references.
    """
    kind: str
    home: int
    task: "TaskDescriptor"
    payload: object = None


class HomeManager:
    """One home's dependence manager: owns the block metadata for every
    block homed in its region and admits footprint slices independently.

    The metadata is the BDDT per-block ordering state (last writer +
    readers since that write, §3.3) kept as two plain dicts — leaner
    than the central analyzer's per-block objects, which is where the
    sharded admission path wins back its messaging overhead.
    """

    __slots__ = ("home", "_writer", "_readers", "deps_found",
                 "admissions", "ready")

    def __init__(self, home: int):
        self.home = home
        self._writer: dict[BlockId, "TaskDescriptor"] = {}
        self._readers: dict[BlockId, list["TaskDescriptor"]] = {}
        self.deps_found = 0             # dependences this manager granted
        self.admissions = 0             # footprint slices admitted
        # per-home ready deque (owner-computes): what drain_ready and the
        # staged wave builder consume
        self.ready: deque["TaskDescriptor"] = deque()

    @property
    def live_blocks(self) -> int:
        """Blocks with live ordering state (leak check surface)."""
        return len(self._writer) + sum(1 for k in self._readers
                                       if k not in self._writer)

    def admit(self, task: "TaskDescriptor",
              items: list) -> set["TaskDescriptor"]:
        """Process one ``dep_query``: the fused collect-then-publish walk
        over this home's slice.  Each region run is visited in argument
        order, so a block touched by several modes of one task sees the
        same sequence of states the central analyzer's two passes produce
        (self-dependences are filtered exactly like the central walk)."""
        writer = self._writer
        readers = self._readers
        deps: set[TaskDescriptor] = set()
        add = deps.add
        wget = writer.get
        rget = readers.get
        for r, w, blocks in items:
            if w:
                for block in blocks:
                    lw = wget(block)
                    if lw is not None and lw is not task \
                            and not lw.is_complete:
                        add(lw)                      # RAW / WAW
                    rl = rget(block)
                    if rl is not None:
                        for t in rl:
                            if t is not task and not t.is_complete:
                                add(t)               # WAR
                        del readers[block]
                    writer[block] = task
            else:
                for block in blocks:
                    lw = wget(block)
                    if lw is not None and lw is not task \
                            and not lw.is_complete:
                        add(lw)                      # RAW
                    rl = rget(block)
                    if rl is None:
                        readers[block] = [task]
                    elif task not in rl:
                        rl.append(task)
        self.admissions += 1
        self.deps_found += len(deps)
        return deps

    def sync(self, blocks: Iterable[BlockId],
             writers_only: bool) -> set["TaskDescriptor"]:
        """The ``tasks_touching`` slice for this home (wait_on support)."""
        found: set[TaskDescriptor] = set()
        for block in blocks:
            w = self._writer.get(block)
            if w is not None and not w.is_complete:
                found.add(w)
            if not writers_only:
                for r in self._readers.get(block, ()):
                    if not r.is_complete:
                        found.add(r)
        return found

    def forget(self, task: "TaskDescriptor", items: list) -> None:
        """Process one ``release``: drop the task's references so block
        state stays O(live tasks) — entries with no live writer and no
        live readers are deleted outright."""
        writer = self._writer
        readers = self._readers
        for _r, _w, blocks in items:
            for block in blocks:
                if writer.get(block) is task:
                    del writer[block]
                rl = readers.get(block)
                if rl is not None:
                    try:
                        rl.remove(task)
                    except ValueError:
                        pass
                    if not rl:
                        del readers[block]


class ShardedDependenceManager:
    """N per-home managers behind the central analyzer's protocol.

    Drop-in for :class:`~repro.core.deps.DependenceAnalyzer` at every
    runtime touch point (``analyze`` / ``tasks_touching`` /
    ``forget_completed`` / the ``blocks_walked`` / ``deps_found``
    counters), plus the sharded extras the scheduler and wave builder
    consume: per-home ready deques (:meth:`push_ready` /
    :meth:`pop_ready`) and owner routing (:meth:`owner_of`).

    Routing needs each block's home, which lives on its ``BlockArray``;
    the runtime calls :meth:`register_array` for every array it
    registers, so the router is one dict lookup per footprint block.
    The admitted slice of each live task is kept (master-side, O(live
    tasks) — the same lifetime as its descriptor) so completion fan-out
    reuses it instead of re-partitioning the footprint.
    """

    def __init__(self, n_managers: int = 4, channel_slots: int = 16,
                 obs=NULL_TRACKER):
        if n_managers < 1:
            raise ValueError("need at least one manager")
        self.n_managers = n_managers
        self.obs = obs
        self.managers = [HomeManager(h) for h in range(n_managers)]
        # MPB-style SPSC rings: one inbox (master -> manager) and one
        # grant channel (manager -> master) per home
        self.inbox = [MPBChannel(f"dep/home{h}", channel_slots)
                      for h in range(n_managers)]
        self.grants = [MPBChannel(f"grant/home{h}", channel_slots)
                       for h in range(n_managers)]
        self._homes: dict[int, dict] = {}    # array_id -> tile home map
        self._live_parts: dict = {}          # td -> admitted slices
        # region -> per-home block runs.  Task programs name the same
        # footprint regions over and over (the same tiles every
        # iteration), so the routing walk runs once per distinct region
        # and every later admission is a dict hit.  Invalidated when an
        # array (re)registers, which is when home maps change.
        self._route_cache: dict = {}
        self.dep_messages = 0
        # blocks walked during admission routing — mirrors the central
        # analyzer's count so stats stay comparable across managers
        self.blocks_walked = 0
        self._deps_found = 0                 # unioned, master-side
        self._rr_home = 0                    # drain_ready round-robin

    # -- routing -------------------------------------------------------------
    def register_array(self, ba) -> None:
        """Learn an array's block -> home map (called at registration,
        after ``placement.assign_homes`` ran)."""
        self._homes[ba.array_id] = ba.home
        self._route_cache.clear()

    def _route(self, region) -> tuple:
        """Per-home block runs of one region: ``(n_blocks, ((home,
        blocks), ...))``, cached by the region's identity (array +
        tile ranges)."""
        key = (region.array.array_id, region.ranges)
        hit = self._route_cache.get(key)
        if hit is None:
            ids = region.block_ids
            hmap = self._homes.get(region.array.array_id)
            if not hmap:
                runs: dict[int, list] = {0: list(ids)}
            else:
                n = self.n_managers
                hget = hmap.get
                runs = {}
                for block in ids:
                    h = hget(block[1], 0) % n
                    blocks = runs.get(h)
                    if blocks is None:
                        runs[h] = [block]
                    else:
                        blocks.append(block)
            hit = (len(ids), tuple(runs.items()))
            self._route_cache[key] = hit
        return hit

    def _partition(self, task: "TaskDescriptor") -> dict[int, list]:
        """Split a footprint into per-home slices of ``(reads, writes,
        blocks)`` region runs, in argument order (the order
        :meth:`HomeManager.admit` replays)."""
        route_get = self._route_cache.get
        route = self._route
        parts: dict[int, list] = {}
        walked = 0
        for mode in task.args:
            region = mode.region
            hit = route_get((region.array.array_id, region.ranges)) \
                or route(region)
            walked += hit[0]
            r, w = mode.READS, mode.WRITES
            for h, blocks in hit[1]:
                lst = parts.get(h)
                if lst is None:
                    parts[h] = [(r, w, blocks)]
                else:
                    lst.append((r, w, blocks))
        self.blocks_walked += walked
        return parts

    # -- the message protocol -----------------------------------------------
    def _post(self, home: int, msg: DepMessage) -> None:
        """Send one message to a manager's inbox, pumping the manager on
        backpressure (a full ring never deadlocks: the consumer is always
        runnable)."""
        ch = self.inbox[home]
        while not ch.try_send(msg):
            self._pump(home)
        self.dep_messages += 1

    def _pump(self, home: int) -> None:
        """Drain one manager's inbox: queries are admitted and answered
        with a grant on the manager's grant channel; releases drop
        metadata in place."""
        mgr = self.managers[home]
        for msg in self.inbox[home].recv_all():
            if msg.kind == "dep_query":
                deps = mgr.admit(msg.task, msg.payload)
                grant = DepMessage("dep_grant", home, msg.task, deps)
                if not self.grants[home].try_send(grant):
                    # protocol invariant: the master drains grants after
                    # every pump, so the grant ring can never refill past
                    # capacity — a full ring means a lost dependence set
                    raise RuntimeError(
                        f"dep_grant ring overflow on home {home}")
                self.dep_messages += 1
            else:                                    # release
                mgr.forget(msg.task, msg.payload)

    # -- the DependenceAnalyzer protocol --------------------------------------
    def analyze(self, task: "TaskDescriptor") -> set["TaskDescriptor"]:
        """Route the footprint to its home managers as ``dep_query``
        messages; union the ``dep_grant`` answers."""
        parts = self._partition(task)
        self._live_parts[task] = parts
        obs_on = self.obs.enabled
        deps: set[TaskDescriptor] = set()
        for home, items in parts.items():
            depth = len(self.inbox[home])
            self._post(home, DepMessage("dep_query", home, task, items))
            self._pump(home)
            for grant in self.grants[home].recv_all():
                got = grant.payload
                if got:
                    deps |= got
                if obs_on:
                    self.obs.emit("manager_admit", manager=home,
                                  task=task.tid, deps=len(got),
                                  depth=depth)
            if obs_on:
                self.obs.emit("dep_msg", manager=home, msg="dep_query",
                              count=1)
                self.obs.emit("dep_msg", manager=home, msg="dep_grant",
                              count=1)
        self._deps_found += len(deps)
        return deps

    def tasks_touching(self, blocks, mode: str = "in") \
            -> set["TaskDescriptor"]:
        """Same rules as the central analyzer's region sync, routed by
        home (``mode="in"`` waits for writers; ``"out"``/``"inout"`` for
        readers too)."""
        mode = coerce_mode(mode)
        n = self.n_managers
        homes = self._homes
        per_home: dict[int, list] = {}
        for block in blocks:
            hmap = homes.get(block[0])
            h = (hmap.get(block[1], 0) if hmap else 0) % n
            per_home.setdefault(h, []).append(block)
        found: set[TaskDescriptor] = set()
        for h, blks in per_home.items():
            found |= self.managers[h].sync(blks,
                                           writers_only=(mode == "in"))
        return found

    def forget_completed(self, task: "TaskDescriptor") -> None:
        """Completion fan-out: one ``release`` message per involved home,
        carrying the slice admitted at initiation."""
        parts = self._live_parts.pop(task, None)
        if parts is None:                # never admitted here (defensive)
            return
        obs_on = self.obs.enabled
        for home, items in parts.items():
            self._post(home, DepMessage("release", home, task, items))
            self._pump(home)
            if obs_on:
                self.obs.emit("dep_msg", manager=home, msg="release",
                              count=1)

    # -- stats ---------------------------------------------------------------
    @property
    def deps_found(self) -> int:
        """Unioned master-side count — matches the central analyzer (a
        predecessor granted by two managers counts once)."""
        return self._deps_found

    @property
    def admissions(self) -> list[int]:
        """Per-manager admitted footprint slices (the acceptance-visible
        admission counts; also emitted as ``manager_admit`` events)."""
        return [m.admissions for m in self.managers]

    @property
    def live_blocks(self) -> int:
        return sum(m.live_blocks for m in self.managers)

    # -- per-home readiness (owner-computes) -----------------------------------
    def owner_of(self, td: "TaskDescriptor") -> int:
        """A task parks at the home of its first output block (the same
        owner-computes rule ``sharded.owner_home`` dispatches by)."""
        for m in td.args:
            if m.WRITES:
                region = m.region
                hmap = self._homes.get(region.array.array_id)
                if hmap:
                    return hmap.get(region.tile_indices[0], 0) \
                        % self.n_managers
                return 0
        return 0

    def push_ready(self, td: "TaskDescriptor", front: bool = False) -> None:
        q = self.managers[self.owner_of(td)].ready
        if front:
            q.appendleft(td)
        else:
            q.append(td)

    @property
    def ready_count(self) -> int:
        return sum(len(m.ready) for m in self.managers)

    def pop_ready(self) -> "TaskDescriptor | None":
        """Round-robin over the per-home ready deques (fair drain; no
        home starves behind a deep neighbor)."""
        n = self.n_managers
        for i in range(n):
            h = (self._rr_home + i) % n
            q = self.managers[h].ready
            if q:
                self._rr_home = (h + 1) % n
                return q.popleft()
        return None
