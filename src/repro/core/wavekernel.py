"""Pallas wave kernels: one fused ``pl.pallas_call`` per grouped wave.

The paper's §3.2 performance argument is that a wave's tasks should run
out of fast on-chip memory (the per-core MPBs) instead of round-tripping
every operand through shared DRAM.  The staged executor already fuses a
wavefront's identical tile tasks into one ``jit(vmap(fn))`` dispatch; this
module goes one level down: an eligible group lowers into a *single*
Pallas kernel whose grid axis is the task axis — ``grid=(n_tasks,)`` —
and whose ``BlockSpec``s map each task's block footprint onto the stacked
tile storage.  Grid step ``t`` sees exactly task ``t``'s operand tiles in
kernel-local memory (the modern analogue of staging through the MPB), the
task body runs unchanged on the per-task views, and outputs are written
back through the output ``BlockSpec``s — tile loads/stores happen in
on-chip memory instead of one HBM round trip per vmap lane.

Selection is ``RuntimeConfig.kernel_backend``: ``"xla"`` (the default) is
today's vmap/shard_map dispatch, ``"pallas"`` tries this lowering per
group and *automatically falls back* to the XLA path for ineligible
groups — :func:`eligibility` names the reason (single-task group,
non-rectangular footprint, mixed dtypes, grid overflow, ...), the
executor counts it in ``RuntimeStats.kernel_fallbacks`` and emits a
``kernel_dispatch`` event carrying backend + reason.  The staged path
thus stays the always-available reference oracle, and the differential
fuzz harness (``tests/test_differential.py``) holds the two bit-identical.

Bit-exactness contract: the built kernel is always wrapped in ``jax.jit``.
Under jit, the Pallas-interpreted task body and the ``jit(vmap(fn))``
reference compile to the same XLA ops per task, so results are bitwise
equal to the staged path (pinned by the fuzz harness); *eager* execution
is excluded precisely because CPU eager dot products differ from
compiled ones in the last ulp.

On hardware without a Pallas backend (the CPU test matrix), the kernel
runs under ``pl.pallas_call(..., interpret=True)`` — forced by the
``REPRO_PALLAS_INTERPRET=1`` env flag in CI and auto-enabled whenever the
default jax backend is not TPU (:func:`interpret_mode`).
"""
from __future__ import annotations

import os
from typing import Callable, Sequence

import jax
import numpy as np

from .graph import TaskDescriptor, normalize_outputs

__all__ = ["MAX_GRID_TASKS", "WaveKernelError", "group_signature",
           "eligibility", "interpret_mode", "infer_out_structs",
           "build_wave_kernel"]

# One pallas grid dimension per fused wave: groups larger than this take
# the XLA fallback ("grid_overflow").  The real bound is the compiler's
# grid-dimension limit (2^16 programs on current TPU lowerings); tests
# monkeypatch this down to exercise the overflow path cheaply.
MAX_GRID_TASKS = 65536


class WaveKernelError(RuntimeError):
    """A group passed eligibility but failed to lower/trace; the caller
    treats it as the ``"lowering_failed"`` fallback, never a user error."""


def group_signature(td: TaskDescriptor) -> tuple:
    """The wave-grouping key: function identity plus the *structure* of
    the footprint and the firstprivate values (shapes/dtypes, never the
    values themselves) — tasks that differ only in region contents or
    index values share one batched dispatch.

    Lives here (not on the executor) because it is the contract shared by
    three consumers that must never drift: the staged executor's group
    builder, this module's eligibility check (which assumes a group is
    structurally homogeneous and so inspects only ``group[0]``), and the
    DES's fused-wave predictor (``sim.py``)."""
    parts: list = [td.fn]
    for m in td.args:
        parts.append((type(m).__name__, m.region.shape,
                      str(m.region.array.dtype)))
    for v in td.values:
        # structure only, no device transfer on the dispatch critical
        # path; the canonical dtype (what jnp.asarray will stage the
        # value to) is the key, so a Python float and an np.float32
        # from different spawn sites still share one dispatch
        dt = jax.dtypes.canonicalize_dtype(np.result_type(v))
        parts.append(("firstprivate", np.shape(v), str(dt)))
    return tuple(parts)


def eligibility(group: Sequence[TaskDescriptor]) -> str | None:
    """Can this group lower into one fused pallas grid?  ``None`` means
    eligible; otherwise the named fallback reason recorded in
    ``RuntimeStats.kernel_fallbacks`` and the ``kernel_dispatch`` event.

    Groups come pre-homogenized by :func:`group_signature`, so structure
    checks read ``group[0]`` only.  Reasons:

    * ``"single_task"``    — a 1-task group; a fused grid buys nothing
      over the plain jitted call and TPU grids dislike degenerate dims.
    * ``"grid_overflow"``  — more tasks than :data:`MAX_GRID_TASKS`.
    * ``"non_rectangular"``— a footprint region that is not a rank-2
      rectangle of tiles; the BlockSpec tiling implemented here covers
      the paper's gemm/jacobi bodies (2-D static block footprints).
    * ``"mixed_dtype"``    — operand/output regions disagree on dtype;
      one fused kernel would need per-operand memory spaces the TPU
      lowering does not give us.
    * ``"nonscalar_firstprivate"`` — an index parameter that is not a
      scalar; scalars ride the grid as ``(n,)`` operands, arrays would
      need their own footprint analysis.
    """
    if len(group) == 1:
        return "single_task"
    if len(group) > MAX_GRID_TASKS:
        return "grid_overflow"
    td = group[0]
    dtypes = set()
    for m in td.args:
        spec = m.region.footprint_spec()
        if spec.rank != 2:
            return "non_rectangular"
        dtypes.add(spec.dtype)
    if len(dtypes) > 1:
        return "mixed_dtype"
    for v in td.values:
        if np.shape(v) != ():
            return "nonscalar_firstprivate"
    return None


def interpret_mode() -> bool:
    """Run the kernel under the Pallas interpreter?  Forced on by
    ``REPRO_PALLAS_INTERPRET=1`` (the CI CPU matrix), auto-enabled off
    TPU where no Pallas lowering exists.  Interpreted kernels execute
    the same traced ops the compiled kernel would, so the bit-exactness
    contract holds either way."""
    if os.environ.get("REPRO_PALLAS_INTERPRET", "") == "1":
        return True
    return jax.default_backend() != "tpu"


def infer_out_structs(fn: Callable, in_structs: Sequence[jax.ShapeDtypeStruct],
                      n_out: int, label: str) -> list[jax.ShapeDtypeStruct]:
    """Abstractly trace one task's body on its per-task operand structure
    to learn the output shapes/dtypes the fused kernel must declare.
    Tracing the *body* (not the region metadata) means a body whose
    result dtype differs from its output region's dtype still lowers to
    exactly what the vmap path computes — the region store converts on
    commit, identically on both paths."""
    try:
        out = jax.eval_shape(fn, *in_structs)
    except Exception as e:             # untraceable body -> XLA fallback
        raise WaveKernelError(f"eval_shape failed for {label}: {e}") from e
    outs = normalize_outputs(out, n_out, label)
    structs = []
    for o in outs:
        if not hasattr(o, "shape") or not hasattr(o, "dtype"):
            raise WaveKernelError(f"{label}: non-array output {type(o)}")
        structs.append(jax.ShapeDtypeStruct(tuple(o.shape), o.dtype))
    return structs


def _task_spec(elt_shape: tuple, pl):
    """The BlockSpec mapping grid step ``t`` onto task ``t``'s slice of a
    stacked operand: block ``(1, *elt_shape)`` at block index ``(t, 0, 0)``
    — each grid step sees exactly its own task's tiles in kernel-local
    memory.  Scalars (firstprivate indices) stack to ``(n,)`` and block
    as ``(1,)`` at index ``(t,)``."""
    if elt_shape == ():
        return pl.BlockSpec((1,), lambda t: (t,))
    zeros = (0,) * len(elt_shape)
    return pl.BlockSpec((1, *elt_shape), lambda t, _z=zeros: (t, *_z))


def build_wave_kernel(fn: Callable, n_tasks: int,
                      in_structs: Sequence[jax.ShapeDtypeStruct],
                      out_structs: Sequence[jax.ShapeDtypeStruct],
                      *, interpret: bool, label: str = "") -> Callable:
    """Lower one eligible group into a jitted fused dispatch.

    Returns ``call(*stacked_ins) -> tuple(stacked_outs)`` where every
    stacked operand/result has the task axis first (the staged stacking
    order: READS args then firstprivate values).  Inside the kernel, grid
    step ``t`` drops the unit task axis (``ref[0]``), runs the unchanged
    task body on its per-task tile views, and writes each output back
    through its own BlockSpec — one ``pallas_call`` replaces ``n_tasks``
    logical dispatches."""
    from jax.experimental import pallas as pl

    n_in = len(in_structs)
    n_out = len(out_structs)

    def kernel(*refs):
        ins = [r[0] for r in refs[:n_in]]
        res = normalize_outputs(fn(*ins), n_out, label)
        for o, v in zip(refs[n_in:], res):
            o[0] = v

    try:
        call = pl.pallas_call(
            kernel,
            grid=(n_tasks,),
            in_specs=[_task_spec(tuple(s.shape), pl) for s in in_structs],
            out_specs=[_task_spec(tuple(s.shape), pl) for s in out_structs],
            out_shape=[jax.ShapeDtypeStruct((n_tasks, *s.shape), s.dtype)
                       for s in out_structs],
            interpret=interpret,
        )
    except Exception as e:
        raise WaveKernelError(f"pallas lowering failed for {label}: {e}") \
            from e
    jitted = jax.jit(call)

    def run(*stacked):
        outs = jitted(*stacked)
        # match the task-fn return convention the group store normalizes
        # (a bare array for one output, a tuple for several)
        return outs[0] if n_out == 1 else tuple(outs)

    return run
