"""Block-structured arrays: the BDDT custom allocator, in JAX.

BDDT-SCC splits all application memory into fixed-size *blocks* via a custom
allocator; blocks are the unit of dependence analysis and of placement across
the SCC's four memory controllers.  Here an array registered with the runtime
becomes a :class:`BlockArray` — a grid of tiles.  Tiles are the dependence
unit (``deps.py``), the scheduling-affinity unit (``scheduler.py``) and the
placement unit (``placement.py``: tile -> "memory controller" / mesh device).

Residency (§3.2/§5): tiles are held behind a :class:`TileStore` backend.
The default :class:`HostTileStore` keeps plain uncommitted ``jnp`` arrays —
the single-machine path.  :class:`DeviceTileStore` makes block *homes*
physical: every tile is committed to the device serving its home
(``placement.device_assignment``), writes re-commit to the home, and reads
that cross devices are *actual* transfers — counted in the array's attached
:class:`TileTraffic` so executors can report measured (not estimated)
cross-home movement.  Assembly (``gather`` / ``Region.materialize``) is
destination-aware: tiles are pulled directly onto the device that consumes
them, never staged through an intermediate device — the paper's
"avoid large core-to-core data transfers" rule applied to the mesh.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockArray",
    "FootprintSpec",
    "Region",
    "In",
    "Out",
    "InOut",
    "AccessMode",
    "ACCESS_MODES",
    "MODE_CLASSES",
    "coerce_mode",
    "TileTraffic",
    "TileStore",
    "HostTileStore",
    "DeviceTileStore",
    "device_of",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def device_of(x):
    """The single device a *committed* jax array lives on, else None.

    Uncommitted arrays (eager results on a single-device platform) have no
    residency obligation — moving them is free in the residency model, so
    they report None and are never charged as transfers.  Committedness
    comes from the public ``jax.Array.committed`` property (private
    ``_committed`` as a fallback for older releases); if neither exists,
    a single-device array on a multi-device platform is conservatively
    treated as committed, so mixed-device assembly harmonizes instead of
    crashing inside ``jnp.block``/``stack``."""
    if not isinstance(x, jax.Array):
        return None
    committed = getattr(x, "committed", None)
    if committed is None:
        committed = getattr(x, "_committed", None)
    if committed is None:
        committed = len(jax.devices()) > 1
    if committed:
        devs = x.devices()
        if len(devs) == 1:
            return next(iter(devs))
    return None


@dataclass
class TileTraffic:
    """Measured tile movement, charged at the memory layer where transfers
    actually happen (executors read these into ``RuntimeStats``).

    * ``tile_moves`` / ``bytes_moved`` — cross-device tile transfers with a
      known destination (a consuming device or the tile's home).
    * ``bytes_staged`` — bytes harmonized onto a device *nobody declared*:
      the legacy mixed-device assembly that routes data through an
      intermediate hop.  The device-resident executors keep this at zero;
      a nonzero value means some path still stages.
    * ``bytes_local`` — reads served in place on the requesting device.
    """
    tile_moves: int = 0
    bytes_moved: int = 0
    bytes_staged: int = 0
    bytes_local: int = 0

    def reset(self) -> None:
        self.tile_moves = self.bytes_moved = 0
        self.bytes_staged = self.bytes_local = 0


def _majority_device(tiles: list):
    """The committed device holding the most of ``tiles`` (deterministic
    tie-break on device id), or None if nothing is committed."""
    counts: dict = {}
    for t in tiles:
        d = device_of(t)
        if d is not None:
            counts[d] = counts.get(d, 0) + 1
    if not counts:
        return None
    return max(sorted(counts, key=lambda d: d.id),
               key=lambda d: counts[d])


def _pull_tiles(tiles: list, device, traffic: TileTraffic | None,
                tile_nbytes: int, staged: bool = False) -> list:
    """Bring every tile to ``device`` (None = the majority device, chosen
    only when tiles are committed to *different* devices), charging the
    attached traffic recorder.  One hop per off-destination tile — assembly
    happens ON the destination, never via an intermediate device."""
    if device is None:
        devs = {device_of(t) for t in tiles} - {None}
        if len(devs) <= 1:
            return tiles                  # nothing to harmonize
        device = _majority_device(tiles)
    else:
        staged = False                    # a declared destination is final
    out = []
    for t in tiles:
        src = device_of(t)
        if src == device:
            if traffic is not None:
                traffic.bytes_local += tile_nbytes
            out.append(t)
            continue
        if src is not None and traffic is not None:
            traffic.tile_moves += 1
            traffic.bytes_moved += tile_nbytes
            if staged:
                traffic.bytes_staged += tile_nbytes
        out.append(jax.device_put(t, device))
    return out


# ---------------------------------------------------------------------------
# tile storage backends
class TileStore:
    """Where a :class:`BlockArray`'s tiles physically live.

    The base class is the host backend: a dict of plain (uncommitted) jnp
    arrays, no residency obligations, no traffic accounting — exactly the
    single-machine behavior every non-mesh executor wants.
    """

    traffic: TileTraffic | None = None

    def __init__(self):
        self._tiles: dict[tuple[int, ...], Any] = {}

    def get(self, idx: tuple[int, ...]):
        return self._tiles[idx]

    def set(self, idx: tuple[int, ...], value) -> None:
        self._tiles[idx] = value

    def device_for(self, idx: tuple[int, ...]):
        """The residency target of tile ``idx`` (None = host/uncommitted)."""
        return None

    def indices(self):
        return self._tiles.keys()


class HostTileStore(TileStore):
    """Alias backend for readability: tiles as uncommitted host arrays."""


class DeviceTileStore(TileStore):
    """Device-resident tiles: every tile is committed to the device serving
    its home (``devmap[home % ndev]``, from ``placement.device_assignment``).

    Writes re-commit to the home device — a value produced elsewhere is one
    direct transfer home (counted in ``traffic``); a value produced on the
    home (owner-computes) commits in place.  This is what makes block homes
    *real*: a multi-device wave reads each tile where it lives instead of
    shipping everything through a staging device.
    """

    def __init__(self, array: "BlockArray", devmap: Sequence,
                 traffic: TileTraffic | None = None):
        super().__init__()
        self.array = array
        self.devmap = list(devmap)
        self.traffic = traffic

    def device_for(self, idx: tuple[int, ...]):
        home = self.array.home.get(idx, 0)
        return self.devmap[home % len(self.devmap)]

    def set(self, idx: tuple[int, ...], value) -> None:
        dest = self.device_for(idx)
        src = device_of(value)
        if src is not None and src != dest and self.traffic is not None:
            self.traffic.tile_moves += 1
            self.traffic.bytes_moved += self.array.tile_nbytes
        self._tiles[idx] = jax.device_put(value, dest)


# ---------------------------------------------------------------------------
class BlockArray:
    """An N-D array stored as a grid of tiles (BDDT "blocks").

    Tiles are held behind a :class:`TileStore` so that tasks touch only the
    blocks in their declared footprint — the software analogue of the SCC's
    block allocator, where a task's footprint names exactly the DRAM blocks
    it may access.  Swapping the store (``use_store``) changes *where* the
    tiles physically live without changing any program.
    """

    _next_id = itertools.count()

    def __init__(self, shape: Sequence[int], block_shape: Sequence[int],
                 dtype=jnp.float32, name: str | None = None):
        if len(shape) != len(block_shape):
            raise ValueError("shape and block_shape rank mismatch")
        for s, b in zip(shape, block_shape):
            if s % b != 0:
                raise ValueError(
                    f"shape {tuple(shape)} not divisible by block_shape "
                    f"{tuple(block_shape)}; pad the array first (the paper's "
                    "allocator likewise pads to block multiples)")
        self.shape = tuple(int(s) for s in shape)
        self.block_shape = tuple(int(b) for b in block_shape)
        self.dtype = dtype
        self.grid = tuple(s // b for s, b in zip(self.shape, self.block_shape))
        self.array_id = next(BlockArray._next_id)
        self.name = name or f"arr{self.array_id}"
        self._store: TileStore = HostTileStore()
        # tile index tuple -> home id (memory controller / device ordinal)
        self.home: dict[tuple[int, ...], int] = {}
        # measured tile movement; the owning runtime attaches its recorder
        self.traffic: TileTraffic | None = None

    @property
    def tile_nbytes(self) -> int:
        return int(np.prod(self.block_shape)) * jnp.dtype(self.dtype).itemsize

    # -- storage backend ---------------------------------------------------
    @property
    def store(self) -> TileStore:
        return self._store

    def use_store(self, store: TileStore) -> None:
        """Swap the storage backend, migrating existing tiles.  Initial
        placement is *not* charged as traffic — tiles are being homed, not
        moved between consumers."""
        old, self._store = self._store, store
        saved, store.traffic = store.traffic, None
        try:
            for idx in list(old.indices()):
                store.set(idx, old.get(idx))
        finally:
            store.traffic = saved

    def tile_device(self, idx: tuple[int, ...]):
        """The device the stored tile is actually committed to (None for
        host/uncommitted tiles)."""
        return device_of(self._store.get(idx))

    # -- construction -----------------------------------------------------
    @classmethod
    def from_array(cls, arr, block_shape: Sequence[int],
                   name: str | None = None) -> "BlockArray":
        arr = jnp.asarray(arr)
        ba = cls(arr.shape, block_shape, arr.dtype, name=name)
        for idx in ba.block_indices():
            ba._store.set(idx, arr[ba._tile_slices(idx)])
        return ba

    @classmethod
    def full(cls, shape, block_shape, fill, dtype=jnp.float32,
             name: str | None = None) -> "BlockArray":
        ba = cls(shape, block_shape, dtype, name=name)
        tile = jnp.full(ba.block_shape, fill, dtype)
        for idx in ba.block_indices():
            ba._store.set(idx, tile)
        return ba

    @classmethod
    def zeros(cls, shape, block_shape, dtype=jnp.float32,
              name: str | None = None) -> "BlockArray":
        return cls.full(shape, block_shape, 0, dtype, name=name)

    # -- indexing ----------------------------------------------------------
    def block_indices(self) -> Iterator[tuple[int, ...]]:
        return itertools.product(*[range(g) for g in self.grid])

    def _tile_slices(self, idx: tuple[int, ...]) -> tuple[slice, ...]:
        return tuple(slice(i * b, (i + 1) * b)
                     for i, b in zip(idx, self.block_shape))

    def __getitem__(self, key) -> "Region":
        """``A[i, j]`` (one tile) or ``A[i0:i1, j]`` (tile range) -> Region.

        Indices are in *block* coordinates, exactly as OmpSs task footprints
        name array tiles.
        """
        if not isinstance(key, tuple):
            key = (key,)
        if len(key) != len(self.grid):
            raise IndexError(f"{self.name}: need {len(self.grid)} block "
                             f"indices, got {len(key)}")
        ranges = []
        for k, g in zip(key, self.grid):
            if isinstance(k, slice):
                start, stop, step = k.indices(g)
                if step != 1:
                    raise IndexError("block slices must be unit-stride")
                ranges.append(range(start, stop))
            else:
                k = int(k)
                if k < 0:
                    k += g
                if not 0 <= k < g:
                    raise IndexError(f"block index {k} out of range {g}")
                ranges.append(range(k, k + 1))
        return Region(self, tuple(ranges))

    @property
    def whole(self) -> "Region":
        return Region(self, tuple(range(g) for g in self.grid))

    # -- tile data access (used by the executors) ---------------------------
    def get_tile(self, idx: tuple[int, ...]):
        return self._store.get(idx)

    def set_tile(self, idx: tuple[int, ...], value) -> None:
        if tuple(value.shape) != self.block_shape:
            raise ValueError(
                f"{self.name}{list(idx)}: tile shape {tuple(value.shape)} != "
                f"block shape {self.block_shape}")
        self._store.set(idx, value)

    def gather(self, device=None):
        """Assemble the full array from tiles (the read-back at a barrier).

        Mixed-device tiles are assembled *on the destination* — ``device``
        if given, else the device already holding the most tiles — so each
        off-destination tile moves exactly once (no staging hop through an
        intermediate device)."""
        idxs = list(self.block_indices())
        tiles = _pull_tiles([self._store.get(idx) for idx in idxs], device,
                            self.traffic, self.tile_nbytes)
        nested = np.empty(self.grid, dtype=object)
        for idx, tile in zip(idxs, tiles):
            nested[idx] = tile
        if len(self.grid) == 1:
            return jnp.concatenate(list(nested), axis=0)
        return jnp.block(nested.tolist())

    def scatter(self, arr) -> None:
        """Overwrite all tiles from a full array."""
        arr = jnp.asarray(arr)
        if arr.shape != self.shape:
            raise ValueError("scatter shape mismatch")
        for idx in self.block_indices():
            self._store.set(idx, arr[self._tile_slices(idx)])

    def __repr__(self):
        return (f"BlockArray({self.name}, shape={self.shape}, "
                f"blocks={self.grid}x{self.block_shape}, dtype={self.dtype})")


@dataclass(frozen=True)
class FootprintSpec:
    """The static per-task tile view a wave kernel's ``BlockSpec`` is built
    from: element ``shape`` (the region's assembled extent), canonical
    ``dtype`` string, and the tile grid the region spans.  Produced by
    :meth:`Region.footprint_spec`; consumed by ``core/wavekernel.py`` for
    eligibility (rank/dtype homogeneity) and for sizing the per-task
    blocks of the fused pallas grid."""
    shape: tuple[int, ...]
    dtype: str
    tile_grid: tuple[int, ...]

    @property
    def rank(self) -> int:
        return len(self.shape)

    @property
    def n_tiles(self) -> int:
        return int(np.prod(self.tile_grid)) if self.tile_grid else 1


@dataclass(frozen=True)
class Region:
    """A rectangular range of tiles of one BlockArray — a task footprint item."""
    array: BlockArray
    ranges: tuple[range, ...]

    @property
    def block_ids(self) -> tuple[tuple[int, tuple[int, ...]], ...]:
        """Globally unique block ids: (array_id, tile index)."""
        return tuple((self.array.array_id, idx)
                     for idx in itertools.product(*self.ranges))

    @property
    def tile_indices(self) -> list[tuple[int, ...]]:
        return list(itertools.product(*self.ranges))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(r) * b
                     for r, b in zip(self.ranges, self.array.block_shape))

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.array.dtype).itemsize

    def footprint_spec(self) -> FootprintSpec:
        """The static tile-view description handed to wave-kernel
        ``BlockSpec`` construction (regions are rectangular tile ranges by
        construction, so shape/grid are exact, never bounding boxes)."""
        return FootprintSpec(self.shape, str(jnp.dtype(self.array.dtype)),
                             tuple(len(r) for r in self.ranges))

    def materialize(self, device=None):
        """Assemble this region's tiles into one array (task input value).

        ``device`` names the consuming device: tiles homed there are read
        in place, every other tile is pulled directly onto it (one hop,
        counted as a measured transfer).  Without a destination,
        mixed-device tiles harmonize onto the majority device and the
        moved bytes are charged as *staged* — the legacy double-hop the
        device-resident executors avoid by always naming the consumer."""
        idxs = self.tile_indices
        traffic = self.array.traffic
        nbytes = self.array.tile_nbytes
        if len(idxs) == 1:
            [tile] = _pull_tiles([self.array.get_tile(idxs[0])], device,
                                 traffic, nbytes, staged=True)
            return tile
        tiles = _pull_tiles([self.array.get_tile(i) for i in idxs], device,
                            traffic, nbytes, staged=True)
        grid = tuple(len(r) for r in self.ranges)
        nested = np.empty(grid, dtype=object)
        # tile_indices and the position product enumerate in the same
        # (row-major) order, so the flat tile list zips positionally
        for pos, tile in zip(itertools.product(*[range(g) for g in grid]),
                             tiles):
            nested[pos] = tile
        if len(grid) == 1:
            return jnp.concatenate(list(nested), axis=0)
        return jnp.block(nested.tolist())

    def store(self, value) -> None:
        """Split a produced value back into this region's tiles (task output).
        Each tile commits wherever the array's store homes it — for a
        device-resident store, tile-by-tile to the home device."""
        idxs = self.tile_indices
        if len(idxs) == 1:
            self.array.set_tile(idxs[0], value)
            return
        if tuple(value.shape) != self.shape:
            raise ValueError(f"store shape {tuple(value.shape)} != region "
                             f"shape {self.shape}")
        bs = self.array.block_shape
        for pos in itertools.product(*[range(len(r)) for r in self.ranges]):
            src = tuple(r[p] for r, p in zip(self.ranges, pos))
            sl = tuple(slice(p * b, (p + 1) * b) for p, b in zip(pos, bs))
            self.array.set_tile(src, value[sl])

    def __repr__(self):
        rs = ",".join(f"{r.start}:{r.stop}" if len(r) > 1 else str(r.start)
                      for r in self.ranges)
        return f"{self.array.name}[{rs}]"


class AccessMode:
    """OmpSs data-access attribute on a task argument (§3.1).

    The three concrete modes are reachable as enum-style members —
    ``AccessMode.IN`` / ``AccessMode.OUT`` / ``AccessMode.INOUT`` — and
    every API that takes a mode (``wait_on``, ``tasks_touching``, the
    ``@task(footprint=...)`` mapping form) accepts either a member or
    its plain-string spelling via :func:`coerce_mode`.
    """
    READS = False
    WRITES = False
    MODE = ""          # canonical string spelling, set on subclasses
    # enum-style member aliases, bound after the subclasses below
    IN: "type[AccessMode]"
    OUT: "type[AccessMode]"
    INOUT: "type[AccessMode]"

    def __init__(self, region: Region):
        if not isinstance(region, Region):
            raise TypeError(f"expected a Region (e.g. A[i, j]), got "
                            f"{type(region).__name__}")
        self.region = region

    def __repr__(self):
        return f"{type(self).__name__}({self.region!r})"


class In(AccessMode):
    READS = True
    MODE = "in"


class Out(AccessMode):
    WRITES = True
    MODE = "out"


class InOut(AccessMode):
    READS = True
    WRITES = True
    MODE = "inout"


AccessMode.IN = In
AccessMode.OUT = Out
AccessMode.INOUT = InOut

#: canonical mode spellings, and the class each one names
ACCESS_MODES = ("in", "out", "inout")
MODE_CLASSES: dict[str, type[AccessMode]] = {
    "in": In, "out": Out, "inout": InOut}


def coerce_mode(mode) -> str:
    """Normalize an access-mode spelling to ``"in"``/``"out"``/``"inout"``.

    Accepts the plain strings, the :class:`AccessMode` members
    (``AccessMode.IN`` — i.e. the ``In``/``Out``/``InOut`` classes), or
    an ``AccessMode`` instance; one helper so every mode-taking API
    raises the same ``ValueError`` listing the valid choices.
    """
    if isinstance(mode, type) and issubclass(mode, AccessMode):
        mode = mode.MODE
    elif isinstance(mode, AccessMode):
        mode = mode.MODE
    if mode not in MODE_CLASSES:
        raise ValueError(
            f"mode must be one of {ACCESS_MODES} (or AccessMode.IN/"
            f"OUT/INOUT), got {mode!r}")
    return mode
