"""Block-structured arrays: the BDDT custom allocator, in JAX.

BDDT-SCC splits all application memory into fixed-size *blocks* via a custom
allocator; blocks are the unit of dependence analysis and of placement across
the SCC's four memory controllers.  Here an array registered with the runtime
becomes a :class:`BlockArray` — a grid of tiles.  Tiles are the dependence
unit (``deps.py``), the scheduling-affinity unit (``scheduler.py``) and the
placement unit (``placement.py``: tile -> "memory controller" / mesh device).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BlockArray",
    "Region",
    "In",
    "Out",
    "InOut",
    "AccessMode",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _same_device(tiles: list) -> list:
    """``jnp.block``/``concatenate`` refuse operands committed to
    different devices, which happens once a mesh executor leaves each
    output tile on its owner (owner-computes); pull everything to the
    first tile's device before assembling."""
    devs = set()
    for t in tiles:
        if hasattr(t, "devices"):
            devs |= t.devices()
    if len(devs) <= 1:
        return tiles
    target = next(iter(tiles[0].devices()))
    return [jax.device_put(t, target) for t in tiles]


class BlockArray:
    """An N-D array stored as a grid of tiles (BDDT "blocks").

    Tiles are held as individual ``jnp`` arrays so that tasks touch only the
    blocks in their declared footprint — the software analogue of the SCC's
    block allocator, where a task's footprint names exactly the DRAM blocks
    it may access.
    """

    _next_id = itertools.count()

    def __init__(self, shape: Sequence[int], block_shape: Sequence[int],
                 dtype=jnp.float32, name: str | None = None):
        if len(shape) != len(block_shape):
            raise ValueError("shape and block_shape rank mismatch")
        for s, b in zip(shape, block_shape):
            if s % b != 0:
                raise ValueError(
                    f"shape {tuple(shape)} not divisible by block_shape "
                    f"{tuple(block_shape)}; pad the array first (the paper's "
                    "allocator likewise pads to block multiples)")
        self.shape = tuple(int(s) for s in shape)
        self.block_shape = tuple(int(b) for b in block_shape)
        self.dtype = dtype
        self.grid = tuple(s // b for s, b in zip(self.shape, self.block_shape))
        self.array_id = next(BlockArray._next_id)
        self.name = name or f"arr{self.array_id}"
        # tile index tuple -> jnp array of block_shape
        self._tiles: dict[tuple[int, ...], Any] = {}
        # tile index tuple -> home id (memory controller / device ordinal)
        self.home: dict[tuple[int, ...], int] = {}

    # -- construction -----------------------------------------------------
    @classmethod
    def from_array(cls, arr, block_shape: Sequence[int],
                   name: str | None = None) -> "BlockArray":
        arr = jnp.asarray(arr)
        ba = cls(arr.shape, block_shape, arr.dtype, name=name)
        for idx in ba.block_indices():
            ba._tiles[idx] = arr[ba._tile_slices(idx)]
        return ba

    @classmethod
    def full(cls, shape, block_shape, fill, dtype=jnp.float32,
             name: str | None = None) -> "BlockArray":
        ba = cls(shape, block_shape, dtype, name=name)
        tile = jnp.full(ba.block_shape, fill, dtype)
        for idx in ba.block_indices():
            ba._tiles[idx] = tile
        return ba

    @classmethod
    def zeros(cls, shape, block_shape, dtype=jnp.float32,
              name: str | None = None) -> "BlockArray":
        return cls.full(shape, block_shape, 0, dtype, name=name)

    # -- indexing ----------------------------------------------------------
    def block_indices(self) -> Iterator[tuple[int, ...]]:
        return itertools.product(*[range(g) for g in self.grid])

    def _tile_slices(self, idx: tuple[int, ...]) -> tuple[slice, ...]:
        return tuple(slice(i * b, (i + 1) * b)
                     for i, b in zip(idx, self.block_shape))

    def __getitem__(self, key) -> "Region":
        """``A[i, j]`` (one tile) or ``A[i0:i1, j]`` (tile range) -> Region.

        Indices are in *block* coordinates, exactly as OmpSs task footprints
        name array tiles.
        """
        if not isinstance(key, tuple):
            key = (key,)
        if len(key) != len(self.grid):
            raise IndexError(f"{self.name}: need {len(self.grid)} block "
                             f"indices, got {len(key)}")
        ranges = []
        for k, g in zip(key, self.grid):
            if isinstance(k, slice):
                start, stop, step = k.indices(g)
                if step != 1:
                    raise IndexError("block slices must be unit-stride")
                ranges.append(range(start, stop))
            else:
                k = int(k)
                if k < 0:
                    k += g
                if not 0 <= k < g:
                    raise IndexError(f"block index {k} out of range {g}")
                ranges.append(range(k, k + 1))
        return Region(self, tuple(ranges))

    @property
    def whole(self) -> "Region":
        return Region(self, tuple(range(g) for g in self.grid))

    # -- tile data access (used by the executors) ---------------------------
    def get_tile(self, idx: tuple[int, ...]):
        return self._tiles[idx]

    def set_tile(self, idx: tuple[int, ...], value) -> None:
        if tuple(value.shape) != self.block_shape:
            raise ValueError(
                f"{self.name}{list(idx)}: tile shape {tuple(value.shape)} != "
                f"block shape {self.block_shape}")
        self._tiles[idx] = value

    def gather(self):
        """Assemble the full array from tiles (the read-back at a barrier)."""
        idxs = list(self.block_indices())
        tiles = _same_device([self._tiles[idx] for idx in idxs])
        nested = np.empty(self.grid, dtype=object)
        for idx, tile in zip(idxs, tiles):
            nested[idx] = tile
        if len(self.grid) == 1:
            return jnp.concatenate(list(nested), axis=0)
        return jnp.block(nested.tolist())

    def scatter(self, arr) -> None:
        """Overwrite all tiles from a full array."""
        arr = jnp.asarray(arr)
        if arr.shape != self.shape:
            raise ValueError("scatter shape mismatch")
        for idx in self.block_indices():
            self._tiles[idx] = arr[self._tile_slices(idx)]

    def __repr__(self):
        return (f"BlockArray({self.name}, shape={self.shape}, "
                f"blocks={self.grid}x{self.block_shape}, dtype={self.dtype})")


@dataclass(frozen=True)
class Region:
    """A rectangular range of tiles of one BlockArray — a task footprint item."""
    array: BlockArray
    ranges: tuple[range, ...]

    @property
    def block_ids(self) -> tuple[tuple[int, tuple[int, ...]], ...]:
        """Globally unique block ids: (array_id, tile index)."""
        return tuple((self.array.array_id, idx)
                     for idx in itertools.product(*self.ranges))

    @property
    def tile_indices(self) -> list[tuple[int, ...]]:
        return list(itertools.product(*self.ranges))

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(r) * b
                     for r, b in zip(self.ranges, self.array.block_shape))

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.array.dtype).itemsize

    def materialize(self):
        """Assemble this region's tiles into one array (task input value)."""
        idxs = self.tile_indices
        if len(idxs) == 1:
            return self.array.get_tile(idxs[0])
        tiles = _same_device([self.array.get_tile(i) for i in idxs])
        grid = tuple(len(r) for r in self.ranges)
        nested = np.empty(grid, dtype=object)
        # tile_indices and the position product enumerate in the same
        # (row-major) order, so the flat tile list zips positionally
        for pos, tile in zip(itertools.product(*[range(g) for g in grid]),
                             tiles):
            nested[pos] = tile
        if len(grid) == 1:
            return jnp.concatenate(list(nested), axis=0)
        return jnp.block(nested.tolist())

    def store(self, value) -> None:
        """Split a produced value back into this region's tiles (task output)."""
        idxs = self.tile_indices
        if len(idxs) == 1:
            self.array.set_tile(idxs[0], value)
            return
        if tuple(value.shape) != self.shape:
            raise ValueError(f"store shape {tuple(value.shape)} != region "
                             f"shape {self.shape}")
        bs = self.array.block_shape
        for pos in itertools.product(*[range(len(r)) for r in self.ranges]):
            src = tuple(r[p] for r, p in zip(self.ranges, pos))
            sl = tuple(slice(p * b, (p + 1) * b) for p, b in zip(pos, bs))
            self.array.set_tile(src, value[sl])

    def __repr__(self):
        rs = ",".join(f"{r.start}:{r.stop}" if len(r) > 1 else str(r.start)
                      for r in self.ranges)
        return f"{self.array.name}[{rs}]"


class AccessMode:
    """OmpSs data-access attribute on a task argument (§3.1)."""
    READS = False
    WRITES = False

    def __init__(self, region: Region):
        if not isinstance(region, Region):
            raise TypeError(f"expected a Region (e.g. A[i, j]), got "
                            f"{type(region).__name__}")
        self.region = region

    def __repr__(self):
        return f"{type(self).__name__}({self.region!r})"


class In(AccessMode):
    READS = True


class Out(AccessMode):
    WRITES = True


class InOut(AccessMode):
    READS = True
    WRITES = True
