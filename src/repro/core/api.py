"""The declarative OmpSs-style front-end: ``@task`` footprint decorators,
firstprivate value parameters, task futures, and runtime configuration.

The paper's programming model is a pragma on the *function*: each argument
is annotated ``in`` / ``out`` / ``inout`` once, and every call site spawns
a task whose footprint the runtime synchronizes automatically.  This module
is that front-end in Python::

    from repro.core import TaskRuntime, task

    @task(inout="c", in_=("a", "b"))
    def gemm(c, a, b):
        return c + a @ b

    with TaskRuntime(executor="staged") as rt:
        A = rt.from_array(a, (64, 64))
        B = rt.from_array(b, (64, 64))
        C = rt.zeros((n, n), (64, 64))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    gemm(C[i, j], A[i, k], B[k, j])   # spawns a task
        rt.wait_on(C[0, 0])        # region-scoped taskwait (§3.3 sync)
        ...                        # exit barrier drains the rest

Scalar parameters — tile offsets, iteration indices, coefficients — are
declared ``firstprivate`` (OmpSs's by-value capture) and bound at the spawn
site like any other argument; the value is copied into the task descriptor,
never synchronized on::

    @task(in_="halo", out="dest", firstprivate=("r0", "c0"))
    def stencil(halo, r0, c0, dest=None):
        return jax.lax.dynamic_slice(step(halo), (r0, c0), (T, T))

    stencil(S[i0:i1, j0:j1], r0, c0, D[i, j])   # r0/c0 ride in the task

Because the function object is shared across spawn sites (no per-value
closures), the staged executor batches same-shape instances of a wavefront
into one ``jit(vmap(fn))`` dispatch, stacking the firstprivate values as
extra vmap operands.

Calling a decorated function *outside* a runtime scope (or from a worker
thread) with plain arrays runs it eagerly — the decorated function is its
own serial-elision reference.

Spawns return a :class:`TaskFuture`; ``future.result()`` forces only that
task's dependence cone, not the whole graph.  :class:`RuntimeConfig`
gathers what used to be nine ``TaskRuntime.__init__`` kwargs, and
:class:`RuntimeStats` is the typed replacement for the old ``stats()``
dict (the dict-style access window has closed; use attributes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import enum
import inspect
import threading
from dataclasses import dataclass
from typing import Callable

import jax
import numpy as np

from .blocks import (AccessMode, BlockArray, In, InOut, MODE_CLASSES, Out,
                     Region, coerce_mode)
from .graph import TaskDescriptor

__all__ = ["task", "TaskFn", "TaskFuture", "RuntimeConfig", "RuntimeStats",
           "STATS_SCHEMA", "current_runtime", "wait_on",
           "ExecutorKind", "DepManagerKind", "DepPumpKind",
           "SchedulingPolicy", "PlacementKind", "KernelBackend",
           "EXECUTORS", "DEP_MANAGERS", "DEP_PUMPS",
           "SCHEDULING_POLICIES", "PLACEMENTS", "KERNEL_BACKENDS"]


# ---------------------------------------------------------------------------
# the ambient runtime scope (``with rt:``)
_scope = threading.local()


def current_runtime():
    """The innermost active ``TaskRuntime`` on this thread, or None.

    Worker threads never see a scope (it is thread-local), so a task body
    that calls another ``@task`` function runs it eagerly instead of
    recursively spawning — master-only task initiation, as in the paper.
    """
    stack = getattr(_scope, "stack", None)
    return stack[-1] if stack else None


def _push_runtime(rt) -> None:
    stack = getattr(_scope, "stack", None)
    if stack is None:
        stack = _scope.stack = []
    stack.append(rt)


def _pop_runtime(rt) -> None:
    stack = getattr(_scope, "stack", [])
    if not stack or stack[-1] is not rt:
        raise RuntimeError("runtime scope exited out of order")
    stack.pop()


@contextlib.contextmanager
def suspend_runtime_scope():
    """Mask the ambient scope while a task body executes.

    Sequential and staged executors run task bodies on the master
    thread, where the spawning scope is still active; without masking, a
    body that calls another ``@task`` function would recursively spawn
    there but run eagerly on a host worker — same program, different
    executors, different behavior.  Masking restores master-only task
    initiation everywhere."""
    stack = getattr(_scope, "stack", None)
    saved = stack[:] if stack else []
    if stack:
        stack.clear()
    try:
        yield
    finally:
        if saved:
            stack = getattr(_scope, "stack", None)
            if stack is None:
                stack = _scope.stack = []
            stack[:] = saved


def wait_on(*regions, mode="in"):
    """Region-scoped taskwait on the ambient runtime (§3.3 sync).

    The module-level spelling of ``rt.wait_on`` for code inside a
    ``with rt:`` scope: blocks until every task whose footprint
    conflicts with ``regions`` under ``mode`` has completed.  ``mode``
    accepts ``"in"``/``"out"``/``"inout"`` or an ``AccessMode`` member
    (``AccessMode.IN`` waits for writers only; ``OUT``/``INOUT`` wait
    for readers too).
    """
    rt = current_runtime()
    if rt is None:
        raise RuntimeError(
            "wait_on: no active runtime scope — call it inside "
            "`with rt:` (or use rt.wait_on(...) on a runtime directly)")
    return rt.wait_on(*regions, mode=mode)


# ---------------------------------------------------------------------------
# configuration choices — every stringly-typed ``RuntimeConfig`` field is
# backed by exactly one enum here; ``validate()``, the executor factory,
# the registries (``scheduler.POLICIES``, ``placement.PLACEMENTS``) and
# the docs all read the same lists, so they cannot drift.  Members are
# ``str`` subclasses: ``ExecutorKind.HOST == "host"``, hashes like the
# plain string, and formats as the bare value — plain strings keep
# working everywhere an enum is accepted.
class _ChoiceEnum(str, enum.Enum):
    def __str__(self) -> str:
        return self.value


class ExecutorKind(_ChoiceEnum):
    """``RuntimeConfig.executor`` — which execution engine runs tasks."""
    SEQUENTIAL = "sequential"
    HOST = "host"
    STAGED = "staged"
    SIM = "sim"
    SHARDED = "sharded"


class DepManagerKind(_ChoiceEnum):
    """``RuntimeConfig.dep_manager`` — central analyzer vs per-home
    sharded managers (bit-identical schedules)."""
    CENTRAL = "central"
    SHARDED = "sharded"


class DepPumpKind(_ChoiceEnum):
    """``RuntimeConfig.dep_pump`` — how sharded home managers are
    pumped: inline on the master (``sync``), on per-home worker threads
    (``threaded``), or resolved from ``REPRO_DEPMAN_THREADS`` at runtime
    construction (``auto``, the default).  Bit-identical schedules and
    dependence counts either way."""
    AUTO = "auto"
    SYNC = "sync"
    THREADED = "threaded"


class SchedulingPolicy(_ChoiceEnum):
    """``RuntimeConfig.policy`` — running-mode ready-queue policy (§3.4)."""
    ROUND_ROBIN = "round_robin"
    LOCALITY = "locality"
    RANDOM = "random"


class PlacementKind(_ChoiceEnum):
    """``RuntimeConfig.placement`` — block → memory-controller map."""
    SINGLE = "single"
    STRIPED = "striped"
    STRIPED_DIAG = "striped_diag"
    STRIPED_ROWS = "striped_rows"


class KernelBackend(_ChoiceEnum):
    """``RuntimeConfig.kernel_backend`` — grouped-wave dispatch path."""
    XLA = "xla"
    PALLAS = "pallas"


EXECUTORS = tuple(m.value for m in ExecutorKind)
DEP_MANAGERS = tuple(m.value for m in DepManagerKind)
DEP_PUMPS = tuple(m.value for m in DepPumpKind)
SCHEDULING_POLICIES = tuple(m.value for m in SchedulingPolicy)
PLACEMENTS = tuple(m.value for m in PlacementKind)
KERNEL_BACKENDS = tuple(m.value for m in KernelBackend)

_EXECUTORS = EXECUTORS        # pre-redesign private alias


def _check_choice(field: str, value, choices: tuple[str, ...]) -> str:
    """Validate one choice field; enum members normalize to their value."""
    if isinstance(value, _ChoiceEnum):
        value = value.value
    if value not in choices:
        raise ValueError(f"{field} must be one of {choices}, "
                         f"got {value!r}")
    return value


@dataclass(frozen=True)
class RuntimeConfig:
    """Everything that shapes a :class:`~repro.core.TaskRuntime`.

    Every choice field accepts the plain string or the matching typed
    member — :class:`ExecutorKind`, :class:`DepManagerKind`,
    :class:`SchedulingPolicy`, :class:`PlacementKind`,
    :class:`KernelBackend` — and ``validate()`` normalizes members to
    their string values, so the two spellings configure identical
    runtimes.  The valid values below are the enum members, verbatim.

    * ``executor``    — "sequential" (serial-elision oracle), "host" (the
      paper's dynamic master/worker protocol), "staged" (wavefront
      batching), "sim" (timing-only DES on the SCC cost model) or
      "sharded" (staged wavefronts placed home-aware on the ambient
      ``repro.dist`` mesh, owner-computes; degrades to the staged path on
      a single device).
    * ``n_workers`` / ``mpb_slots`` — worker count and per-worker MPB ring
      depth (§3.2).
    * ``pool_capacity`` — pre-allocated task-descriptor pool (§3.3).
    * ``dep_manager`` — "central" (one master-side
      ``DependenceAnalyzer``, the paper's §3.3 loop) or "sharded"
      (``ShardedDependenceManager``: one manager per block home —
      ``n_controllers`` of them — admitting footprint slices
      independently, with dep_query/dep_grant/release messages over
      MPB-style channels).  Both produce bit-identical schedules; sharded
      removes the global admission bottleneck and is charged as message
      traffic by the DES.
    * ``dep_pump``    — sharded manager pumping: ``"sync"`` (the master
      services manager inboxes inline at sync points), ``"threaded"``
      (each home manager runs on a pump worker thread; the master is a
      pure producer posting envelopes and draining grant rings) or
      ``"auto"`` (the default: threaded iff ``REPRO_DEPMAN_THREADS``
      is a positive integer, which also caps the thread count).  All
      modes are bit-identical in schedules, numerics and dependence
      counts; ignored under ``dep_manager="central"``.
    * ``dep_batch_lines`` — envelope capacity of the sharded manager's
      descriptor batching, in 32-byte MPB lines (2 descriptors per
      line).  Logical ``dep_query``/``release`` descriptors bound for
      one home coalesce into a single multi-descriptor ``DepMessage``
      flushed at wave boundaries and on ring pressure; managers answer
      one grant envelope per query envelope.  ``1`` disables coalescing
      (one descriptor per envelope, the pre-batching wire traffic);
      the default is 4 lines (8 descriptor slots per envelope).
    * ``policy``      — running-mode scheduling policy (§3.4).
    * ``placement`` / ``n_controllers`` — block -> memory-controller map;
      the sharded executor reuses the same homes as mesh-device homes.
    * ``owner_skew_threshold`` — sharded executor: contention-aware owner
      override (0 = off, the default).  When one home owns more than
      ``threshold x mean`` of a wave group's tasks, the surplus spills to
      the least-loaded home (``placement.rebalance_owners``), trading an
      extra counted output transfer against serializing the wave behind
      one home — the paper's Fig 4 contention, dodged at schedule time.
    * ``group_waves`` — staged/sharded executors: fuse identical tile
      tasks of a wavefront into one batched dispatch.
    * ``kernel_backend`` — how a grouped wave dispatches: ``"xla"`` (the
      default vmap/shard_map path) or ``"pallas"`` (lower each eligible
      group into one fused ``pl.pallas_call`` whose grid axis is the task
      axis — ``core/wavekernel.py``, the §3.2 on-chip staging analogue).
      Ineligible groups automatically fall back to the XLA path; the
      runtime counts them in ``RuntimeStats.kernel_fallbacks`` and tags
      each decision with a ``kernel_dispatch`` tracker event.  The sim
      executor uses the same eligibility to predict which waves fuse and
      charges their write-back traffic at on-chip (MPB) cost.
    * ``sim_cost_fn`` — "sim" executor: ``td -> (flops, bytes)``; the
      descriptor carries the task's footprint *and* its firstprivate
      ``values``, so costs may depend on index parameters.  Defaults to
      :class:`repro.core.sim.FlopcountCost` — exact jaxpr flop/byte
      accounting of the traced kernel body plus the footprint's DRAM
      traffic (falls back to a footprint-derived estimate for bodies
      that cannot be abstractly traced).
    * ``sim_params`` — "sim" executor: the
      :class:`~repro.core.costmodel.SCCParams` the DES runs on; None
      means the uncalibrated defaults (``repro.core.calibrate.calibrate``
      produces a fitted instance).
    * ``tracker`` — the observability sink (``repro.obs``): None (off,
      the default — zero event overhead), a spec string (``"memory"``,
      ``"console"``, ``"jsonl"``, ``"jsonl:PATH"``) or a ready
      ``Tracker`` instance (caller-owned, shareable across runtimes).
      Every executor reports the same per-wave event schema through it.
    * ``profile_waves`` — wrap each staged/sharded wave dispatch in a
      ``jax.profiler.TraceAnnotation`` so device profiles name waves.
    * ``worker_cache_tiles`` — host executor: per-worker pinned tile
      cache capacity (entries of assembled region operands, validated by
      tile identity; 0 disables).  Hit/miss counters surface in
      ``RuntimeStats.worker_cache_hits/misses`` and as ``tile_cache``
      tracker events.
    """
    executor: str | ExecutorKind = "host"
    n_workers: int = 4
    mpb_slots: int = 16
    pool_capacity: int = 4096
    dep_manager: str | DepManagerKind = "central"
    dep_pump: str | DepPumpKind = "auto"
    dep_batch_lines: int = 4
    policy: str | SchedulingPolicy = "round_robin"
    placement: str | PlacementKind = "striped"
    n_controllers: int = 4
    owner_skew_threshold: float = 0.0
    group_waves: bool = True
    kernel_backend: str | KernelBackend = "xla"
    seed: int = 0
    sim_cost_fn: Callable | None = None
    sim_params: object | None = None
    tracker: object | None = None
    profile_waves: bool = False
    worker_cache_tiles: int = 64

    #: choice field → (enum type, canonical values); the single source
    #: the validator, the snapshot test, and the docs table all read
    CHOICES = {
        "executor": (ExecutorKind, EXECUTORS),
        "dep_manager": (DepManagerKind, DEP_MANAGERS),
        "dep_pump": (DepPumpKind, DEP_PUMPS),
        "policy": (SchedulingPolicy, SCHEDULING_POLICIES),
        "placement": (PlacementKind, PLACEMENTS),
        "kernel_backend": (KernelBackend, KERNEL_BACKENDS),
    }

    def validate(self) -> "RuntimeConfig":
        """Check every field and return a normalized copy: enum members
        in choice fields come back as their plain-string values, so the
        runtime internals only ever see canonical strings."""
        norm = {fld: _check_choice(fld, getattr(self, fld), choices)
                for fld, (_, choices) in self.CHOICES.items()}
        cfg = self if all(norm[f] == getattr(self, f) and
                          not isinstance(getattr(self, f), _ChoiceEnum)
                          for f in norm) \
            else dataclasses.replace(self, **norm)
        for fld in ("n_workers", "mpb_slots", "pool_capacity",
                    "n_controllers", "dep_batch_lines"):
            if getattr(cfg, fld) < 1:
                raise ValueError(f"{fld} must be >= 1")
        if cfg.owner_skew_threshold < 0:
            raise ValueError("owner_skew_threshold must be >= 0 (0 = off)")
        if cfg.worker_cache_tiles < 0:
            raise ValueError("worker_cache_tiles must be >= 0 (0 = off)")
        if isinstance(cfg.tracker, str):
            from repro.obs.tracker import validate_spec
            validate_spec(cfg.tracker)
        elif cfg.tracker is not None and \
                not hasattr(cfg.tracker, "emit"):
            raise ValueError("tracker must be a spec string, a Tracker "
                             "instance, or None")
        return cfg

    def replace(self, **overrides) -> "RuntimeConfig":
        return dataclasses.replace(self, **overrides)


# ---------------------------------------------------------------------------
# statistics
STATS_SCHEMA = "bddt-scc-stats/1"


@dataclass
class RuntimeStats:
    """Typed runtime instrumentation (was: an ad-hoc ``stats()`` dict;
    the dict-style ``stats[...]``/``.get`` window closed after the
    benchmarks moved to attribute access — use the fields, or
    ``as_dict()`` for serialization).

    Core counters always present; executor-specific fields are None when
    the executor does not produce them.
    """
    tasks_spawned: int = 0
    tasks_scheduled: int = 0
    polling_rounds: int = 0
    blocks_walked: int = 0
    deps_found: int = 0
    spawn_time_s: float = 0.0
    barrier_time_s: float = 0.0
    wait_time_s: float = 0.0
    region_waits: int = 0
    futures_resolved: int = 0
    mpb_full_rejections: int = 0
    # host executor
    worker_busy_s: list[float] | None = None
    worker_tasks: list[int] | None = None
    # host executor: per-worker pinned tile-cache counters (None unless
    # the host executor ran; all-zero hits when the cache is disabled)
    worker_cache_hits: list[int] | None = None
    worker_cache_misses: list[int] | None = None
    # staged / sharded executors
    waves: int | None = None
    grouped_dispatches: int | None = None
    # wave-kernel backend (kernel_backend="pallas"): groups fused into one
    # pallas grid vs groups that took the XLA fallback (both None under
    # kernel_backend="xla", where the layer is inert).  The sim executor
    # fills the same fields with its *predicted* fuse/fallback split.
    kernel_dispatches: int | None = None
    kernel_fallbacks: int | None = None
    # sharded executor: owner-computes traffic accounting (§4.1-§4.2
    # generalized — cross-home bytes are what the DES charges contention
    # for) plus how many grouped dispatches went through the
    # shard_map/vmap hybrid
    sharded_dispatches: int | None = None
    cross_home_bytes: int | None = None
    local_home_bytes: int | None = None
    owner_overrides: int | None = None
    # residency accounting, measured at the memory layer (``TileTraffic``)
    # and shared by every executor: actual cross-device tile transfers,
    # not footprint estimates.  ``bytes_staged`` counts bytes harmonized
    # through a device nobody declared (the legacy staging hop) — the
    # device-resident sharded path keeps it at zero.  Under the
    # timing-only sim executor ``tile_moves`` is the DES's *predicted*
    # count of cross-home block fetches for the same footprints.
    tile_moves: int | None = None
    bytes_moved: int | None = None
    bytes_staged: int | None = None
    # sharded dependence manager: total dep_query/dep_grant/release
    # messages over the MPB channels, and per-manager admission counts
    # (None under the central analyzer).  ``dep_messages`` counts
    # *logical* descriptors regardless of batching; ``dep_batches`` the
    # multi-descriptor envelopes actually sent (== dep_messages when
    # ``dep_batch_lines=1``, strictly fewer when batching engages);
    # ``dep_lines`` the 32-byte MPB lines those envelopes occupied;
    # ``pump_wall_s`` the wall seconds spent inside manager servicing
    # (pump-thread busy time under dep_pump="threaded", the master's
    # inline service time under "sync")
    dep_messages: int | None = None
    dep_batches: int | None = None
    dep_lines: int | None = None
    pump_wall_s: float | None = None
    manager_admissions: list[int] | None = None
    # serving admission controller (``repro.serve``): request counters
    # and the in-flight footprint high-water mark against the byte
    # budget.  All None unless a ``Session`` attached an
    # ``AdmissionController`` to the runtime; the invariant
    # ``submitted == admitted + rejected`` holds once the session
    # closes (still-queued requests resolve to rejected).
    admission_submitted: int | None = None
    admission_admitted: int | None = None
    admission_rejected: int | None = None
    admission_deferred: int | None = None
    admission_peak_bytes: int | None = None
    admission_budget_bytes: int | None = None
    # sim executor
    predicted_total_s: float | None = None

    def as_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    # -- the stable serialization schema (``bddt-scc-stats/1``) ----------
    # One schema shared by ``to_json``, the tracker's ``stats`` event
    # payload (``ConsoleTracker`` summarizes it), and the benchmark
    # report's table input — so consumers stop reaching into attributes
    # ad hoc and a field rename is a schema decision, not an accident.
    def to_dict(self) -> dict:
        """The schema-tagged dict (None fields dropped; absent = None on
        the way back in, so the round-trip is exact)."""
        return {"schema": STATS_SCHEMA, **self.as_dict()}

    def to_json(self) -> str:
        import json
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "RuntimeStats":
        d = dict(d)
        schema = d.pop("schema", None)
        if schema != STATS_SCHEMA:
            raise ValueError(f"stats schema is {schema!r}, "
                             f"expected {STATS_SCHEMA!r}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"unknown RuntimeStats fields {unknown} "
                             f"(schema {STATS_SCHEMA})")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RuntimeStats":
        import json
        return cls.from_dict(json.loads(s))

    @property
    def spawn_us_per_task(self) -> float:
        if not self.tasks_spawned:
            return 0.0
        return 1e6 * self.spawn_time_s / self.tasks_spawned


# ---------------------------------------------------------------------------
# futures
class TaskFuture:
    """A handle on one spawned task.

    ``result()`` synchronizes on *this task only*: the executor runs (or
    waits for) the task's dependence cone and leaves every unrelated
    pending task alone, then returns the task's output value(s) — one
    array per ``out``/``inout`` argument, in argument order.
    """

    __slots__ = ("_rt", "_td")

    def __init__(self, rt, td: TaskDescriptor):
        self._rt = rt
        self._td = td

    # -- introspection ------------------------------------------------------
    @property
    def descriptor(self) -> TaskDescriptor:
        return self._td

    @property
    def tid(self) -> int:
        return self._td.tid

    @property
    def name(self) -> str:
        return self._td.name or self._td.fn.__name__

    @property
    def exec_order(self) -> int | None:
        return self._td.exec_order

    def done(self) -> bool:
        """True once the task executed (its outputs are in place)."""
        return self._td.is_complete

    # -- synchronization ----------------------------------------------------
    def wait(self) -> "TaskFuture":
        """Block until done, forcing only this task's dependence cone."""
        if not self._td.is_complete:
            self._rt._wait_tasks([self._td], kind="future")
        return self

    def result(self):
        """Wait, then return the value(s) *this task* produced.

        Outputs are captured at execution, so the result is deterministic
        across executors and immune to later writers overwriting the same
        region (read the region itself for current-memory semantics)."""
        self.wait()
        outs = self._td.output_values
        if outs is None:
            raise RuntimeError(
                f"task {self.name}#{self.tid} completed without captured "
                "outputs — executor='sim' is timing-only and never "
                "computes task values")
        if not outs:
            return None
        return outs[0] if len(outs) == 1 else tuple(outs)

    def __repr__(self):
        return f"<TaskFuture {self.name}#{self.tid} " \
               f"{'done' if self.done() else 'pending'}>"


# ---------------------------------------------------------------------------
# the @task decorator
def _names(arg) -> tuple[str, ...]:
    if arg is None:
        return ()
    if isinstance(arg, str):
        return (arg,)
    return tuple(arg)


def _is_numeric_value(v) -> bool:
    """True for the by-value types every executor accepts: Python/NumPy/JAX
    numeric scalars and arrays (bool, int, uint, float, complex kinds)."""
    if isinstance(v, (bool, int, float, complex)):
        return True
    if isinstance(v, (np.ndarray, np.generic, jax.Array)):
        return np.dtype(v.dtype).kind in "biufc"
    return False


def as_region(value, param: str) -> Region:
    if isinstance(value, Region):
        return value
    if isinstance(value, BlockArray):
        return value.whole
    if isinstance(value, AccessMode):
        raise TypeError(
            f"parameter {param!r}: pass the region directly (e.g. A[i, j]) "
            "— the @task decorator already declares the access mode")
    raise TypeError(
        f"parameter {param!r}: expected a Region (e.g. A[i, j]) or "
        f"BlockArray, got {type(value).__name__}")


class TaskFn:
    """A function with a declared footprint; calling it spawns a task.

    Footprint parameters (``in_``/``out``/``inout``) receive block regions
    at spawn sites and are what the runtime synchronizes on; firstprivate
    parameters receive plain values that are copied into the descriptor
    (OmpSs by-value capture) and handed to the body at execution.
    """

    def __init__(self, fn: Callable, in_=(), out=(), inout=(),
                 firstprivate=()):
        self.fn = fn
        self.__name__ = fn.__name__
        self.__doc__ = fn.__doc__
        self.__wrapped__ = fn
        self._sig = inspect.signature(fn)
        modes: dict[str, type[AccessMode]] = {}
        for names, mode in ((_names(in_), In), (_names(out), Out),
                            (_names(inout), InOut)):
            for n in names:
                if n in modes:
                    raise ValueError(
                        f"@task({fn.__name__}): parameter {n!r} declared "
                        "in more than one footprint list")
                if n not in self._sig.parameters:
                    raise ValueError(
                        f"@task({fn.__name__}): no parameter named {n!r} "
                        f"(has {tuple(self._sig.parameters)})")
                modes[n] = mode
        fp_set: set[str] = set()
        for n in _names(firstprivate):
            if n in modes or n in fp_set:
                raise ValueError(
                    f"@task({fn.__name__}): parameter {n!r} declared "
                    "both firstprivate and in a footprint list"
                    if n in modes else
                    f"@task({fn.__name__}): firstprivate parameter {n!r} "
                    "declared twice")
            if n not in self._sig.parameters:
                raise ValueError(
                    f"@task({fn.__name__}): no parameter named {n!r} "
                    f"(has {tuple(self._sig.parameters)})")
            fp_set.add(n)
        # params without a footprint or firstprivate declaration must
        # carry defaults (closure-capture idiom, e.g. ``def f(x,
        # dest=None, _i=i)``); they are never bound at spawn sites
        missing = [n for n, p in self._sig.parameters.items()
                   if n not in modes and n not in fp_set
                   and p.default is inspect.Parameter.empty]
        if missing:
            raise ValueError(
                f"@task({fn.__name__}): every required parameter needs a "
                f"footprint (in_/out/inout) or a firstprivate "
                f"declaration; missing {missing}")
        if not any(m.WRITES for m in modes.values()):
            raise ValueError(
                f"@task({fn.__name__}): at least one out/inout parameter "
                "is required (tasks communicate through their footprints)")
        # argument order == parameter order, the TaskDescriptor contract:
        # at execution the runtime calls fn(*reads_values, *values), so
        # the READS params (in_/inout) must be exactly the leading
        # positional params, firstprivate params must directly follow
        # them, and everything after (out-only params, closure captures)
        # must carry defaults since it receives no value
        params = list(self._sig.parameters)
        reads = [n for n in params if n in modes and modes[n].READS]
        if params[:len(reads)] != reads:
            raise ValueError(
                f"@task({fn.__name__}): in_/inout parameters must come "
                f"first in the signature (the task body receives their "
                f"values positionally); got order {params}")
        fp = [n for n in params if n in fp_set]
        if params[len(reads):len(reads) + len(fp)] != fp:
            raise ValueError(
                f"@task({fn.__name__}): firstprivate parameters must "
                f"directly follow the in_/inout parameters (the task "
                f"body receives their values positionally); got order "
                f"{params}")
        for n in params[len(reads) + len(fp):]:
            if self._sig.parameters[n].default is inspect.Parameter.empty:
                raise ValueError(
                    f"@task({fn.__name__}): parameter {n!r} receives no "
                    f"value at execution (it is not in_/inout/"
                    f"firstprivate) and must declare a default, "
                    f"e.g. {n}=None")
        self.modes = {n: modes[n] for n in params if n in modes}
        self.firstprivate = tuple(fp)

    def _bind_values(self, bound) -> tuple:
        """The firstprivate values of one spawn, in parameter order."""
        values = []
        for n in self.firstprivate:
            if n in bound.arguments:
                v = bound.arguments[n]
            else:
                v = self._sig.parameters[n].default
                if v is inspect.Parameter.empty:
                    raise TypeError(
                        f"{self.__name__}: firstprivate parameter {n!r} "
                        f"needs a value at the call site (or a default "
                        f"in the signature)")
            if isinstance(v, (Region, BlockArray, AccessMode)):
                raise TypeError(
                    f"{self.__name__}: firstprivate parameter {n!r} is "
                    f"passed by value, got {type(v).__name__} — block "
                    "regions belong in in_/out/inout footprints")
            if not _is_numeric_value(v):
                # reject at the spawn site, uniformly across executors —
                # a non-numeric value would only blow up later inside the
                # staged executor's jit/vmap tracing, far from this call
                raise TypeError(
                    f"{self.__name__}: firstprivate parameter {n!r} must "
                    f"be a numeric scalar or array (it is staged through "
                    f"jit/vmap), got {type(v).__name__}")
            if type(v) is int:
                info = np.iinfo(jax.dtypes.canonicalize_dtype(np.int64))
                if not info.min <= v <= info.max:
                    raise TypeError(
                        f"{self.__name__}: firstprivate parameter {n!r} "
                        f"value {v} overflows the canonical JAX integer "
                        f"dtype {np.dtype(info.dtype).name}; pass it as "
                        f"an explicit-width array instead")
            values.append(v)
        return tuple(values)

    def __call__(self, *args, **kwargs):
        rt = current_runtime()
        if rt is None:
            if any(isinstance(a, (Region, BlockArray))
                   for a in (*args, *kwargs.values())):
                raise RuntimeError(
                    f"{self.__name__}: called with block regions but no "
                    "active runtime scope — wrap the call in `with rt:` "
                    "(or `with rt.scope():`) to spawn it as a task")
            return self.fn(*args, **kwargs)      # eager / serial elision
        bound = self._sig.bind_partial(*args, **kwargs)
        extra = [n for n in bound.arguments
                 if n not in self.modes and n not in self.firstprivate]
        if extra:
            raise TypeError(
                f"{self.__name__}: parameters without a footprint or "
                f"firstprivate declaration are closure captures and "
                f"cannot be bound at a spawn site: {extra}")
        missing = [n for n in self.modes if n not in bound.arguments]
        if missing:
            raise TypeError(
                f"{self.__name__}: every footprint parameter needs a "
                f"region at the call site; missing {missing}")
        access = tuple(
            self.modes[name](as_region(bound.arguments[name], name))
            for name in self.modes)
        return rt._initiate(self.fn, access, name=self.__name__,
                            values=self._bind_values(bound))

    def spawn_on(self, rt, *args, **kwargs) -> TaskFuture:
        """Spawn explicitly on ``rt`` (no ambient scope needed)."""
        _push_runtime(rt)
        try:
            return self(*args, **kwargs)
        finally:
            _pop_runtime(rt)

    def __repr__(self):
        ann = ", ".join(f"{n}:{m.__name__}" for n, m in self.modes.items())
        if self.firstprivate:
            ann += ", " + ", ".join(f"{n}:firstprivate"
                                    for n in self.firstprivate)
        return f"<task {self.__name__}({ann})>"


def task(fn: Callable | None = None, *, in_=(), out=(), inout=(),
         firstprivate=(), footprint=None):
    """Declare a task function's footprint (OmpSs ``#pragma omp task``).

    ``in_`` / ``out`` / ``inout`` each name one parameter (a string) or
    several (an iterable).  Every parameter of the function must appear in
    exactly one list — or in ``firstprivate`` — or carry a default; at
    call sites inside a ``with rt:`` scope each footprint parameter
    receives a block :class:`Region` (or a whole :class:`BlockArray`).
    ``footprint`` is the mapping spelling of the same declaration — a
    dict of parameter name to access mode, where each mode is ``"in"``/
    ``"out"``/``"inout"`` or an :class:`AccessMode` member
    (``AccessMode.INOUT``); it merges with the list kwargs and a
    parameter declared through both raises the usual duplicate error::

        @task(footprint={"c": AccessMode.INOUT, "a": "in", "b": "in"})
        def gemm(c, a, b): ...
    The function body receives materialized arrays for its ``in_`` and
    ``inout`` parameters (in parameter order) and returns one array per
    ``out``/``inout`` parameter (in parameter order).

    ``firstprivate`` names parameters passed *by value* at the spawn site
    (scalars, index offsets, small arrays): the value is copied into the
    task descriptor at initiation, never synchronized on, and handed to
    the body positionally right after the ``in_``/``inout`` arrays.  A
    firstprivate parameter may declare a default, used when the spawn
    site omits it.  On the staged executor, same-function tasks of a
    wavefront that differ only in firstprivate values batch into one
    ``jit(vmap(fn))`` dispatch with the values stacked as vmap operands —
    so the body must be vmap-traceable over them (index with
    ``jax.lax.dynamic_slice``, not Python slicing).
    """
    def wrap(f):
        fin, fout, finout = (list(_names(in_)), list(_names(out)),
                             list(_names(inout)))
        if footprint:
            buckets = {"in": fin, "out": fout, "inout": finout}
            for name, mode in footprint.items():
                buckets[coerce_mode(mode)].append(name)
        return TaskFn(f, in_=tuple(fin), out=tuple(fout),
                      inout=tuple(finout), firstprivate=firstprivate)
    if fn is not None:                 # bare @task is an error we explain
        raise TypeError(
            "@task needs footprint declarations, e.g. "
            "@task(inout='c', in_=('a', 'b'))")
    return wrap
