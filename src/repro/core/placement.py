"""Block placement across memory controllers (§4.1-§4.2).

The SCC's four memory controllers give each core a distance-dependent DRAM
latency, and concurrent access to one controller creates strong contention.
The paper's fix is to distribute application data across all controllers
"as uniformly as possible" using padding and non-unit strides at allocation.

Here placement assigns each block a *home* — on the SCC a memory controller,
on a TPU mesh a device / HBM channel.  The DES charges contention per home;
on a real mesh :func:`device_assignment` turns homes into a block-cyclic
``NamedSharding`` layout, the generalization of the paper's striping.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .blocks import BlockArray

__all__ = ["assign_homes", "PLACEMENTS", "home_histogram"]


def _single(ba: BlockArray, n_homes: int) -> None:
    """Everything behind controller 0 — the paper's pathological baseline
    ("small, concentrated datasets ... within the shared-memory segment of a
    single memory controller")."""
    for idx in ba.block_indices():
        ba.home[idx] = 0


def _striped(ba: BlockArray, n_homes: int) -> None:
    """Block-cyclic striping across all controllers (the paper's padding +
    non-unit-stride allocation pattern)."""
    for i, idx in enumerate(ba.block_indices()):
        ba.home[idx] = i % n_homes


def _striped_diag(ba: BlockArray, n_homes: int) -> None:
    """Diagonal striping: for 2-D grids, ``home = (i + j) % n`` keeps both
    row-walks and column-walks balanced (useful for Cholesky/MM traversals
    where row-major striping aliases the traversal order)."""
    for idx in ba.block_indices():
        ba.home[idx] = int(np.sum(idx)) % n_homes


PLACEMENTS: dict[str, Callable[[BlockArray, int], None]] = {
    "single": _single,
    "striped": _striped,
    "striped_diag": _striped_diag,
}


def assign_homes(ba: BlockArray, policy: str = "striped",
                 n_homes: int = 4) -> BlockArray:
    try:
        PLACEMENTS[policy](ba, n_homes)
    except KeyError:
        raise ValueError(f"unknown placement {policy!r}; "
                         f"one of {sorted(PLACEMENTS)}") from None
    return ba


def home_histogram(ba: BlockArray, n_homes: int = 4) -> list[int]:
    hist = [0] * n_homes
    for h in ba.home.values():
        hist[h] += 1
    return hist
