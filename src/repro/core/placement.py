"""Block placement across memory controllers (§4.1-§4.2).

The SCC's four memory controllers give each core a distance-dependent DRAM
latency, and concurrent access to one controller creates strong contention.
The paper's fix is to distribute application data across all controllers
"as uniformly as possible" using padding and non-unit strides at allocation.

Here placement assigns each block a *home* — on the SCC a memory controller,
on a TPU mesh a device / HBM channel.  The DES charges contention per home;
on a real mesh :func:`device_assignment` turns homes into a block-cyclic
``NamedSharding`` layout, the generalization of the paper's striping.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from .blocks import BlockArray

__all__ = ["assign_homes", "PLACEMENTS", "home_histogram",
           "device_assignment", "home_sharding", "rebalance_owners"]


def _single(ba: BlockArray, n_homes: int) -> None:
    """Everything behind controller 0 — the paper's pathological baseline
    ("small, concentrated datasets ... within the shared-memory segment of a
    single memory controller")."""
    for idx in ba.block_indices():
        ba.home[idx] = 0


def _striped(ba: BlockArray, n_homes: int) -> None:
    """Block-cyclic striping across all controllers (the paper's padding +
    non-unit-stride allocation pattern)."""
    for i, idx in enumerate(ba.block_indices()):
        ba.home[idx] = i % n_homes


def _striped_diag(ba: BlockArray, n_homes: int) -> None:
    """Diagonal striping: for 2-D grids, ``home = (i + j) % n`` keeps both
    row-walks and column-walks balanced (useful for Cholesky/MM traversals
    where row-major striping aliases the traversal order)."""
    for idx in ba.block_indices():
        ba.home[idx] = int(np.sum(idx)) % n_homes


def _striped_rows(ba: BlockArray, n_homes: int) -> None:
    """Row-banded striping: ``home = i % n`` keeps each block row behind
    one controller, so row-footprint tasks (stencils, row updates) touch
    one home per region — the layout the sharded dependence manager
    admits with the fewest cross-home messages."""
    for idx in ba.block_indices():
        ba.home[idx] = int(idx[0]) % n_homes


PLACEMENTS: dict[str, Callable[[BlockArray, int], None]] = {
    "single": _single,
    "striped": _striped,
    "striped_diag": _striped_diag,
    "striped_rows": _striped_rows,
}

# the canonical choice list lives in api.PlacementKind; this registry
# must implement exactly that list, no more, no less
from .api import PLACEMENTS as _PLACEMENT_NAMES  # noqa: E402

assert set(PLACEMENTS) == set(_PLACEMENT_NAMES), \
    "placement.PLACEMENTS drifted from api.PlacementKind"


def assign_homes(ba: BlockArray, policy: str = "striped",
                 n_homes: int = 4) -> BlockArray:
    try:
        PLACEMENTS[policy](ba, n_homes)
    except KeyError:
        raise ValueError(f"unknown placement {policy!r}; "
                         f"one of {sorted(PLACEMENTS)}") from None
    return ba


def rebalance_owners(owners, n_homes: int, skew_threshold: float,
                     base_load=None) -> tuple[list[int], int]:
    """Contention-aware owner override (§4.1–§4.2, generalized).

    ``owners`` is one wave-group's owner home per task.  When the busiest
    home's load exceeds ``skew_threshold`` times the mean load, tasks
    spill one at a time from the hottest home to the least-loaded one —
    trading an extra output transfer (the spilled task now writes home
    across devices, which the memory layer counts) against serializing the
    whole wave behind one controller, exactly the contention the paper's
    Fig 4 measures.  ``skew_threshold <= 0`` disables the override.

    ``base_load`` (one non-negative number per home) is background work
    already queued behind each home — the tracker's live per-device queue
    depth, fed back by the sharded executor — so the skew decision sees
    what each controller is *actually* serving, not just this group.
    Only this group's tasks can move: a home hot on background load alone
    stops the spill loop.  ``None`` (or all zeros) reproduces the
    wave-local behavior exactly.

    Deterministic: ties break on the lowest home id and the latest task
    spills first.  Returns ``(new_owners, n_spilled)``.
    """
    owners = [h % n_homes for h in owners]
    if skew_threshold <= 0 or not owners:
        return owners, 0
    if base_load is None:
        base = [0.0] * n_homes
    else:
        base = [float(b) for b in base_load]
        if len(base) != n_homes:
            raise ValueError(f"base_load needs one entry per home "
                             f"({n_homes}), got {len(base)}")
        if any(b < 0 for b in base):
            raise ValueError("base_load entries must be >= 0")
    wave = [0] * n_homes
    for h in owners:
        wave[h] += 1
    load = [b + w for b, w in zip(base, wave)]
    mean = sum(load) / n_homes
    spilled = 0
    while True:
        hot = max(range(n_homes), key=lambda h: load[h])
        cold = min(range(n_homes), key=lambda h: load[h])
        if load[hot] <= skew_threshold * mean or load[hot] - load[cold] <= 1:
            break
        for i in range(len(owners) - 1, -1, -1):
            if owners[i] == hot:
                owners[i] = cold
                load[hot] -= 1
                load[cold] += 1
                spilled += 1
                break
        else:
            # the hot home's load is all background — nothing of this
            # group's to move there; stop rather than spin
            break
    return owners, spilled


def home_histogram(ba: BlockArray, n_homes: int = 4) -> list[int]:
    hist = [0] * n_homes
    for h in ba.home.values():
        hist[h] += 1
    return hist


# ---------------------------------------------------------------------------
# homes -> mesh devices (the generalization the ShardedExecutor consumes)
def device_assignment(n_homes: int = 4, ctx=None) -> list:
    """Home id -> device: block-cyclic assignment of homes onto the ambient
    mesh's devices, the mesh generalization of controller striping — home
    ``h`` is served by device ``h % ndev``, so striped homes spread blocks
    over every device the way the paper's allocator spreads them over the
    four memory controllers.

    ``ctx`` is a :class:`repro.dist.MeshContext`; when None the ambient
    context (``repro.dist.current()``) is consulted, and with no mesh
    installed every home maps to the default local device — the
    single-device fallback that lets the same task program run unchanged
    in tests and CI.
    """
    import jax

    if ctx is None:
        from repro import dist
        ctx = dist.current()
    if ctx is None:
        devices = [jax.devices()[0]]
    else:
        devices = list(np.asarray(ctx.mesh.devices).flat)
    return [devices[h % len(devices)] for h in range(max(n_homes, 1))]


def home_sharding(ba: BlockArray, ctx=None):
    """A block-cyclic ``NamedSharding`` for the stacked-blocks view of
    ``ba`` — an array of shape ``(n_blocks, *block_shape)`` whose leading
    axis enumerates tiles in ``block_indices()`` order.

    Sharding that axis over every mesh axis places block ``b`` on device
    ``b % ndev``, which coincides with :func:`device_assignment` of the
    block's home whenever homes stripe block-cyclically (the "striped"
    policy) and the device count divides the home count.  Divisibility is
    guarded the same way as :mod:`repro.dist.sharding`: an indivisible
    block count degrades to replication rather than failing.  Returns
    None when no mesh context is active (single-device fallback: there is
    nothing to shard over).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    if ctx is None:
        from repro import dist
        ctx = dist.current()
    if ctx is None:
        return None
    mesh = ctx.mesh
    ndev = int(np.prod([int(mesh.shape[a]) for a in mesh.axis_names]))
    n_blocks = int(np.prod(ba.grid))
    if ndev > 0 and n_blocks % ndev == 0:
        return NamedSharding(mesh, P(tuple(mesh.axis_names)))
    return NamedSharding(mesh, P())
