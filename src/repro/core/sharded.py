"""Home-aware mesh execution: the ShardedExecutor.

The paper's central performance lesson is that memory locality dominates on
non cache-coherent machines: BDDT-SCC stripes application data across the
SCC's four memory controllers and keeps tasks near the controller serving
their blocks (§4.1-§4.2).  On a device mesh the same policy is
*owner-computes*: every block already has a home (``placement.assign_homes``),
:func:`~repro.core.placement.device_assignment` maps homes block-cyclically
onto the mesh's devices, and each task executes on the home device of its
*output* footprint.  Reads of blocks homed elsewhere are cross-home
transfers — the mesh analogue of the remote-controller accesses the DES
(``sim.py``) charges contention for — and this executor records them in
``RuntimeStats`` (``cross_home_bytes`` / ``local_home_bytes``) so the
benchmark tables can show what a placement policy saves.

Dispatch reuses the staged executor's wavefront grouping unchanged: tasks
of one wavefront with the same function and footprint/value structure
stack into one batched call.  With a mesh context active
(:func:`repro.dist.use_mesh`) that call becomes a shard_map/vmap hybrid —
the stacked task axis is sharded over every mesh axis (tasks sorted by
owner so each device's slice is, under block-cyclic homes, the tasks it
owns) and ``vmap`` maps the per-device slice.  Groups a mesh cannot split
evenly fall back to per-owner-device sub-dispatches, and with no mesh at
all every dispatch degrades to the plain staged path on the default
device — the single-device fallback tests and CI run.

Multi-device note: tiles written by a dispatch stay committed to their
owner's device.  A later wave's sharded operands are assembled per
device — each device's shard is built on that device
(``_sharded_stack``), so tiles a task owns never move and a cross-home
read transfers once, matching the bytes this executor accounts.
Mixed-device tile assembly elsewhere (multi-block
``Region.materialize``, ``BlockArray.gather``) harmonizes devices first
(``blocks._same_device``), so the whole program runs unchanged however
many devices back the homes.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from .api import suspend_runtime_scope
from .executor import StagedExecutor, _run_one
from .graph import TaskDescriptor, TaskState
from .placement import device_assignment

__all__ = ["ShardedExecutor", "owner_home"]


def owner_home(td: TaskDescriptor) -> int:
    """Owner-computes: a task belongs to the home of its first output
    block (the paper's locality-aware scheduling keyed on where the task's
    result lives, not where its inputs came from)."""
    for m in td.args:
        if m.WRITES:
            return m.region.array.home.get(m.region.tile_indices[0], 0)
    return 0


class ShardedExecutor(StagedExecutor):
    """Staged wavefronts, placed home-aware on the ambient device mesh."""

    def __init__(self, graph, scheduler, group: bool = True,
                 n_homes: int = 4):
        super().__init__(graph, scheduler, group=group)
        self.n_homes = n_homes
        self._smap: dict = {}           # (fn, mesh, n_ins) -> jitted hybrid
        self.sharded_dispatches = 0
        self.cross_home_bytes = 0
        self.local_home_bytes = 0

    # -- placement ----------------------------------------------------------
    def _mesh_ctx(self):
        from repro import dist
        return dist.current()

    def _account(self, td: TaskDescriptor, owner: int) -> None:
        """Charge every footprint block against the owner home: blocks
        homed elsewhere are cross-home traffic (what ``sim.py`` turns into
        controller contention), blocks at the owner are local.  The counts
        are policy-level — what owner-computes *must* move — independent
        of how many physical devices back the homes, so the single-device
        fallback reports the same numbers a real mesh would."""
        for m in td.args:
            arr = m.region.array
            block_bytes = (int(np.prod(arr.block_shape))
                           * jnp.dtype(arr.dtype).itemsize)
            for idx in m.region.tile_indices:
                if arr.home.get(idx, 0) != owner:
                    self.cross_home_bytes += block_bytes
                else:
                    self.local_home_bytes += block_bytes

    # -- dispatch -----------------------------------------------------------
    def _run_group(self, group: list[TaskDescriptor]) -> None:
        owners = [owner_home(td) for td in group]
        for td, h in zip(group, owners):
            self._account(td, h)
        ctx = self._mesh_ctx()
        if ctx is None:
            # single-device fallback: identical to the staged executor
            return super()._run_group(group)
        mesh = ctx.mesh
        devmap = device_assignment(self.n_homes, ctx)
        ndev = int(np.asarray(mesh.devices).size)
        if len(group) == 1 or not self.group:
            jfn = self._jitted(group[0].fn)
            for td, h in zip(group, owners):
                dev = devmap[h % len(devmap)]
                _run_one(td, jfn,
                         place=lambda x, d=dev: jax.device_put(x, d))
            return
        # sort by owner device so the sharded task axis hands each device
        # (under balanced block-cyclic homes) exactly the tasks it owns
        order = sorted(range(len(group)), key=lambda i: owners[i] % ndev)
        group = [group[i] for i in order]
        owners = [owners[i] for i in order]
        if len(group) % ndev == 0:
            self._run_sharded(group, mesh)
        else:
            # a wave the mesh cannot split evenly: owner-computes
            # sub-dispatches, one batched call per owner device
            by_dev = defaultdict(list)
            for td, h in zip(group, owners):
                by_dev[devmap[h % len(devmap)]].append(td)
            for dev, sub in by_dev.items():
                self._run_subgroup_on(sub, dev)

    def _sharded_stack(self, group: list[TaskDescriptor],
                       sharding) -> list:
        """Assemble each stacked operand (READS args then firstprivate
        values, the staged stacking order) directly as a sharded global
        array: every device's shard is built on that device — element
        device_puts are no-ops for tiles the task already owns, and a
        cross-home read moves once, matching the bytes ``_account``
        charges (no staging-device double hop)."""
        pulls = []
        for pos in range(len(group[0].args)):
            if group[0].args[pos].READS:
                pulls.append(
                    lambda td, p=pos: td.args[p].region.materialize())
        for pos in range(len(group[0].values)):
            pulls.append(lambda td, p=pos: jnp.asarray(td.values[p]))
        n = len(group)
        ins = []
        for pull in pulls:
            elts = [pull(td) for td in group]
            shape = (n, *np.shape(elts[0]))
            shards = []
            for dev, idx in sharding.devices_indices_map(shape).items():
                lo, hi, _ = idx[0].indices(n)     # the task-axis slice
                shards.append(jnp.stack(
                    [jax.device_put(x, dev) for x in elts[lo:hi]]))
            ins.append(jax.make_array_from_single_device_arrays(
                shape, sharding, shards))
        return ins

    def _run_sharded(self, group: list[TaskDescriptor], mesh) -> None:
        """The shard_map/vmap hybrid: stacked operands are sharded along
        the task axis over every mesh axis; inside each shard ``vmap``
        maps the local slice."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        fn = group[0].fn
        for td in group:
            td.state = TaskState.RUNNING
        spec = P(tuple(mesh.axis_names))
        ins = self._sharded_stack(group, NamedSharding(mesh, spec))
        key = (fn, mesh, len(ins))
        sfn = self._smap.get(key)
        if sfn is None:
            sfn = self._smap[key] = jax.jit(jax.shard_map(
                jax.vmap(fn), mesh=mesh,
                in_specs=tuple(spec for _ in ins), out_specs=spec,
                check_vma=False))
        with suspend_runtime_scope():    # tracing runs fn on this thread
            result = sfn(*ins)
        self.sharded_dispatches += 1
        self._store_group(group, result)

    def _run_subgroup_on(self, group: list[TaskDescriptor], dev) -> None:
        """Batched vmap dispatch pinned to one owner device (the uneven-
        wave fallback; computation follows the placed operands)."""
        fn = group[0].fn
        if len(group) == 1:
            _run_one(group[0], self._jitted(fn),
                     place=lambda x: jax.device_put(x, dev))
            return
        for td in group:
            td.state = TaskState.RUNNING
        ins = self._stack_group(group,
                                place=lambda x: jax.device_put(x, dev))
        vfn = self._vjit.get(fn)
        if vfn is None:
            vfn = self._vjit[fn] = jax.jit(jax.vmap(fn))
        with suspend_runtime_scope():
            result = vfn(*ins)
        self._store_group(group, result)
