"""Home-aware mesh execution: the ShardedExecutor.

The paper's central performance lesson is that memory locality dominates on
non cache-coherent machines: BDDT-SCC stripes application data across the
SCC's four memory controllers and keeps tasks near the controller serving
their blocks (§4.1-§4.2).  On a device mesh the same policy is
*owner-computes*: every block already has a home (``placement.assign_homes``),
:func:`~repro.core.placement.device_assignment` maps homes block-cyclically
onto the mesh's devices, and each task executes on the home device of its
*output* footprint.  Reads of blocks homed elsewhere are cross-home
transfers — the mesh analogue of the remote-controller accesses the DES
(``sim.py``) charges contention for — and this executor records them in
``RuntimeStats`` (``cross_home_bytes`` / ``local_home_bytes``) so the
benchmark tables can show what a placement policy saves.

Residency: blocks are *device-resident*.  :meth:`ShardedExecutor.make_store`
hands every registered ``BlockArray`` a
:class:`~repro.core.blocks.DeviceTileStore`, so each tile physically lives
on the device serving its home.  A grouped wave dispatch assembles every
device's operand shard *on that device* (``Region.materialize(device=...)``
inside :meth:`_sharded_stack`): tiles a task owns never move, a cross-home
read transfers exactly once, and nothing routes through a staging device —
``RuntimeStats.bytes_staged`` stays zero, and ``tile_moves``/``bytes_moved``
report the transfers that actually happened (measured at the memory layer
by :class:`~repro.core.blocks.TileTraffic`, not estimated from footprints).
Results come back shard-by-shard (:meth:`_store_sharded` reads each task's
output from the shard data on its executing device) and commit tile-by-tile
to the output's home.

Dispatch reuses the staged executor's wavefront grouping unchanged: tasks
of one wavefront with the same function and footprint/value structure
stack into one batched call.  With a mesh context active
(:func:`repro.dist.use_mesh`) that call becomes a shard_map/vmap hybrid —
the stacked task axis is sharded over every mesh axis (tasks sorted by
owner so each device's slice is, under block-cyclic homes, the tasks it
owns) and ``vmap`` maps the per-device slice.  Groups a mesh cannot split
evenly fall back to per-owner-device sub-dispatches, and with no mesh at
all every dispatch degrades to the plain staged path on the default
device — the single-device fallback tests and CI run.

When ``RuntimeConfig.owner_skew_threshold`` is set, a wave group whose
owner loads are badly skewed is rebalanced before dispatch
(:func:`~repro.core.placement.rebalance_owners`): surplus tasks of the
hottest home spill to the least-loaded one, and the spilled task's output
transfer home is charged for real by the device store — contention traded
against one counted copy, the override the paper's Fig 4 numbers argue for.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from .api import suspend_runtime_scope
from .blocks import DeviceTileStore
from .executor import StagedExecutor, _run_one
from .graph import TaskDescriptor, TaskState, normalize_outputs
from .placement import device_assignment, rebalance_owners

__all__ = ["ShardedExecutor", "owner_home"]


def owner_home(td: TaskDescriptor) -> int:
    """Owner-computes: a task belongs to the home of its first output
    block (the paper's locality-aware scheduling keyed on where the task's
    result lives, not where its inputs came from)."""
    for m in td.args:
        if m.WRITES:
            return m.region.array.home.get(m.region.tile_indices[0], 0)
    return 0


class ShardedExecutor(StagedExecutor):
    """Staged wavefronts, placed home-aware on the ambient device mesh."""

    kind = "sharded"

    def __init__(self, graph, scheduler, group: bool = True,
                 n_homes: int = 4, owner_skew_threshold: float = 0.0,
                 kernel_backend: str = "xla"):
        super().__init__(graph, scheduler, group=group,
                         kernel_backend=kernel_backend)
        self.n_homes = n_homes
        self.owner_skew_threshold = owner_skew_threshold
        self._smap: dict = {}           # (fn, mesh, n_ins) -> jitted hybrid
        self.sharded_dispatches = 0
        self.cross_home_bytes = 0
        self.local_home_bytes = 0
        self.owner_overrides = 0

    # -- placement ----------------------------------------------------------
    def _mesh_ctx(self):
        from repro import dist
        return dist.current()

    def make_store(self, ba):
        """The runtime's residency hook: with a mesh active, give ``ba`` a
        device-resident store so its tiles live on their home devices from
        allocation onward (``from_array``/``zeros``/``full`` place each
        tile per ``device_assignment``).  Without a mesh the host store
        stays — the single-device fallback."""
        ctx = self._mesh_ctx()
        if ctx is None:
            return None
        return DeviceTileStore(ba, device_assignment(self.n_homes, ctx),
                               traffic=ba.traffic)

    def _account(self, td: TaskDescriptor, owner: int) -> None:
        """Charge every footprint block against the owner home: blocks
        homed elsewhere are cross-home traffic (what ``sim.py`` turns into
        controller contention), blocks at the owner are local.  The counts
        are policy-level — what owner-computes *must* move — independent
        of how many physical devices back the homes, so the single-device
        fallback reports the same numbers a real mesh would.  (The
        *measured* movement lives in the runtime's ``TileTraffic``.)"""
        for m in td.args:
            arr = m.region.array
            block_bytes = (int(np.prod(arr.block_shape))
                           * jnp.dtype(arr.dtype).itemsize)
            for idx in m.region.tile_indices:
                if arr.home.get(idx, 0) != owner:
                    self.cross_home_bytes += block_bytes
                else:
                    self.local_home_bytes += block_bytes

    def _owners(self, group: list[TaskDescriptor]) -> list[int]:
        owners = [owner_home(td) for td in group]
        if self.owner_skew_threshold > 0:
            base = None
            if self.obs.enabled:
                # the tracker's live per-home queue depth: work of this
                # wave still queued behind each home ("queued, not yet
                # dispatched" — this group was dequeued before placement,
                # so it is not double-counted)
                depths = self.obs.queue_depths()
                base = [max(0, depths.get(h, 0))
                        for h in range(self.n_homes)]
            owners, spilled = rebalance_owners(
                owners, self.n_homes, self.owner_skew_threshold,
                base_load=base)
            self.owner_overrides += spilled
            if spilled and self.obs.enabled:
                self.obs.emit("owner_override", wave=self._wave_id,
                              spilled=spilled)
        return owners

    # -- queue accounting (per owner-home channel) ----------------------------
    def _home_counts(self, tds: list[TaskDescriptor]):
        counts: dict[int, int] = defaultdict(int)
        for td in tds:
            counts[owner_home(td) % self.n_homes] += 1
        return counts

    def _enqueue_wave(self, wave: list[TaskDescriptor]) -> None:
        for home, n in sorted(self._home_counts(wave).items()):
            self.obs.queue(home, n)

    def _dequeue_group(self, group: list[TaskDescriptor]) -> None:
        # keyed on the raw owner home (pre-rebalance), matching enqueue
        for home, n in sorted(self._home_counts(group).items()):
            self.obs.queue(home, -n)

    # -- dispatch -----------------------------------------------------------
    def _run_group(self, group: list[TaskDescriptor]) -> None:
        owners = self._owners(group)
        for td, h in zip(group, owners):
            self._account(td, h)
        ctx = self._mesh_ctx()
        if ctx is None:
            # single-device fallback: identical to the staged executor
            # (including its pallas wave-kernel attempt when
            # kernel_backend="pallas" — how the CPU matrix exercises it)
            return super()._run_group(group)
        if self.kernel_backend == "pallas":
            # under a live mesh the group dispatches through the
            # shard_map/vmap hybrid; a fused pallas grid would pin the
            # whole wave to one device and undo owner-computes, so the
            # mesh path is a named fallback, not a lowering attempt
            self._note_kernel_fallback(group, "sharded_mesh")
        mesh = ctx.mesh
        devmap = device_assignment(self.n_homes, ctx)
        ndev = int(np.asarray(mesh.devices).size)
        if len(group) == 1 or not self.group:
            jfn = self._jitted(group[0].fn)
            for td, h in zip(group, owners):
                _run_one(td, jfn, device=devmap[h % len(devmap)])
            return
        # sort by owner device so the sharded task axis hands each device
        # (under balanced block-cyclic homes) exactly the tasks it owns
        order = sorted(range(len(group)), key=lambda i: owners[i] % ndev)
        group = [group[i] for i in order]
        owners = [owners[i] for i in order]
        if len(group) % ndev == 0:
            self._run_sharded(group, mesh)
        else:
            # a wave the mesh cannot split evenly: owner-computes
            # sub-dispatches, one batched call per owner device
            by_dev = defaultdict(list)
            for td, h in zip(group, owners):
                by_dev[devmap[h % len(devmap)]].append(td)
            for dev, sub in by_dev.items():
                self._run_subgroup_on(sub, dev)

    def _sharded_stack(self, group: list[TaskDescriptor],
                       sharding) -> tuple[list, list]:
        """Assemble each stacked operand (READS args then firstprivate
        values, the staged stacking order) directly as a sharded global
        array: every device's shard is built *on that device* by
        destination-aware assembly — tiles resident there are read in
        place, a cross-home tile transfers once, and no operand ever
        routes through a staging device.  Returns ``(ins, slices)`` where
        ``slices`` is the per-device ``(device, lo, hi)`` split of the
        task axis (``_store_sharded`` reads results back along it)."""
        n = len(group)
        slices = [(dev, *idx[0].indices(n)[:2])
                  for dev, idx in sharding.devices_indices_map((n,)).items()]
        ins = []
        for elt_shape, pull in self._pulls(group):
            shards = [jnp.stack([pull(i, dev) for i in range(lo, hi)])
                      for dev, lo, hi in slices]
            ins.append(jax.make_array_from_single_device_arrays(
                (n, *elt_shape), sharding, shards))
        return ins, slices

    def _store_sharded(self, group: list[TaskDescriptor], result,
                       slices: list) -> None:
        """Unstack a sharded result without cross-device gathers: each
        output's per-device shard holds exactly the tasks that ran there,
        so every task's value is read from the shard data already on its
        executing device and committed tile-by-tile to its output's home
        (a no-op when owner-computes held; one counted transfer when the
        owner override spilled the task)."""
        result = normalize_outputs(result, len(group[0].outputs),
                                   group[0].name or group[0].tid)
        self.grouped_dispatches += 1
        shard_data = [{s.device: s.data for s in out.addressable_shards}
                      for out in result]
        for dev, lo, hi in slices:
            for i in range(lo, hi):
                self._assign_outputs(
                    group[i],
                    tuple(data[dev][i - lo] for data in shard_data))

    def _run_sharded(self, group: list[TaskDescriptor], mesh) -> None:
        """The shard_map/vmap hybrid: stacked operands are sharded along
        the task axis over every mesh axis; inside each shard ``vmap``
        maps the local slice."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        fn = group[0].fn
        for td in group:
            td.state = TaskState.RUNNING
        spec = P(tuple(mesh.axis_names))
        ins, slices = self._sharded_stack(group, NamedSharding(mesh, spec))
        key = (fn, mesh, len(ins))
        sfn = self._smap.get(key)
        if sfn is None:
            sfn = self._smap[key] = jax.jit(jax.shard_map(
                jax.vmap(fn), mesh=mesh,
                in_specs=tuple(spec for _ in ins), out_specs=spec,
                check_vma=False))
        self._last_mode = "shard_map"
        with suspend_runtime_scope():    # tracing runs fn on this thread
            result = sfn(*ins)
        self.sharded_dispatches += 1
        self._store_sharded(group, result, slices)

    def _run_subgroup_on(self, group: list[TaskDescriptor], dev) -> None:
        """Batched vmap dispatch pinned to one owner device (the uneven-
        wave fallback; computation follows the placed operands)."""
        fn = group[0].fn
        if len(group) == 1:
            _run_one(group[0], self._jitted(fn), device=dev)
            return
        for td in group:
            td.state = TaskState.RUNNING
        ins = self._stack_group(group, device=dev)
        vfn = self._vjit.get(fn)
        if vfn is None:
            vfn = self._vjit[fn] = jax.jit(jax.vmap(fn))
        self._last_mode = "vmap_device"
        with suspend_runtime_scope():
            result = vfn(*ins)
        self._store_group(group, result)
