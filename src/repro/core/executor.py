"""Executors: how a discovered task graph actually runs.

* :class:`SequentialExecutor` — serial elision; the oracle for tests.
* :class:`HostExecutor` — the paper-faithful dynamic runtime: the host
  thread is the SCC master, worker threads drain MPB descriptor rings and
  execute jitted tile tasks.  Reproduces the paper's protocol including
  bounded slots, master-never-blocks spawns, lazy collection and release.
* :class:`StagedExecutor` — the TPU-idiomatic adaptation: the DAG is
  layered into wavefronts and each wavefront's identical tile tasks are
  fused into one batched (``vmap``-ed, jitted) dispatch.  On an SPMD
  machine there is no dynamic master->worker dispatch at run time, so the
  descriptor traffic of the paper is staged into the compiled program —
  the dependence analysis is unchanged, only the dispatch is ahead-of-time.
* :class:`repro.core.sharded.ShardedExecutor` — the staged wavefronts
  placed home-aware on a device mesh (owner-computes over
  ``BlockArray.home``); lives in its own module to keep mesh plumbing out
  of the single-machine path.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, defaultdict, deque
from typing import Callable, Iterable, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.profiler import trace_span
from repro.obs.tracker import NULL_TRACKER

from . import wavekernel
from .api import suspend_runtime_scope
from .graph import TaskDescriptor, TaskGraph, TaskState, normalize_outputs
from .mpb import MPBQueue
from .scheduler import MasterScheduler

__all__ = ["Executor", "ExecutorBase", "SequentialExecutor", "HostExecutor",
           "StagedExecutor", "dependence_cone"]


@runtime_checkable
class Executor(Protocol):
    """What the runtime front-end requires of an execution strategy.

    Implementations: :class:`SequentialExecutor` (serial elision),
    :class:`HostExecutor` (the paper's dynamic master/worker protocol),
    :class:`StagedExecutor` (wavefront batching for SPMD hardware),
    :class:`repro.core.sharded.ShardedExecutor` (home-aware wavefronts on
    a device mesh) and :class:`repro.core.sim.SimExecutor` (timing-only
    discrete-event prediction on the SCC cost model).
    """

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        """A task was initiated; ``ready`` means no unresolved deps."""
        ...

    def barrier(self) -> None:
        """Global synchronization: return once every spawned task ran."""
        ...

    def wait_for(self, tds: Sequence[TaskDescriptor]) -> None:
        """Partial synchronization: return once ``tds`` (and hence their
        dependence cones) completed — unrelated tasks need not have run."""
        ...

    def reclaim(self) -> None:
        """Make progress so a descriptor can be recycled (pool exhausted)."""
        ...

    def shutdown(self) -> None:
        ...


def dependence_cone(targets: Iterable[TaskDescriptor]) -> set[TaskDescriptor]:
    """The incomplete transitive predecessors of ``targets`` (targets
    included) — exactly what must run before a wait on them returns."""
    cone: set[TaskDescriptor] = set()
    stack = [td for td in targets if not td.is_complete]
    while stack:
        td = stack.pop()
        if td in cone:
            continue
        cone.add(td)
        stack.extend(p for p in td.preds
                     if not p.is_complete and p not in cone)
    return cone


class ExecutorBase:
    """Shared defaults for :class:`Executor` implementations.

    Observability: the runtime hands every executor the tracker it owns
    (``obs``), its traffic recorder (``traffic``) and the profiler flag
    (``profile``) right after construction — class-level defaults keep
    executors constructed standalone (tests, the DES) working with zero
    event overhead.  Hot paths guard event construction on
    ``obs.enabled``, so the default ``NULL_TRACKER`` never even builds
    an event dict.
    """

    kind = "base"                 # the ``executor`` field of emitted events
    obs = NULL_TRACKER            # set by TaskRuntime.__init__
    traffic = None                # the runtime's TileTraffic recorder
    profile = False               # RuntimeConfig.profile_waves

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        raise NotImplementedError

    def barrier(self) -> None:
        raise NotImplementedError

    def wait_for(self, tds: Sequence[TaskDescriptor]) -> None:
        """Conservative default: a full barrier satisfies any wait."""
        if any(not td.is_complete for td in tds):
            self.barrier()

    def reclaim(self) -> None:
        """Make progress so a descriptor can be recycled (pool exhausted)."""
        self.barrier()

    def shutdown(self) -> None:
        pass


# ---------------------------------------------------------------------------
class SequentialExecutor(ExecutorBase):
    """Serial elision: run each task at spawn, in program order.  Program
    order is a topological order of the dependence DAG by construction, so
    every dependence is satisfied."""

    kind = "sequential"

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler):
        self.graph = graph
        self.scheduler = scheduler

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        assert ready, ("sequential spawn found an unresolved dependence; "
                       "program order must satisfy all deps")
        td.state = TaskState.RUNNING
        td.run()
        self.scheduler._collect(td)
        self.scheduler.release_all()

    def barrier(self) -> None:
        assert self.graph.quiescent

    def wait_for(self, tds) -> None:
        # every task ran at its spawn; nothing can be outstanding
        assert all(td.is_complete for td in tds)


# ---------------------------------------------------------------------------
class _Worker(threading.Thread):
    """A worker core: drains its MPB ring, executes tasks, marks slots
    completed (§3.5).  Cache invalidate/flush fences around the task body
    are no-ops on coherent CPython (charged for real in the DES).

    Pinned tile cache: each worker keeps up to ``cache_tiles`` assembled
    READS operands, keyed by region identity and validated by the
    *identity* of the constituent tile objects (jax arrays are immutable
    and the store swaps in a new object on every write, so object
    identity is exact freshness; the cached entry pins its tiles, ruling
    out id reuse).  A hit skips region reassembly — the SCC analogue of
    a worker keeping hot tiles resident in its own memory slice."""

    def __init__(self, wid: int, queue: MPBQueue, cache_tiles: int = 0):
        super().__init__(name=f"bddt-worker-{wid}", daemon=True)
        self.wid = wid
        self.queue = queue
        self.stop_flag = threading.Event()
        self.busy_s = 0.0
        self.tasks_run = 0
        self.cache_tiles = cache_tiles
        self.cache_hits = 0
        self.cache_misses = 0
        # region key -> (pinned tile objects, assembled value), LRU order
        self._cache: OrderedDict = OrderedDict()

    def _materialize(self, region):
        if not self.cache_tiles:
            return region.materialize()
        key = (region.array.array_id, region.ranges)
        tiles = tuple(region.array.get_tile(i) for i in region.tile_indices)
        hit = self._cache.get(key)
        if hit is not None and len(hit[0]) == len(tiles) and \
                all(a is b for a, b in zip(hit[0], tiles)):
            self.cache_hits += 1
            self._cache.move_to_end(key)
            return hit[1]
        self.cache_misses += 1
        value = region.materialize()
        self._cache[key] = (tiles, value)
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_tiles:
            self._cache.popitem(last=False)
        return value

    def run(self) -> None:
        while not self.stop_flag.is_set():
            td = self.queue.next_ready(timeout=0.05)
            if td is None:
                continue
            td.state = TaskState.RUNNING
            t0 = time.perf_counter()
            # read fence (L2 invalidate) | task body | write fence (L2 flush)
            td.run(materialize=self._materialize)
            self.busy_s += time.perf_counter() - t0
            self.tasks_run += 1
            self.queue.mark_completed(td)


class HostExecutor(ExecutorBase):
    """The paper's runtime: master = the spawning host thread."""

    kind = "host"

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler,
                 queues: list[MPBQueue], cache_tiles: int = 0):
        self.graph = graph
        self.scheduler = scheduler
        self.queues = queues
        self._cache_reported = False
        self.workers = [_Worker(q.worker_id, q, cache_tiles=cache_tiles)
                        for q in queues]
        for w in self.workers:
            w.start()

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        if ready:
            # running mode: one attempt, never block (§3.4)
            self.scheduler.schedule_running(td)
        # dependent tasks stay in the task graph until released

    def barrier(self) -> None:
        # polling mode until every spawned task has been released
        while not self.graph.quiescent:
            self.scheduler.polling_step()
            if not self.graph.quiescent:
                time.sleep(0)  # yield to worker threads

    def wait_for(self, tds) -> None:
        """Polling mode scoped to ``tds``: the master polls/schedules/
        releases until the waited-on tasks completed, then returns to the
        main program — in-flight unrelated tasks keep running on their
        workers undisturbed."""
        while not all(td.is_complete for td in tds):
            self.scheduler.polling_step()
            if not all(td.is_complete for td in tds):
                time.sleep(0)

    def pump(self) -> None:
        """One non-blocking master step: poll worker rings, release
        completed tasks, dispatch newly-ready ones.  Serving loops call
        this between arrivals so completions surface without forcing a
        dependence-cone wait."""
        self.scheduler.polling_step()

    def reclaim(self) -> None:
        # §3.3: master blocks until a task completes, freeing a descriptor
        while self.scheduler.pool.free == 0:
            self.scheduler.polling_step()
            time.sleep(0)

    def shutdown(self) -> None:
        for w in self.workers:
            w.stop_flag.set()
        for w in self.workers:
            w.join(timeout=2.0)
        if self.obs.enabled and not self._cache_reported:
            self._cache_reported = True
            for w in self.workers:
                self.obs.emit("tile_cache", worker=w.wid,
                              hits=w.cache_hits, misses=w.cache_misses)


# ---------------------------------------------------------------------------
class StagedExecutor(ExecutorBase):
    """Wavefront staging: spawn only records; the barrier layers the DAG and
    dispatches each layer as batched jitted calls.

    Grouping: tasks in one wavefront with the same function and the same
    input/output signature are stacked and executed through one
    ``jit(vmap(fn))`` call — the TPU analogue of handing each worker its MPB
    queue of identical tile tasks.  Firstprivate values are stacked as extra
    vmap operands, so index-parameterized tile tasks (same function,
    different offsets) share the dispatch too.  The stacked axis is the
    "worker" axis; under ``shard_map`` on real hardware it shards over the
    mesh.
    """

    kind = "staged"

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler,
                 group: bool = True, kernel_backend: str = "xla"):
        self.graph = graph
        self.scheduler = scheduler
        self.group = group
        self.kernel_backend = kernel_backend
        self.pending: list[TaskDescriptor] = []
        self._vjit: dict[Callable, Callable] = {}
        self._jit: dict[Callable, Callable] = {}
        self._pjit: dict[tuple, Callable] = {}   # built wave kernels
        self.waves_run = 0
        self.grouped_dispatches = 0
        self.kernel_dispatches = 0     # groups fused into one pallas grid
        self.kernel_fallbacks = 0      # pallas-requested groups gone XLA
        self._dispatches = 0           # all dispatch events this executor
        self._wave_id = 0              # current wave (event correlation)
        self._last_mode = "jit"        # how the last group dispatched

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        self.pending.append(td)

    # -- wavefront layering ---------------------------------------------------
    def _wavefronts(self, tasks: list[TaskDescriptor]) \
            -> list[list[TaskDescriptor]]:
        mgr = getattr(self.scheduler, "_ready_mgr", None)
        if mgr is not None:
            return self._wavefronts_sharded(tasks, mgr)
        indeg = {td: td.deps_remaining for td in tasks}
        frontier = [td for td, d in indeg.items() if d == 0]
        waves = []
        seen = 0
        while frontier:
            # canonical intra-wave order: spawn order, not discovery
            # order — the order is the schedule contract the sharded
            # wave builder reproduces, so it must not depend on which
            # predecessor happened to unlock a task first
            frontier.sort(key=lambda t: t.spawn_order)
            waves.append(frontier)
            seen += len(frontier)
            nxt: list[TaskDescriptor] = []
            for td in frontier:
                for dep in td.dependents:
                    if dep in indeg:
                        indeg[dep] -= 1
                        if indeg[dep] == 0:
                            nxt.append(dep)
            frontier = nxt
        if seen != len(tasks):
            raise RuntimeError("cycle in task graph (impossible for "
                               "footprint-derived deps)")
        return waves

    def _wavefronts_sharded(self, tasks: list[TaskDescriptor], mgr) \
            -> list[list[TaskDescriptor]]:
        """Wavefront layering over the sharded manager's per-home ready
        sets: ready tasks bucket at their owner home (the same
        owner-computes rule the per-home ready deques use), each wave is
        the union of the buckets spawn-ordered, and the dependents
        decrement refills next wave's buckets.  A wave is exactly the set
        of zero-indegree tasks, so the *levels* are identical to the
        central builder's — only who holds the ready tasks changes."""
        indeg = {td: td.deps_remaining for td in tasks}
        buckets = [deque() for _ in range(mgr.n_managers)]
        for td in tasks:                 # pending order == spawn order
            if indeg[td] == 0:
                buckets[mgr.owner_of(td)].append(td)
        waves = []
        seen = 0
        while any(buckets):
            wave = [td for q in buckets for td in q]
            wave.sort(key=lambda t: t.spawn_order)
            for q in buckets:
                q.clear()
            waves.append(wave)
            seen += len(wave)
            for td in wave:
                for dep in td.dependents:
                    if dep in indeg:
                        indeg[dep] -= 1
                        if indeg[dep] == 0:
                            buckets[mgr.owner_of(dep)].append(dep)
        if seen != len(tasks):
            raise RuntimeError("cycle in task graph (impossible for "
                               "footprint-derived deps)")
        return waves

    def _sig(self, td: TaskDescriptor):
        """The grouping key — shared with the wave-kernel layer and the
        DES's fused-wave predictor, so it lives in ``wavekernel.py``
        (:func:`~repro.core.wavekernel.group_signature`): tasks that
        differ only in region contents or index values share one batched
        dispatch."""
        return wavekernel.group_signature(td)

    def _jitted(self, fn: Callable) -> Callable:
        jfn = self._jit.get(fn)
        if jfn is None:
            jfn = self._jit[fn] = jax.jit(fn)
        return jfn

    @staticmethod
    def _pulls(group: list[TaskDescriptor]) -> list:
        """One ``(element_shape, pull(i, device))`` pair per stacked
        operand — READS args then firstprivate values, the canonical
        stacking order shared by the staged and sharded dispatch paths.
        ``pull(i, device)`` produces task ``i``'s operand assembled on
        ``device`` (left in place when None, the plain staged path)."""
        pulls = []
        for pos in range(len(group[0].args)):
            if not group[0].args[pos].READS:
                continue
            pulls.append((
                group[0].args[pos].region.shape,
                lambda i, dev, p=pos:
                    group[i].args[p].region.materialize(device=dev)))
        for pos in range(len(group[0].values)):
            pulls.append((
                np.shape(group[0].values[pos]),
                lambda i, dev, p=pos:
                    jnp.asarray(group[i].values[p]) if dev is None
                    else jax.device_put(jnp.asarray(group[i].values[p]),
                                        dev)))
        return pulls

    def _stack_group(self, group: list[TaskDescriptor],
                     device=None) -> list:
        """Stack each READS arg across the group, then the firstprivate
        values as extra vmap operands — same function, different index
        values, one compiled dispatch per wavefront.  ``device`` (if
        given) is the dispatch destination: each operand is assembled
        *directly on it* (``Region.materialize(device=...)``), so tiles
        resident on other devices move exactly once and nothing routes
        through a staging device.  The sharded executor passes the owner
        device here; the plain staged path leaves operands where they
        are."""
        return [jnp.stack([pull(i, device) for i in range(len(group))])
                for _, pull in self._pulls(group)]

    @staticmethod
    def _assign_outputs(td: TaskDescriptor, vals: tuple) -> None:
        """Commit one task's output values — the §3.5 store contract
        shared by every batched path (regions first, captured outputs
        after)."""
        for mode, value in zip(td.outputs, vals):
            mode.region.store(value)
        td.output_values = vals

    def _store_group(self, group: list[TaskDescriptor], result) -> None:
        """Unstack one batched result back into the group's regions and
        captured outputs (one slice per task, in group order)."""
        result = normalize_outputs(result, len(group[0].outputs),
                                   group[0].name or group[0].tid)
        self.grouped_dispatches += 1
        for i, td in enumerate(group):
            self._assign_outputs(
                td, tuple(stacked[i] for stacked in result))

    def _run_group(self, group: list[TaskDescriptor]) -> None:
        if self.kernel_backend == "pallas":
            reason = self._try_wave_kernel(group)
            if reason is None:
                return                 # fused pallas grid dispatched
            self._note_kernel_fallback(group, reason)
        fn = group[0].fn
        if len(group) == 1 or not self.group:
            jfn = self._jitted(fn)
            for td in group:
                _run_one(td, jfn)
            return
        for td in group:
            td.state = TaskState.RUNNING
        ins = self._stack_group(group)
        vfn = self._vjit.get(fn)
        if vfn is None:
            vfn = self._vjit[fn] = jax.jit(jax.vmap(fn))
        self._last_mode = "vmap"
        with suspend_runtime_scope():    # tracing runs fn on this thread
            result = vfn(*ins)
        self._store_group(group, result)

    # -- the pallas wave-kernel backend (kernel_backend="pallas") -------------
    def _try_wave_kernel(self, group: list[TaskDescriptor]) -> str | None:
        """Dispatch the group as one fused pallas grid if it qualifies.
        Returns None on success (results committed), else the fallback
        reason — the caller then takes the XLA path, which stays the
        reference oracle for everything the lowering does not cover."""
        if not self.group:
            return "ungrouped"
        reason = wavekernel.eligibility(group)
        if reason is not None:
            return reason
        td = group[0]
        label = td.name or td.fn.__name__
        for t in group:
            t.state = TaskState.RUNNING
        ins = self._stack_group(group)
        key = (td.fn, len(group),
               tuple((tuple(x.shape), str(x.dtype)) for x in ins))
        try:
            pfn = self._pjit.get(key)
            if pfn is None:
                in_structs = [jax.ShapeDtypeStruct(x.shape[1:], x.dtype)
                              for x in ins]
                out_structs = wavekernel.infer_out_structs(
                    td.fn, in_structs, len(td.outputs), label)
                pfn = self._pjit[key] = wavekernel.build_wave_kernel(
                    td.fn, len(group), in_structs, out_structs,
                    interpret=wavekernel.interpret_mode(), label=label)
            with suspend_runtime_scope():   # tracing runs fn on this thread
                result = pfn(*ins)
        except Exception:
            # untraceable body, unsupported op under the pallas
            # interpreter, compiler limits... — every lowering failure
            # degrades to the XLA path, where a genuine task-body error
            # resurfaces to the user unchanged
            return "lowering_failed"
        self._last_mode = "pallas"
        self.kernel_dispatches += 1
        if self.obs.enabled:
            self.obs.emit("kernel_dispatch", wave=self._wave_id,
                          executor=self.kind, fn=label, tasks=len(group),
                          backend="pallas", reason="")
        self._store_group(group, result)
        return None

    def _note_kernel_fallback(self, group: list[TaskDescriptor],
                              reason: str) -> None:
        """Account one pallas-requested group that takes the XLA path."""
        self.kernel_fallbacks += 1
        if self.obs.enabled:
            td = group[0]
            self.obs.emit("kernel_dispatch", wave=self._wave_id,
                          executor=self.kind,
                          fn=td.name or td.fn.__name__, tasks=len(group),
                          backend="xla", reason=reason)

    # -- wave instrumentation -------------------------------------------------
    def _traffic_snapshot(self) -> tuple[int, int, int]:
        t = self.traffic
        if t is None:
            return (0, 0, 0)
        return (t.tile_moves, t.bytes_moved, t.bytes_staged)

    def _enqueue_wave(self, wave: list[TaskDescriptor]) -> None:
        """Account a staged wave as queued work; the staged path has one
        logical dispatch channel (0).  Sharded overrides per owner home."""
        self.obs.queue(0, len(wave))

    def _dequeue_group(self, group: list[TaskDescriptor]) -> None:
        self.obs.queue(0, -len(group))

    def _run_wave_group(self, group: list[TaskDescriptor]) -> None:
        if not self.obs.enabled:
            self._run_group(group)
            return
        # dequeue before dispatch so live depth means "queued, not yet
        # dispatched" — the sharded rebalance reads it as background load
        # and must not count the group it is placing
        self._dequeue_group(group)
        self._last_mode = "jit"
        t0 = time.perf_counter()
        self._run_group(group)
        wall = time.perf_counter() - t0
        self._dispatches += 1
        td = group[0]
        self.obs.emit("dispatch", wave=self._wave_id, executor=self.kind,
                      fn=td.name or td.fn.__name__, tasks=len(group),
                      mode=self._last_mode, wall_s=wall)

    def _run_waves(self, tasks: list[TaskDescriptor]) -> None:
        for wave in self._wavefronts(tasks):
            self.waves_run += 1
            groups: dict = defaultdict(list)
            for td in wave:
                groups[self._sig(td)].append(td)
            if self.obs.enabled:
                self._wave_id += 1
                wid = self._wave_id
                self.obs.emit("wave_open", wave=wid, executor=self.kind,
                              tasks=len(wave), groups=len(groups))
                self._enqueue_wave(wave)
                moves0, moved0, staged0 = self._traffic_snapshot()
                disp0 = self._dispatches
                t0 = time.perf_counter()
                with trace_span(f"bddt/{self.kind}/wave{wid}", self.profile):
                    for group in groups.values():
                        self._run_wave_group(group)
                wall = time.perf_counter() - t0
                moves1, moved1, staged1 = self._traffic_snapshot()
                self.obs.emit("wave_close", wave=wid, executor=self.kind,
                              tasks=len(wave), wall_s=wall,
                              dispatches=self._dispatches - disp0,
                              tile_moves=moves1 - moves0,
                              bytes_moved=moved1 - moved0,
                              bytes_staged=staged1 - staged0)
            else:
                for group in groups.values():
                    self._run_group(group)
            for td in wave:
                self.scheduler._collect(td)
        self.scheduler.release_all()

    def barrier(self) -> None:
        self._run_waves(self.pending)
        self.pending.clear()

    def wait_for(self, tds) -> None:
        """Stage and dispatch *only* the dependence cone of ``tds``; every
        pending task outside the cone stays pending for a later wave."""
        cone = dependence_cone(tds)
        if not cone:
            return
        self._run_waves([td for td in self.pending if td in cone])
        self.pending = [td for td in self.pending if td not in cone]

    def reclaim(self) -> None:
        self.barrier()


def _run_one(td: TaskDescriptor, jfn: Callable, device=None) -> None:
    """Run one task through a jitted function.  ``device`` (if given) is
    the execution destination: operands assemble directly on it, so jit,
    following its inputs, executes the body on the task's owner device
    and resident tiles are read in place."""
    td.state = TaskState.RUNNING
    if device is None:
        in_vals = [a.region.materialize() for a in td.args if a.READS]
        values = td.values
    else:
        in_vals = [a.region.materialize(device=device)
                   for a in td.args if a.READS]
        values = tuple(jax.device_put(jnp.asarray(v), device)
                       for v in td.values)
    with suspend_runtime_scope():        # tracing runs fn on this thread
        result = jfn(*in_vals, *values)
    outs = td.outputs
    result = normalize_outputs(result, len(outs), td.name or td.tid)
    for mode, value in zip(outs, result):
        mode.region.store(value)
    td.output_values = result
