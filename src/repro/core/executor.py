"""Executors: how a discovered task graph actually runs.

* :class:`SequentialExecutor` — serial elision; the oracle for tests.
* :class:`HostExecutor` — the paper-faithful dynamic runtime: the host
  thread is the SCC master, worker threads drain MPB descriptor rings and
  execute jitted tile tasks.  Reproduces the paper's protocol including
  bounded slots, master-never-blocks spawns, lazy collection and release.
* :class:`StagedExecutor` — the TPU-idiomatic adaptation: the DAG is
  layered into wavefronts and each wavefront's identical tile tasks are
  fused into one batched (``vmap``-ed, jitted) dispatch.  On an SPMD
  machine there is no dynamic master->worker dispatch at run time, so the
  descriptor traffic of the paper is staged into the compiled program —
  the dependence analysis is unchanged, only the dispatch is ahead-of-time.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Callable

import jax
import jax.numpy as jnp

from .graph import TaskDescriptor, TaskGraph, TaskState
from .mpb import MPBQueue
from .scheduler import MasterScheduler

__all__ = ["SequentialExecutor", "HostExecutor", "StagedExecutor"]


class ExecutorBase:
    """Interface between the runtime front-end (spawn/barrier) and an
    execution strategy."""

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        raise NotImplementedError

    def barrier(self) -> None:
        raise NotImplementedError

    def reclaim(self) -> None:
        """Make progress so a descriptor can be recycled (pool exhausted)."""
        self.barrier()

    def shutdown(self) -> None:
        pass


# ---------------------------------------------------------------------------
class SequentialExecutor(ExecutorBase):
    """Serial elision: run each task at spawn, in program order.  Program
    order is a topological order of the dependence DAG by construction, so
    every dependence is satisfied."""

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler):
        self.graph = graph
        self.scheduler = scheduler

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        assert ready, ("sequential spawn found an unresolved dependence; "
                       "program order must satisfy all deps")
        td.state = TaskState.RUNNING
        td.run()
        self.scheduler._collect(td)
        self.scheduler.release_all()

    def barrier(self) -> None:
        assert self.graph.quiescent


# ---------------------------------------------------------------------------
class _Worker(threading.Thread):
    """A worker core: drains its MPB ring, executes tasks, marks slots
    completed (§3.5).  Cache invalidate/flush fences around the task body
    are no-ops on coherent CPython (charged for real in the DES)."""

    def __init__(self, wid: int, queue: MPBQueue):
        super().__init__(name=f"bddt-worker-{wid}", daemon=True)
        self.wid = wid
        self.queue = queue
        self.stop_flag = threading.Event()
        self.busy_s = 0.0
        self.tasks_run = 0

    def run(self) -> None:
        while not self.stop_flag.is_set():
            td = self.queue.next_ready(timeout=0.05)
            if td is None:
                continue
            td.state = TaskState.RUNNING
            t0 = time.perf_counter()
            # read fence (L2 invalidate) | task body | write fence (L2 flush)
            td.run()
            self.busy_s += time.perf_counter() - t0
            self.tasks_run += 1
            self.queue.mark_completed(td)


class HostExecutor(ExecutorBase):
    """The paper's runtime: master = the spawning host thread."""

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler,
                 queues: list[MPBQueue]):
        self.graph = graph
        self.scheduler = scheduler
        self.queues = queues
        self.workers = [_Worker(q.worker_id, q) for q in queues]
        for w in self.workers:
            w.start()

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        if ready:
            # running mode: one attempt, never block (§3.4)
            self.scheduler.schedule_running(td)
        # dependent tasks stay in the task graph until released

    def barrier(self) -> None:
        # polling mode until every spawned task has been released
        while not self.graph.quiescent:
            self.scheduler.polling_step()
            if not self.graph.quiescent:
                time.sleep(0)  # yield to worker threads

    def reclaim(self) -> None:
        # §3.3: master blocks until a task completes, freeing a descriptor
        while self.scheduler.pool.free == 0:
            self.scheduler.polling_step()
            time.sleep(0)

    def shutdown(self) -> None:
        for w in self.workers:
            w.stop_flag.set()
        for w in self.workers:
            w.join(timeout=2.0)


# ---------------------------------------------------------------------------
class StagedExecutor(ExecutorBase):
    """Wavefront staging: spawn only records; the barrier layers the DAG and
    dispatches each layer as batched jitted calls.

    Grouping: tasks in one wavefront with the same function and the same
    input/output signature are stacked and executed through one
    ``jit(vmap(fn))`` call — the TPU analogue of handing each worker its MPB
    queue of identical tile tasks.  The stacked axis is the "worker" axis;
    under ``shard_map`` on real hardware it shards over the mesh.
    """

    def __init__(self, graph: TaskGraph, scheduler: MasterScheduler,
                 group: bool = True):
        self.graph = graph
        self.scheduler = scheduler
        self.group = group
        self.pending: list[TaskDescriptor] = []
        self._vjit: dict[Callable, Callable] = {}
        self._jit: dict[Callable, Callable] = {}
        self.waves_run = 0
        self.grouped_dispatches = 0

    def on_spawn(self, td: TaskDescriptor, ready: bool) -> None:
        self.pending.append(td)

    # -- wavefront layering ---------------------------------------------------
    def _wavefronts(self) -> list[list[TaskDescriptor]]:
        indeg = {td: td.deps_remaining for td in self.pending}
        frontier = [td for td, d in indeg.items() if d == 0]
        waves = []
        seen = 0
        while frontier:
            waves.append(frontier)
            seen += len(frontier)
            nxt: list[TaskDescriptor] = []
            for td in frontier:
                for dep in td.dependents:
                    if dep in indeg:
                        indeg[dep] -= 1
                        if indeg[dep] == 0:
                            nxt.append(dep)
            frontier = nxt
        if seen != len(self.pending):
            raise RuntimeError("cycle in task graph (impossible for "
                               "footprint-derived deps)")
        return waves

    def _sig(self, td: TaskDescriptor):
        parts = [td.fn]
        for m in td.args:
            parts.append((type(m).__name__, m.region.shape,
                          str(m.region.array.dtype)))
        return tuple(parts)

    def _run_group(self, group: list[TaskDescriptor]) -> None:
        fn = group[0].fn
        if len(group) == 1 or not self.group:
            jfn = self._jit.setdefault(fn, jax.jit(fn))
            for td in group:
                _run_one(td, jfn)
            return
        # batched dispatch: stack each READS arg across the group
        ins = []
        for pos in range(len(group[0].args)):
            if not group[0].args[pos].READS:
                continue
            ins.append(jnp.stack(
                [td.args[pos].region.materialize() for td in group]))
        vfn = self._vjit.setdefault(fn, jax.jit(jax.vmap(fn)))
        result = vfn(*ins)
        n_out = len(group[0].outputs)
        if n_out == 1:
            result = (result,)
        self.grouped_dispatches += 1
        for i, td in enumerate(group):
            for mode, stacked in zip(td.outputs, result):
                mode.region.store(stacked[i])

    def barrier(self) -> None:
        waves = self._wavefronts()
        for wave in waves:
            self.waves_run += 1
            groups: dict = defaultdict(list)
            for td in wave:
                groups[self._sig(td)].append(td)
            for group in groups.values():
                self._run_group(group)
            for td in wave:
                self.scheduler._collect(td)
        self.scheduler.release_all()
        self.pending.clear()

    def reclaim(self) -> None:
        self.barrier()


def _run_one(td: TaskDescriptor, jfn: Callable) -> None:
    td.state = TaskState.RUNNING
    in_vals = [a.region.materialize() for a in td.args if a.READS]
    result = jfn(*in_vals)
    outs = td.outputs
    if len(outs) == 1:
        result = (result,)
    for mode, value in zip(outs, result):
        mode.region.store(value)
