"""Calibration of the SCC cost model against the paper's microbenchmarks.

``costmodel.SCCParams`` ships with plausible SCC magnitudes; this module
*fits* the three constants the paper actually measures to the published
microbenchmark shapes and then checks that the fitted model still
reproduces the paper's two qualitative findings:

* **Fig 3** — DRAM access latency grows linearly with the core's mesh-hop
  distance from the memory controller.  The anchor points below are the
  digitized curve (cycles per cache-line access at each hop count); the
  fit recovers ``dram_base_cycles`` (intercept) and ``dram_hop_cycles``
  (slope) by least squares.
* **Fig 4** — concurrent access through one controller degrades
  near-linearly in the number of accessing cores.  The anchors are
  slowdown factors relative to a single accessor; the fit recovers
  ``contention_alpha`` (slope of ``1 + alpha * (cores - 1)``) by
  through-origin least squares on ``slowdown - 1``.

:func:`calibrate` = fit + trend validation: the calibrated parameters
must still make striped placement beat single-controller placement on a
memory-bound task graph (§4.2) and put the granularity sweep's optimum at
an *interior* tile size (§4.3 — too-fine tasks hit the master bottleneck,
too-coarse tasks starve workers).  Validation runs on self-contained
probe graphs so the fit step has no dependency on the benchmarks package;
``benchmarks/run.py`` re-validates on the full paper workloads.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from .costmodel import SCCParams
from .sim import SimTask, sequential_time, simulate

__all__ = ["CalibrationError", "CalibrationResult", "FIG3_LATENCY_CYCLES",
           "FIG4_SLOWDOWN", "fit_params", "validate_trends", "calibrate"]


# Anchor shapes digitized from the paper's microbenchmark figures.
# Fig 3: cycles per cache-line DRAM access vs mesh-hop distance to the MC.
FIG3_LATENCY_CYCLES: dict[int, float] = {
    0: 255.0, 2: 289.0, 4: 321.0, 6: 352.0, 8: 385.0,
}
# Fig 4: slowdown of one accessor when `cores` cores hammer the same MC
# (reference core fixed at the paper's worst-case 9 hops).
FIG4_SLOWDOWN: dict[int, float] = {
    1: 1.00, 2: 1.56, 4: 2.67, 8: 4.88, 16: 9.22, 24: 13.70, 32: 18.10,
}


class CalibrationError(RuntimeError):
    """The fitted parameters no longer reproduce a paper finding."""


@dataclass(frozen=True)
class CalibrationResult:
    """A fitted :class:`SCCParams` plus fit quality and trend checks."""
    params: SCCParams
    fig3_max_rel_err: float
    fig4_max_rel_err: float
    checks: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return all(self.checks.values()) if self.checks else True

    def as_dict(self) -> dict:
        """JSON-ready summary (consumed by the BENCH emitter)."""
        return {
            "dram_base_cycles": self.params.dram_base_cycles,
            "dram_hop_cycles": self.params.dram_hop_cycles,
            "contention_alpha": self.params.contention_alpha,
            "fig3_max_rel_err": self.fig3_max_rel_err,
            "fig4_max_rel_err": self.fig4_max_rel_err,
            "checks": {k: bool(v) for k, v in self.checks.items()},
        }


def fit_params(base: SCCParams | None = None,
               fig3: dict[int, float] | None = None,
               fig4: dict[int, float] | None = None) -> CalibrationResult:
    """Least-squares fit of the measured constants; everything else keeps
    ``base``'s values (frozen dataclass -> a new instance is returned)."""
    base = base or SCCParams()
    fig3 = fig3 or FIG3_LATENCY_CYCLES
    fig4 = fig4 or FIG4_SLOWDOWN

    hops = np.array(sorted(fig3), dtype=float)
    lat = np.array([fig3[int(h)] for h in hops])
    slope, intercept = np.polyfit(hops, lat, 1)

    cores = np.array(sorted(fig4), dtype=float)
    slow = np.array([fig4[int(c)] for c in cores])
    x, y = cores - 1.0, slow - 1.0
    alpha = float(x @ y / max(x @ x, 1e-12))

    fitted = dataclasses.replace(base,
                                 dram_base_cycles=float(intercept),
                                 dram_hop_cycles=float(slope),
                                 contention_alpha=alpha)
    lat_hat = intercept + slope * hops
    slow_hat = 1.0 + alpha * x
    return CalibrationResult(
        params=fitted,
        fig3_max_rel_err=float(np.max(np.abs(lat_hat - lat) / lat)),
        fig4_max_rel_err=float(np.max(np.abs(slow_hat - slow) / slow)),
    )


# ---------------------------------------------------------------------------
# probe task graphs — minimal shapes of the paper's two findings
def _probe_stream(placement: str, *, n_tasks: int = 256,
                  tile: int = 256) -> list[SimTask]:
    """Independent memory-bound tasks (a jacobi/fft-shaped stream): with
    ``single`` placement every access funnels through MC0 and contention
    dominates; ``striped`` spreads the load over all four controllers."""
    byts = 2.0 * tile * tile * 4
    return [SimTask(tid=i, flops=4.0 * tile * tile, mem_bytes=byts,
                    homes=(i % 4 if placement == "striped" else 0,),
                    n_blocks=2)
            for i in range(n_tasks)]


def _probe_matmul(*, n: int = 1024, tile: int = 64) -> list[SimTask]:
    """The granularity probe: tiled C += A@B at fixed problem size, tasks
    chained over k (same DAG shape as ``benchmarks.workloads.matmul``)."""
    g = n // tile
    flops = 2.0 * tile ** 3
    byts = 3 * tile * tile * 4 * 0.15       # L2 tile reuse, per the paper
    tasks, tid = [], 0
    for i in range(g):
        for j in range(g):
            prev = None
            for k in range(g):
                homes = tuple({(i * g + k) % 4, (k * g + j) % 4,
                               (i * g + j) % 4})
                tasks.append(SimTask(
                    tid=tid, flops=flops, mem_bytes=byts, homes=homes,
                    deps=(prev,) if prev is not None else (), n_blocks=3))
                prev = tid
                tid += 1
    return tasks


def granularity_sweep(p: SCCParams, *, workers: int = 43, n: int = 512,
                      tiles=(128, 64, 32, 16)) -> list[dict]:
    """Speedup vs tile size on the matmul probe (§4.3's sweep shape).
    The default sizes are the smallest instance that keeps the sweep's
    optimum interior (too-coarse starves workers of parallelism, too-fine
    hits the master bottleneck); ``benchmarks.granularity`` runs the
    paper-size version."""
    rows = []
    for tile in tiles:
        tasks = _probe_matmul(n=n, tile=tile)
        seq = sequential_time(_probe_matmul(n=n, tile=tile), p)
        r = simulate(tasks, workers, p)
        rows.append({"tile": tile, "tasks": len(tasks),
                     "speedup": seq / r.total_s})
    return rows


def validate_trends(p: SCCParams, *, workers: int = 43) -> dict:
    """The paper's qualitative findings, as booleans on model ``p``."""
    checks: dict[str, bool] = {}
    lat = [p.mem_time_s(2 ** 20, h) for h in range(10)]
    checks["fig3_latency_monotone_in_hops"] = \
        all(b > a for a, b in zip(lat, lat[1:]))
    con = [p.mem_time_s(2 ** 20, 9, concurrent=c) for c in range(1, 33)]
    checks["fig4_time_monotone_in_contention"] = \
        all(b > a for a, b in zip(con, con[1:]))

    striped = simulate(_probe_stream("striped"), workers, p).total_s
    single = simulate(_probe_stream("single"), workers, p).total_s
    checks["striped_beats_single"] = striped < 0.7 * single

    sweep = granularity_sweep(p, workers=workers)
    best = max(range(len(sweep)), key=lambda i: sweep[i]["speedup"])
    checks["granularity_interior_optimum"] = 0 < best < len(sweep) - 1
    return checks


def calibrate(base: SCCParams | None = None, *,
              validate: bool = True) -> CalibrationResult:
    """Fit the measured constants and (by default) assert the calibrated
    model still reproduces the paper's trends; raises
    :class:`CalibrationError` when a finding no longer holds."""
    res = fit_params(base)
    if not validate:
        return res
    checks = validate_trends(res.params)
    res = dataclasses.replace(res, checks=checks)
    bad = [k for k, v in checks.items() if not v]
    if bad:
        raise CalibrationError(
            f"calibrated SCCParams no longer reproduce: {', '.join(bad)} "
            f"(fitted {res.as_dict()})")
    return res
