"""The BDDT-SCC front-end: declarative tasks, futures, region-scoped waits.

The programming model (OmpSs in JAX clothing) — declare each kernel's
footprint once with :func:`~repro.core.api.task`, then call it naturally
inside a runtime scope::

    from repro.core import RuntimeConfig, TaskRuntime, task

    @task(inout="c", in_=("a", "b"), firstprivate="alpha")
    def gemm(c, a, b, alpha=1.0):
        return c + alpha * (a @ b)

    with TaskRuntime(RuntimeConfig(executor="host", n_workers=4)) as rt:
        A = rt.from_array(a, block_shape=(64, 64))
        B = rt.from_array(b, block_shape=(64, 64))
        C = rt.zeros((n, n), block_shape=(64, 64))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    # regions bind the footprint; alpha is firstprivate,
                    # copied by value into the task descriptor
                    f = gemm(C[i, j], A[i, k], B[k, j], 0.5)  # TaskFuture
        rt.wait_on(C[0, 0])      # taskwait on a region: forces only the
        ...                      # tasks (and deps) touching that block
        rt.barrier()             # global sync (also implied at scope exit)
    result = C.gather()

Synchronization surface:

* ``future.result()`` / ``future.wait()`` — force one task's dependence
  cone only;
* ``rt.wait_on(region, mode=...)`` — the paper's automatic sync
  generalized past the global barrier: wait for the live tasks whose
  footprints conflict with ``region`` under ``mode`` ("in" waits for
  pending writers; "out"/"inout" also waits for readers);
* ``rt.barrier()`` — full quiescence.

The imperative form ``rt.spawn(fn, In(A[i, k]), InOut(C[i, j]))`` is gone
(its deprecation window closed; ``@task`` is the only spawn surface — the
shared initiation path lives in :meth:`TaskRuntime._initiate`).  Task
functions receive one array per READS argument (in argument order), then
their firstprivate values (in parameter order), and return one array per
WRITES argument (in argument order).
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Sequence

from .api import (ExecutorKind, RuntimeConfig, RuntimeStats, TaskFuture,
                  _pop_runtime, _push_runtime)
from .blocks import AccessMode, BlockArray, Region, TileTraffic, coerce_mode
from .deps import DependenceAnalyzer
from .executor import (Executor, HostExecutor, SequentialExecutor,
                       StagedExecutor)
from .graph import DescriptorPool, TaskDescriptor, TaskGraph
from .mpb import MPBQueue
from .placement import assign_homes
from .scheduler import MasterScheduler

__all__ = ["TaskRuntime"]


class TaskRuntime:
    """One master + N workers + the block store, wired per the paper."""

    def __init__(self, config: RuntimeConfig | None = None, **overrides):
        if config is None:
            config = RuntimeConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        # validate() also normalizes typed choice members (ExecutorKind
        # etc.) to canonical strings — internals only see those
        self.config = config = config.validate()
        self.executor_kind = config.executor
        self.placement = config.placement
        self.n_controllers = config.n_controllers
        self.graph = TaskGraph()
        self.pool = DescriptorPool(config.pool_capacity)
        if config.dep_manager == "sharded":
            from .depman import ShardedDependenceManager
            # "auto" resolves here, at construction: threaded iff
            # REPRO_DEPMAN_THREADS parses as a positive integer (which
            # also caps the pump-thread count); explicit "sync" /
            # "threaded" are always honored regardless of environment
            pump = config.dep_pump
            if pump == "auto":
                import os
                try:
                    n_threads = int(os.environ.get(
                        "REPRO_DEPMAN_THREADS", "0"))
                except ValueError:
                    n_threads = 0
                pump = "threaded" if n_threads > 0 else "sync"
            self.dep_pump = pump
            self.analyzer = ShardedDependenceManager(
                n_managers=config.n_controllers,
                channel_slots=config.mpb_slots,
                batch_lines=config.dep_batch_lines,
                pump=pump)
        else:
            self.dep_pump = None
            self.analyzer = DependenceAnalyzer()
        self.queues = [MPBQueue(w, config.mpb_slots)
                       for w in range(config.n_workers)]
        self.scheduler = MasterScheduler(self.queues, self.graph, self.pool,
                                         self.analyzer, policy=config.policy,
                                         seed=config.seed)
        # measured tile movement (shared by every array this runtime
        # registers; the memory layer charges it, stats() reports it)
        self.traffic = TileTraffic()
        # observability: one tracker per runtime, handed to the scheduler
        # and the executor — the single emit point of the subsystem.
        # ``owned`` sinks (built from a spec string) are closed at
        # shutdown; caller-provided instances stay open for inspection.
        from repro.obs.tracker import make_tracker
        self.obs, self._obs_owned = make_tracker(config.tracker)
        self._closed = False
        self.scheduler.obs = self.obs
        if hasattr(self.analyzer, "register_array"):
            # sharded dependence manager: emits dep_msg/manager_admit
            # events through the runtime's tracker like everything else
            self.analyzer.obs = self.obs
        self._exec: Executor = self._make_executor(config)
        self._exec.obs = self.obs
        self._exec.traffic = self.traffic
        self._exec.profile = config.profile_waves
        self._arrays: list[BlockArray] = []
        # ``repro.serve`` attaches its AdmissionController here so
        # ``stats()`` surfaces the admission_* fields; None when the
        # runtime is not serving
        self.admission = None
        self._spawn_counter = 0
        self.spawn_time_s = 0.0
        self.barrier_time_s = 0.0
        self.wait_time_s = 0.0
        self.region_waits = 0
        self.futures_resolved = 0

    def _make_executor(self, config: RuntimeConfig) -> Executor:
        if config.executor == ExecutorKind.SEQUENTIAL:
            return SequentialExecutor(self.graph, self.scheduler)
        if config.executor == ExecutorKind.HOST:
            return HostExecutor(self.graph, self.scheduler, self.queues,
                                cache_tiles=config.worker_cache_tiles)
        if config.executor == ExecutorKind.SIM:
            from .sim import SimExecutor
            return SimExecutor(self.graph, self.scheduler,
                               n_workers=config.n_workers,
                               mpb_slots=config.mpb_slots,
                               cost_fn=config.sim_cost_fn,
                               params=config.sim_params,
                               dep_managers=(config.n_controllers
                                             if config.dep_manager ==
                                             "sharded" else None),
                               dep_batch_lines=config.dep_batch_lines,
                               kernel_backend=config.kernel_backend)
        if config.executor == ExecutorKind.SHARDED:
            from .sharded import ShardedExecutor
            return ShardedExecutor(
                self.graph, self.scheduler, group=config.group_waves,
                n_homes=config.n_controllers,
                owner_skew_threshold=config.owner_skew_threshold,
                kernel_backend=config.kernel_backend)
        return StagedExecutor(self.graph, self.scheduler,
                              group=config.group_waves,
                              kernel_backend=config.kernel_backend)

    # -- memory management (§3.2): the custom allocator --------------------------
    def _register(self, ba: BlockArray) -> BlockArray:
        """Assign homes, attach the runtime's traffic recorder, and — if
        the executor wants residency (sharded under a mesh) — swap in the
        store that places each tile on its home device.  After this,
        ``from_array``/``zeros``/``full`` results physically live where
        ``placement.device_assignment`` says they do."""
        assign_homes(ba, self.placement, self.n_controllers)
        ba.traffic = self.traffic
        register = getattr(self.analyzer, "register_array", None)
        if register is not None:
            # sharded dependence manager learns the block -> home map so
            # footprints route to the owning per-home manager
            register(ba)
        make_store = getattr(self._exec, "make_store", None)
        if make_store is not None:
            store = make_store(ba)
            if store is not None:
                ba.use_store(store)
        self._arrays.append(ba)
        return ba

    def from_array(self, arr, block_shape: Sequence[int],
                   name: str | None = None) -> BlockArray:
        return self._register(BlockArray.from_array(arr, block_shape, name))

    def zeros(self, shape, block_shape, dtype=None,
              name: str | None = None) -> BlockArray:
        import jax.numpy as jnp
        return self._register(BlockArray.zeros(
            shape, block_shape, dtype or jnp.float32, name))

    def full(self, shape, block_shape, fill, dtype=None,
             name: str | None = None) -> BlockArray:
        import jax.numpy as jnp
        return self._register(BlockArray.full(
            shape, block_shape, fill, dtype or jnp.float32, name))

    # -- task initiation (§3.3) -----------------------------------------------------
    def _initiate(self, fn: Callable, args: Sequence[AccessMode],
                  name: str = "", values: tuple = ()) -> TaskFuture:
        """The task-initiation path shared by ``@task`` spawn sites and the
        deprecated imperative ``spawn`` shim: acquire a descriptor (blocking
        on pool exhaustion), discover dependencies, hand to the executor.
        ``values`` carries the firstprivate by-value parameters."""
        t0 = time.perf_counter()
        td = self.pool.acquire(fn, args, name=name, values=values)
        while td is None:
            # §3.3: no free descriptors -> master blocks until one recycles
            self._exec.reclaim()
            td = self.pool.acquire(fn, args, name=name, values=values)
        td.spawn_order = self._spawn_counter
        self._spawn_counter += 1
        deps = self.analyzer.analyze(td)
        ready = self.graph.insert(td, deps)
        self._exec.on_spawn(td, ready)
        self.spawn_time_s += time.perf_counter() - t0
        return TaskFuture(self, td)

    # -- synchronization ---------------------------------------------------------------
    def _wait_tasks(self, tds: Sequence[TaskDescriptor],
                    kind: str = "future") -> None:
        t0 = time.perf_counter()
        self._exec.wait_for(tds)
        self.wait_time_s += time.perf_counter() - t0
        if kind == "future":
            self.futures_resolved += len(tds)

    def wait_on(self, *regions, mode="in") -> None:
        """Region-scoped taskwait (OmpSs ``taskwait on(...)``).

        Returns once every live task whose footprint conflicts with
        ``regions`` under ``mode`` has completed — in-flight tasks with
        disjoint footprints are *not* waited for.  ``mode`` is ``"in"``/
        ``"out"``/``"inout"`` or the matching ``AccessMode`` member:
        ``"in"`` waits for pending writers (the regions' values become
        readable); ``"out"``/``"inout"`` additionally waits for pending
        readers (the regions become safely overwritable)."""
        mode = coerce_mode(mode)
        blocks = []
        for r in regions:
            if isinstance(r, BlockArray):
                r = r.whole
            if isinstance(r, AccessMode):
                raise TypeError("wait_on takes regions, not In/Out/InOut "
                                "wrappers; pass e.g. A[i, j]")
            if not isinstance(r, Region):
                raise TypeError(f"wait_on expected a Region or BlockArray, "
                                f"got {type(r).__name__}")
            blocks.extend(r.block_ids)
        targets = self.analyzer.tasks_touching(blocks, mode=mode)
        self.region_waits += 1
        if targets:
            self._wait_tasks(sorted(targets, key=lambda t: t.spawn_order),
                             kind="region")

    def wait_all(self, futures: Sequence[TaskFuture]) -> list:
        """Wait on several futures at once; returns their results."""
        self._wait_tasks([f.descriptor for f in futures], kind="future")
        return [f.result() for f in futures]

    def barrier(self) -> None:
        t0 = time.perf_counter()
        self._exec.barrier()
        quiesce = getattr(self.analyzer, "quiesce", None)
        if quiesce is not None:
            # sharded manager: flush buffered release descriptors and
            # wait out the pump threads so metadata and the batch/line
            # counters are exact at the barrier
            quiesce()
        self.barrier_time_s += time.perf_counter() - t0
        assert self.graph.quiescent

    def shutdown(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._exec.shutdown()
        stop_analyzer = getattr(self.analyzer, "shutdown", None)
        if stop_analyzer is not None:
            # quiesces and joins the dependence pump threads, so the
            # stats emitted below carry final counter values
            stop_analyzer()
        if self.obs.enabled:
            # the final stats snapshot, in the same schema to_json() emits
            # — one source of truth for the console summary and reports
            self.obs.emit("stats", stats=self.stats().to_dict())
        if self._obs_owned:
            self.obs.close()

    # -- the runtime scope --------------------------------------------------------------
    @contextlib.contextmanager
    def scope(self):
        """Activate as the ambient runtime for ``@task`` calls *without*
        taking ownership: no barrier or shutdown at exit.  Use ``with
        rt:`` for the owning form (callers that create the runtime)."""
        _push_runtime(self)
        try:
            yield self
        finally:
            _pop_runtime(self)

    def __enter__(self) -> "TaskRuntime":
        _push_runtime(self)
        return self

    def __exit__(self, *exc) -> None:
        _pop_runtime(self)
        try:
            if exc == (None, None, None):
                self.barrier()
        finally:
            self.shutdown()

    # -- instrumentation -----------------------------------------------------------------
    def stats(self) -> RuntimeStats:
        s = RuntimeStats(
            tasks_spawned=self._spawn_counter,
            tasks_scheduled=self.scheduler.tasks_scheduled,
            polling_rounds=self.scheduler.polling_rounds,
            blocks_walked=self.analyzer.blocks_walked,
            deps_found=self.analyzer.deps_found,
            spawn_time_s=self.spawn_time_s,
            barrier_time_s=self.barrier_time_s,
            wait_time_s=self.wait_time_s,
            region_waits=self.region_waits,
            futures_resolved=self.futures_resolved,
            mpb_full_rejections=sum(q.full_rejections for q in self.queues),
        )
        if isinstance(self._exec, HostExecutor):
            s.worker_busy_s = [w.busy_s for w in self._exec.workers]
            s.worker_tasks = [w.tasks_run for w in self._exec.workers]
            s.worker_cache_hits = [w.cache_hits for w in self._exec.workers]
            s.worker_cache_misses = [w.cache_misses
                                     for w in self._exec.workers]
        if isinstance(self._exec, StagedExecutor):
            s.waves = self._exec.waves_run
            s.grouped_dispatches = self._exec.grouped_dispatches
        # wave-kernel backend counters, duck-typed so any executor that
        # routes groups through the pallas layer (staged/sharded real,
        # sim predicted) reports the same fields; inert under "xla"
        if getattr(self._exec, "kernel_backend", "xla") == "pallas":
            s.kernel_dispatches = self._exec.kernel_dispatches
            s.kernel_fallbacks = self._exec.kernel_fallbacks
        # residency semantics are shared by all five executors: the
        # measured movement comes from the memory layer's recorder (zero
        # under executors that never place tiles on devices)
        s.tile_moves = self.traffic.tile_moves
        s.bytes_moved = self.traffic.bytes_moved
        s.bytes_staged = self.traffic.bytes_staged
        # duck-typed (like last_result below) so the single-machine path
        # never imports the sharded module just to fill in stats
        if getattr(self._exec, "cross_home_bytes", None) is not None:
            s.sharded_dispatches = self._exec.sharded_dispatches
            s.cross_home_bytes = self._exec.cross_home_bytes
            s.local_home_bytes = self._exec.local_home_bytes
            s.owner_overrides = self._exec.owner_overrides
        # sharded dependence manager: message traffic + per-manager
        # admissions (duck-typed like the executor extras above)
        if getattr(self.analyzer, "dep_messages", None) is not None:
            s.dep_messages = self.analyzer.dep_messages
            s.dep_batches = self.analyzer.dep_batches
            s.dep_lines = self.analyzer.dep_lines
            s.pump_wall_s = self.analyzer.pump_wall_s
            s.manager_admissions = list(self.analyzer.admissions)
        # serving admission controller (attached by repro.serve.Session)
        if self.admission is not None:
            a = self.admission
            s.admission_submitted = a.submitted
            s.admission_admitted = a.admitted
            s.admission_rejected = a.rejected
            s.admission_deferred = a.deferred
            s.admission_peak_bytes = a.peak_in_flight_bytes
            s.admission_budget_bytes = a.budget_bytes
        if getattr(self._exec, "last_result", None) is not None:
            s.predicted_total_s = self._exec.predicted_total_s
            # the DES never executes bodies: tile_moves is its *predicted*
            # count of cross-home block fetches, staging is always zero
            s.tile_moves = self._exec.predicted_tile_moves
        return s
