"""The BDDT-SCC front-end: spawn tasks with declared footprints, barrier.

Usage (OmpSs in JAX clothing)::

    from repro.core import TaskRuntime, In, Out, InOut

    rt = TaskRuntime(executor="host", n_workers=4)
    A = rt.from_array(a, block_shape=(64, 64))
    B = rt.from_array(b, block_shape=(64, 64))
    C = rt.zeros((n, n), block_shape=(64, 64))

    for i in range(g):
        for j in range(g):
            for k in range(g):
                rt.spawn(gemm_tile, InOut(C[i, j]), In(A[i, k]), In(B[k, j]))
    rt.barrier()
    result = C.gather()

Task functions receive one array per READS argument (in argument order) and
return one array per WRITES argument (in argument order).
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

from .blocks import AccessMode, BlockArray, In, InOut, Out, Region
from .deps import DependenceAnalyzer
from .executor import (ExecutorBase, HostExecutor, SequentialExecutor,
                       StagedExecutor)
from .graph import DescriptorPool, TaskDescriptor, TaskGraph
from .mpb import MPBQueue
from .placement import assign_homes
from .scheduler import MasterScheduler

__all__ = ["TaskRuntime"]

_EXECUTORS = ("sequential", "host", "staged")


class TaskRuntime:
    """One master + N workers + the block store, wired per the paper."""

    def __init__(self, executor: str = "host", n_workers: int = 4,
                 mpb_slots: int = 16, pool_capacity: int = 4096,
                 policy: str = "round_robin", placement: str = "striped",
                 n_controllers: int = 4, group_waves: bool = True,
                 seed: int = 0):
        if executor not in _EXECUTORS:
            raise ValueError(f"executor must be one of {_EXECUTORS}")
        self.executor_kind = executor
        self.placement = placement
        self.n_controllers = n_controllers
        self.graph = TaskGraph()
        self.pool = DescriptorPool(pool_capacity)
        self.analyzer = DependenceAnalyzer()
        self.queues = [MPBQueue(w, mpb_slots) for w in range(n_workers)]
        self.scheduler = MasterScheduler(self.queues, self.graph, self.pool,
                                         self.analyzer, policy=policy,
                                         seed=seed)
        if executor == "sequential":
            self._exec: ExecutorBase = SequentialExecutor(self.graph,
                                                          self.scheduler)
        elif executor == "host":
            self._exec = HostExecutor(self.graph, self.scheduler, self.queues)
        else:
            self._exec = StagedExecutor(self.graph, self.scheduler,
                                        group=group_waves)
        self._arrays: list[BlockArray] = []
        self._spawn_counter = 0
        self.spawn_time_s = 0.0
        self.barrier_time_s = 0.0

    # -- memory management (§3.2): the custom allocator --------------------------
    def _register(self, ba: BlockArray) -> BlockArray:
        assign_homes(ba, self.placement, self.n_controllers)
        self._arrays.append(ba)
        return ba

    def from_array(self, arr, block_shape: Sequence[int],
                   name: str | None = None) -> BlockArray:
        return self._register(BlockArray.from_array(arr, block_shape, name))

    def zeros(self, shape, block_shape, dtype=None,
              name: str | None = None) -> BlockArray:
        import jax.numpy as jnp
        return self._register(BlockArray.zeros(
            shape, block_shape, dtype or jnp.float32, name))

    def full(self, shape, block_shape, fill, dtype=None,
             name: str | None = None) -> BlockArray:
        import jax.numpy as jnp
        return self._register(BlockArray.full(
            shape, block_shape, fill, dtype or jnp.float32, name))

    # -- task initiation (§3.3) -----------------------------------------------------
    def spawn(self, fn: Callable, *args: AccessMode, name: str = "") -> TaskDescriptor:
        for a in args:
            if not isinstance(a, AccessMode):
                raise TypeError(
                    "spawn arguments must be In/Out/InOut(region); got "
                    f"{type(a).__name__}")
        t0 = time.perf_counter()
        td = self.pool.acquire(fn, args, name=name)
        while td is None:
            # §3.3: no free descriptors -> master blocks until one recycles
            self._exec.reclaim()
            td = self.pool.acquire(fn, args, name=name)
        td.spawn_order = self._spawn_counter
        self._spawn_counter += 1
        deps = self.analyzer.analyze(td)
        ready = self.graph.insert(td, deps)
        self._exec.on_spawn(td, ready)
        self.spawn_time_s += time.perf_counter() - t0
        return td

    # -- synchronization ---------------------------------------------------------------
    def barrier(self) -> None:
        t0 = time.perf_counter()
        self._exec.barrier()
        self.barrier_time_s += time.perf_counter() - t0
        assert self.graph.quiescent

    def shutdown(self) -> None:
        self._exec.shutdown()

    def __enter__(self) -> "TaskRuntime":
        return self

    def __exit__(self, *exc) -> None:
        try:
            if exc == (None, None, None):
                self.barrier()
        finally:
            self.shutdown()

    # -- instrumentation -----------------------------------------------------------------
    def stats(self) -> dict:
        s = {
            "tasks_spawned": self._spawn_counter,
            "tasks_scheduled": self.scheduler.tasks_scheduled,
            "polling_rounds": self.scheduler.polling_rounds,
            "blocks_walked": self.analyzer.blocks_walked,
            "deps_found": self.analyzer.deps_found,
            "spawn_time_s": self.spawn_time_s,
            "barrier_time_s": self.barrier_time_s,
            "mpb_full_rejections": sum(q.full_rejections for q in self.queues),
        }
        if isinstance(self._exec, HostExecutor):
            s["worker_busy_s"] = [w.busy_s for w in self._exec.workers]
            s["worker_tasks"] = [w.tasks_run for w in self._exec.workers]
        if isinstance(self._exec, StagedExecutor):
            s["waves"] = self._exec.waves_run
            s["grouped_dispatches"] = self._exec.grouped_dispatches
        return s
