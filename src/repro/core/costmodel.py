"""SCC hardware cost model — calibrated to the paper's microbenchmarks.

Figure 3: DRAM access time grows with the core's mesh-hop distance from
the memory controller.  Figure 4: concurrent access through one controller
degrades sharply (near-linear in the number of accessing cores).  This
module models both, plus MPB descriptor traffic and the P54C's
whole-L2 flush/invalidate penalty, and is consumed by

* the locality-aware scheduler (tile affinity),
* the DES (``core/sim.py``) that reproduces Figures 5-7, and
* the TPU roofline translation (same three-resource structure: compute,
  local memory, interconnect).

Absolute constants are plausible SCC magnitudes (533 MHz P54C cores,
~256 cycles base DRAM latency, 8 KB MPBs, 32 B lines); the *shape* of the
curves is what the reproduction validates against the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field


# SCC topology: 6x4 tile mesh, 2 cores/tile, 4 MCs on the left/right edges
TILE_COLS, TILE_ROWS = 6, 4
MC_TILES = [(0, 0), (0, 2), (5, 0), (5, 2)]


def tile_of_core(core: int) -> tuple[int, int]:
    tile = core // 2
    return tile % TILE_COLS, tile // TILE_COLS


def hops(a: tuple[int, int], b: tuple[int, int]) -> int:
    return abs(a[0] - b[0]) + abs(a[1] - b[1])


def core_mc_hops(core: int, mc: int) -> int:
    return hops(tile_of_core(core), MC_TILES[mc])


def core_core_hops(a: int, b: int) -> int:
    return hops(tile_of_core(a), tile_of_core(b))


@dataclass(frozen=True)
class SCCParams:
    freq_hz: float = 533e6
    # Fig 3: DRAM latency = base + per-hop cycles (round trip)
    dram_base_cycles: float = 256.0
    dram_hop_cycles: float = 16.0
    cacheline_bytes: int = 32
    # Fig 4: contention slope — effective latency multiplier per extra
    # concurrent accessor on the same controller
    contention_alpha: float = 0.55
    # compute: P54C ~0.5 sustained flops/cycle
    flops_per_cycle: float = 0.5
    # L1 hit ratio proxy: fraction of a task's footprint actually fetched
    # from DRAM (rest is cache-resident across the task)
    dram_fraction: float = 1.0
    # MPB: descriptor = one 32B line; cost = base + per-hop
    mpb_base_cycles: float = 45.0
    mpb_hop_cycles: float = 8.0
    # whole-L2 flush / invalidate: the P54C has no partial flush (§6) —
    # WBINVD walks all 8192 lines with writebacks, O(100k) cycles
    flush_cycles: float = 8192 * 20.0
    invalidate_cycles: float = 8192 * 18.0
    # master-side costs (cycles)
    spawn_base_cycles: float = 1200.0
    dep_block_cycles: float = 90.0      # per footprint block walked
    schedule_cycles: float = 350.0
    poll_cycles: float = 120.0
    release_cycles: float = 400.0

    def seconds(self, cycles: float) -> float:
        return cycles / self.freq_hz

    # -- Fig 3: latency vs hops ------------------------------------------------
    def dram_access_cycles(self, n_hops: int) -> float:
        return self.dram_base_cycles + self.dram_hop_cycles * n_hops

    def mem_time_s(self, nbytes: float, n_hops: int,
                   concurrent: int = 1) -> float:
        """Time for one core to move ``nbytes`` through one MC with
        ``concurrent`` total accessors on that controller (Fig 4)."""
        lines = max(nbytes / self.cacheline_bytes, 1.0)
        per_line = self.dram_access_cycles(n_hops)
        factor = 1.0 + self.contention_alpha * max(concurrent - 1, 0)
        return self.seconds(lines * per_line * factor * self.dram_fraction)

    def compute_time_s(self, flops: float) -> float:
        return self.seconds(flops / self.flops_per_cycle)

    def mpb_write_s(self, n_hops: int) -> float:
        return self.seconds(self.mpb_base_cycles +
                            self.mpb_hop_cycles * n_hops)


@dataclass(frozen=True)
class TPUParams:
    """Target-hardware constants for the roofline (TPU v5e)."""
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_link_bw: float = 50e9

    def roofline_terms(self, flops: float, hbm_bytes: float,
                       link_bytes: float, chips: int = 1) -> dict:
        return {
            "compute_s": flops / (chips * self.peak_flops_bf16),
            "memory_s": hbm_bytes / (chips * self.hbm_bw),
            "collective_s": link_bytes / (chips * self.ici_link_bw),
        }


def master_core_choice() -> int:
    """§4.1: the master sits at a middle core minimizing total hops to all
    MPBs and MCs — the paper picks core 16."""
    best, best_cost = None, None
    for c in range(48):
        t = tile_of_core(c)
        mpb = sum(hops(t, tile_of_core(w)) for w in range(48))
        mc = sum(hops(t, m) for m in MC_TILES)
        worst = max(hops(t, tile_of_core(w)) for w in range(48))
        cost = (worst, mpb + mc)
        if best_cost is None or cost < best_cost:
            best, best_cost = c, cost
    return best


def worker_order(master: int) -> list[int]:
    """Workers sorted by distance from the master (§4.1): every additional
    worker is as close to the master as possible."""
    others = [c for c in range(48) if c != master]
    return sorted(others, key=lambda c: (core_core_hops(master, c), c))
