"""Per-worker task queues in message-passing-buffer style (§3.2, §3.4, §3.5).

On the SCC each worker's task queue is an array of 32-byte-aligned descriptor
slots inside that worker's 8 KB on-chip MPB; the master writes descriptors
directly into remote slots (asynchronously, never interrupting the worker),
and the worker marks slots *completed* in place.  Slot reuse is the
completion signal — there are no interrupts and no locks, just the SPSC
discipline plus explicit fences.

This module reproduces that protocol faithfully as a bounded SPSC ring of
slots with the three states of the paper (EMPTY / READY / COMPLETED) and the
master-side "local index of the next available entry".  On the SCC the fences
are L1 invalidation (read) and write-combine-buffer flush (write); under
CPython the shared memory is coherent, so the fences are no-ops kept as
explicit markers — the DES (``sim.py``) charges their true costs.

The 8 KB MPB / 32 B lines give 512 lines per worker in hardware; descriptor
alignment to MPB cache lines avoids master/worker false sharing, which we
model with one descriptor per slot.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass
from typing import Optional

from .graph import TaskDescriptor

__all__ = ["SlotState", "MPBQueue", "MPBChannel", "MPB_LINE_BYTES",
           "MPB_BYTES_PER_CORE", "DESC_BYTES", "DESCRIPTORS_PER_LINE",
           "lines_for"]

MPB_LINE_BYTES = 32          # one MPB cache line (§3.2)
MPB_BYTES_PER_CORE = 8192    # 8 KB of on-chip SRAM per core

# Dependence-protocol descriptor packing (§3.2): one region-run or grant
# descriptor is 16 bytes (array id + tile range, or a header plus packed
# predecessor ids), so two descriptors share each 32-byte MPB line.  The
# dependence manager, the DES, and the traffic predictor all count lines
# through :func:`lines_for`, which is what keeps predicted and measured
# line counts reconciled.
DESC_BYTES = 16
DESCRIPTORS_PER_LINE = MPB_LINE_BYTES // DESC_BYTES


def lines_for(slots: int) -> int:
    """MPB lines occupied by ``slots`` 16-byte descriptors (>= 1: even an
    empty envelope spends its header line)."""
    if slots <= 0:
        return 1
    return -(-slots // DESCRIPTORS_PER_LINE)


class SlotState(enum.Enum):
    EMPTY = 0
    READY = 1
    COMPLETED = 2


@dataclass
class _Slot:
    state: SlotState = SlotState.EMPTY
    task: Optional[TaskDescriptor] = None


class MPBQueue:
    """Bounded SPSC descriptor ring between the master and one worker.

    Master-side ops: :meth:`try_put` (enqueue a ready task into the next
    slot, collecting a completed descriptor if the slot holds one) and
    :meth:`collect_completed` (poll for finished tasks).  Worker-side ops:
    :meth:`next_ready` / :meth:`mark_completed`.
    """

    def __init__(self, worker_id: int, n_slots: int = 16):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.worker_id = worker_id
        self.n_slots = n_slots
        self._slots = [_Slot() for _ in range(n_slots)]
        self._head = 0   # master's local index of the next entry to fill
        self._tail = 0   # worker's local index of the next entry to run
        # On SCC the protocol is lock-free via the SPSC discipline + fences.
        # A CPython lock stands in for per-line atomic visibility; the
        # protocol logic is unchanged.
        self._lock = threading.Lock()
        self._work_available = threading.Condition(self._lock)
        # instrumentation
        self.enq_count = 0
        self.full_rejections = 0

    # -- master side ---------------------------------------------------------
    def try_put(self, td: TaskDescriptor) -> tuple[bool, Optional[TaskDescriptor]]:
        """Append ``td`` at the master's next slot (§3.4).

        Returns ``(accepted, collected)``: ``collected`` is a completed
        descriptor that was reclaimed from the slot, if any.  If the slot is
        still READY (worker behind), the put is rejected and the master must
        either keep the task in its local ready queue (running mode) or try
        the next worker (polling mode).
        """
        with self._work_available:
            slot = self._slots[self._head]
            collected = None
            if slot.state is SlotState.COMPLETED:
                collected = slot.task
                slot.state = SlotState.EMPTY
                slot.task = None
            if slot.state is not SlotState.EMPTY:
                self.full_rejections += 1
                return False, collected
            slot.task = td
            slot.state = SlotState.READY
            td.worker = self.worker_id
            self._head = (self._head + 1) % self.n_slots
            self.enq_count += 1
            # master does NOT flush its write-combine buffer here (§3.5
            # optimization): the worker may observe the transition late,
            # which only causes it to poll again.
            self._work_available.notify()
            return True, collected

    def collect_completed(self) -> list[TaskDescriptor]:
        """Master poll (§3.4 polling mode, function ii): gather descriptors
        marked completed, freeing their slots for reuse.  Master invalidates
        its L1 before reading a worker's queue (read fence — no-op here)."""
        out = []
        with self._lock:
            for slot in self._slots:
                if slot.state is SlotState.COMPLETED:
                    out.append(slot.task)
                    slot.task = None
                    slot.state = SlotState.EMPTY
        return out

    # -- worker side ----------------------------------------------------------
    def next_ready(self, timeout: float | None = None) -> Optional[TaskDescriptor]:
        """Worker poll: invalidate L1 (read fence — no-op) then check the next
        slot in order.  Blocks up to ``timeout`` for work (the condvar stands
        in for the SCC's polling loop so this container's single CPU isn't
        burned spinning; the DES charges real polling costs)."""
        with self._work_available:
            slot = self._slots[self._tail]
            if slot.state is not SlotState.READY:
                self._work_available.wait(timeout)
                slot = self._slots[self._tail]
            if slot.state is SlotState.READY:
                self._tail = (self._tail + 1) % self.n_slots
                return slot.task
            return None

    def mark_completed(self, td: TaskDescriptor) -> None:
        """Worker marks the descriptor's slot completed, then flushes its
        write-combine buffer (write fence — no-op here) so the master
        observes it (§3.5)."""
        with self._lock:
            for slot in self._slots:
                if slot.task is td:
                    slot.state = SlotState.COMPLETED
                    return
        raise RuntimeError(f"descriptor {td!r} not found in MPB "
                           f"{self.worker_id}")

    # -- introspection ----------------------------------------------------------
    def occupancy(self) -> int:
        with self._lock:
            return sum(1 for s in self._slots
                       if s.state is not SlotState.EMPTY)


class MPBChannel:
    """Bounded SPSC message ring for small typed control messages.

    The dependence managers (``depman.py``) exchange ``dep_query`` /
    ``dep_grant`` / ``release`` messages with the master over these rings
    — the same MPB transport the descriptor queues use (§3.2), but
    carrying a few 32-byte lines of metadata per message instead of a
    task descriptor.

    Unlike :class:`MPBQueue` this ring is lock-free even under CPython:
    the discipline is strictly SPSC — exactly one producer thread and one
    consumer thread per ring (under ``dep_pump="sync"`` both roles run on
    the master; under ``dep_pump="threaded"`` the consumer is the home's
    pump thread).  ``try_send`` refuses when full (the producer must let
    the consumer progress — backpressure, never blocking); ``recv_all``
    drains in FIFO order one ``popleft`` at a time, so a message appended
    concurrently by the producer is either drained this call or intact
    for the next (a snapshot-then-clear drain would drop it).  The GIL
    plus ``deque``'s atomic append/popleft stand in for the SCC's
    per-line fences.  The DES charges ``SCCParams.mpb_write_s`` per MPB
    *line*, with several descriptors packed per line
    (:data:`DESCRIPTORS_PER_LINE`).
    """

    def __init__(self, name: str, n_slots: int = 16):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.name = name
        self.n_slots = n_slots
        from collections import deque
        self._ring: deque = deque()
        # instrumentation (mirrors MPBQueue's counters)
        self.sends = 0
        self.full_stalls = 0

    def try_send(self, msg) -> bool:
        """Producer: append one message, or refuse when the ring is full
        (the caller pumps the consumer and retries — SPSC backpressure)."""
        if len(self._ring) >= self.n_slots:
            self.full_stalls += 1
            return False
        self._ring.append(msg)
        self.sends += 1
        return True

    def recv_all(self) -> list:
        """Consumer: drain every pending message in FIFO order.

        Pops one slot at a time so it is safe against a producer thread
        appending concurrently (SPSC: this method has exactly one
        caller thread per ring); a message appended mid-drain waits for
        the next call, which also bounds one drain at the ring depth."""
        ring = self._ring
        n = len(ring)
        if not n:
            return []
        pop = ring.popleft
        return [pop() for _ in range(n)]

    def __len__(self) -> int:
        return len(self._ring)
