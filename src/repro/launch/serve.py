"""Serving driver: batched prefill + decode with a pre-allocated KV arena.

The server keeps one cache arena sized to ``max_len`` (the dry-run's
decode shapes: one new token against a seq_len cache); requests are
processed in fixed batches — prefill fills the arena, then greedy/sampled
decode steps run until length or EOS.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import api


def build_serve_fns(cfg):
    prefill = jax.jit(lambda params, batch: api.prefill_step(params, cfg,
                                                             batch))
    decode = jax.jit(lambda params, tok, caches, pos:
                     api.decode_step(params, cfg, tok, caches, pos))
    return prefill, decode


def generate(cfg, params, batch, *, max_new_tokens: int, max_len: int,
             temperature: float = 0.0, seed: int = 0):
    """Greedy (or sampled) generation for a batch of prompts."""
    prefill, decode = build_serve_fns(cfg)
    prompt_len = batch["tokens"].shape[1]
    logits, caches = prefill(params, batch)
    caches = api.pad_caches(caches, max_len)
    key = jax.random.PRNGKey(seed)
    outs = []
    tok = None
    for i in range(max_new_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)
            tok = tok[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
        tok = jnp.minimum(tok, cfg.vocab_size - 1)
        outs.append(tok)
        logits, caches = decode(params, tok, caches,
                                jnp.int32(prompt_len + i))
    return jnp.concatenate(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description="repro server (batched)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)}
    if cfg.vision_seq:
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, cfg.vision_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    t0 = time.perf_counter()
    out = generate(cfg, params, batch,
                   max_new_tokens=args.max_new_tokens,
                   max_len=args.prompt_len + args.max_new_tokens + 8)
    dt = time.perf_counter() - t0
    n_tok = out.size
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
