import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory/cost/collective statistics.

This is the proof that the distribution config is coherent: a sharding
mismatch, compile-time OOM or unsupported collective fails the cell.

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count on first init.  Results land in ``experiments/dryrun/`` as one
JSON per (arch, shape, mesh); EXPERIMENTS.md tables are generated from
them by ``benchmarks.roofline``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both [--skip-existing]
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import dist
from ..configs import ARCH_IDS, SHAPES, applicable_shapes, get_config, \
    input_specs
from ..dist.sharding import (batch_shardings, cache_shardings,
                             default_policy, param_shardings)
from ..models import api
from ..optim.adamw import AdamWState, adamw_init
from .flopcount import count_step
from .hlo_stats import collective_stats, memory_stats
from .mesh import make_production_mesh
from .train import build_train_step

HW = {  # TPU v5e
    "peak_flops_bf16": 197e12,
    "hbm_gbps": 819e9,
    "ici_link_gbps": 50e9,
}


def _abstract_params(cfg, *, serving: bool = False):
    p = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    if serving:
        # inference deployments load bf16 weights (no optimizer master
        # copies to protect); matrices cast, small vectors stay f32
        p = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
            if l.ndim >= 2 and jnp.issubdtype(l.dtype, jnp.floating)
            else l, p)
    return p


def _mesh_ctx(multi_pod: bool, *, model_in_batch: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    return mesh, dict(data_axes=("data",), model_axis="model",
                      pod_axis="pod" if multi_pod else None,
                      model_in_batch=model_in_batch)


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *,
               policy: str | None = None, n_layers: int | None = None):
    """Lower + compile one cell; returns (record, compiled)."""
    import dataclasses
    cfg = get_config(arch)
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    spec = SHAPES[shape_name]
    # recurrent families: the model axis joins data parallelism for
    # train/prefill (per-step TP resharding is pathological; §Perf)
    chips = 512 if multi_pod else 256
    mib = (cfg.family in ("hybrid", "ssm")
           and spec.kind in ("train", "prefill")
           and spec.global_batch % chips == 0)
    mesh, ctx_kw = _mesh_ctx(multi_pod, model_in_batch=mib)
    t0 = time.perf_counter()
    with dist.use_mesh(mesh, **ctx_kw) as ctx:
        pol = policy or default_policy(cfg)
        # serving has no optimizer state: FSDP would all-gather weights
        # every layer for nothing — decode shards weights TP-only
        # (§Perf, command-r decode cell)
        if spec.kind == "decode" and pol == "fsdp" \
                and cfg.family in ("dense", "vlm", "moe", "audio"):
            pol = "tp"
        params_abs = _abstract_params(cfg, serving=spec.kind == "decode")
        p_sh = param_shardings(cfg, params_abs, ctx, policy=pol)
        specs = input_specs(cfg, shape_name)
        repl = NamedSharding(mesh, P())

        if spec.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            o_sh = AdamWState(step=repl, mu=p_sh, nu=p_sh)
            b_sh = batch_shardings(cfg, specs["batch"], ctx)
            step_fn = build_train_step(cfg)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, o_sh, b_sh, repl),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif spec.kind == "prefill":
            b_sh = batch_shardings(cfg, specs["batch"], ctx)
            cache_abs = jax.eval_shape(
                lambda p, b: api.prefill_step(p, cfg, b)[1],
                params_abs, specs["batch"])
            c_sh = cache_shardings(cfg, cache_abs, ctx)
            fn = partial(api.prefill_step, cfg=cfg)
            jitted = jax.jit(
                lambda params, batch: api.prefill_step(params, cfg, batch),
                in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh))
            lowered = jitted.lower(params_abs, specs["batch"])
        else:  # decode
            c_sh = cache_shardings(cfg, specs["caches"], ctx)
            tok_sh = NamedSharding(
                mesh, P(ctx.all_data_axes
                        if spec.global_batch % _dp_size(ctx) == 0 else None))
            jitted = jax.jit(
                lambda params, tok, caches, pos:
                api.decode_step(params, cfg, tok, caches, pos),
                in_shardings=(p_sh, tok_sh, c_sh, repl),
                out_shardings=(None, c_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, specs["token"],
                                   specs["caches"], specs["pos"])

        compiled = lowered.compile()

        # exact global flop/byte accounting from the jaxpr (scan lengths
        # applied; see flopcount.py — HLO cost analysis counts loop bodies
        # once and is kept only as "hlo_raw" reference)
        if spec.kind == "train":
            jx = count_step(step_fn, params_abs, opt_abs, specs["batch"],
                            jax.ShapeDtypeStruct((), jnp.int32))
        elif spec.kind == "prefill":
            jx = count_step(
                lambda p, b: api.prefill_step(p, cfg, b),
                params_abs, specs["batch"])
        else:
            jx = count_step(
                lambda p, t, c, i: api.decode_step(p, cfg, t, c, i),
                params_abs, specs["token"], specs["caches"], specs["pos"])

    n_dev = mesh.devices.size
    cost = dict(compiled.cost_analysis() or {})
    mem = memory_stats(compiled)
    colls = collective_stats(compiled.as_text())
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "policy": pol,
        "kind": spec.kind,
        "seq_len": spec.seq_len,
        "global_batch": spec.global_batch,
        "compile_s": round(time.perf_counter() - t0, 2),
        "flops_per_device": float(jx["flops"]) / n_dev,
        "bytes_per_device": float(jx["bytes"]) / n_dev,
        "flops_per_device_hlo_raw": float(cost.get("flops", 0.0)),
        "bytes_per_device_hlo_raw": float(cost.get("bytes accessed", 0.0)),
        "memory": mem,
        "collectives": colls.to_dict(),
    }
    return record, compiled


def _dp_size(ctx):
    import numpy as np
    return int(np.prod([ctx.mesh.shape[a] for a in ctx.all_data_axes]))


def run_cells(archs, shapes, meshes, out_dir: str, *,
              skip_existing: bool = False, calibrate: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        cfg = get_config(arch)
        valid = applicable_shapes(cfg)
        for shape_name in shapes:
            if shape_name not in valid:
                continue
            for mesh_name in meshes:
                multi = mesh_name == "multi"
                tag = f"{arch}__{shape_name}__{'2x16x16' if multi else '16x16'}"
                path = os.path.join(out_dir, tag + ".json")
                if skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip {tag} (exists)")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    record, compiled = lower_cell(arch, shape_name, multi)
                    del compiled
                except Exception as e:
                    record = {"arch": arch, "shape": shape_name,
                              "mesh": "2x16x16" if multi else "16x16",
                              "error": f"{type(e).__name__}: {e}",
                              "traceback": traceback.format_exc()[-2000:]}
                    print(f"[dryrun] FAIL {tag}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(record, f, indent=1)
                if "error" not in record:
                    gb = record["memory"].get("per_device_total_bytes",
                                              0) / 2**30
                    print(f"[dryrun] OK {tag}: "
                          f"{record['flops_per_device']:.3e} flops/dev, "
                          f"{gb:.2f} GiB/dev, "
                          f"{record['collectives']['total_link_bytes']:.3e}"
                          f" link B, {record['compile_s']}s", flush=True)
                results.append(record)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)
    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    results = run_cells(archs, shapes, meshes, args.out,
                        skip_existing=args.skip_existing)
    n_ok = sum(1 for r in results if "error" not in r)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK")
    if n_ok != len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
