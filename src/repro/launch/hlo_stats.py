"""Parse compiled HLO text for collective-communication statistics.

``compiled.cost_analysis()`` has no collective accounting, so the roofline's
communication term comes from here: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute instruction is parsed for
its result shape and replica group size, from which we derive

* ``operand_bytes`` — the spec-literal "sum of operand sizes" (operand =
  result for AR/A2A/CP, result/G for AG, result*G for RS), and
* ``link_bytes``    — ring-model bytes per device actually crossing ICI
  links: AR 2*(G-1)/G * R; AG/RS/A2A (G-1)/G * full; CP = R.

The roofline collective term uses ``link_bytes`` (physically meaningful);
both are recorded.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?((?:\w+\[[\d,]*\](?:\{[^}]*\})?(?:,\s*)?)+)(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    operand_bytes: dict = field(default_factory=lambda: defaultdict(float))
    link_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))

    @property
    def total_link_bytes(self) -> float:
        return float(sum(self.link_bytes.values()))

    def to_dict(self) -> dict:
        return {
            "counts": dict(self.counts),
            "operand_bytes": {k: float(v)
                              for k, v in self.operand_bytes.items()},
            "link_bytes": {k: float(v) for k, v in self.link_bytes.items()},
            "total_operand_bytes": self.total_operand_bytes,
            "total_link_bytes": self.total_link_bytes,
        }


_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)(?:_spmd)?\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        m = _COMP_HDR_RE.match(ls.strip())
        if m and ("->" in ls):
            name = ls.strip().split("(")[0].replace("ENTRY", "").strip() \
                .lstrip("%").rstrip()
            cur = name.split()[0] if name else None
            if cur is not None:
                comps[cur] = []
            continue
        if cur is not None:
            if ls.strip() == "}":
                cur = None
                continue
            comps[cur].append(ls)
    return comps


def _multipliers(comps: dict[str, list[str]]) -> dict[str, float]:
    """Computation -> execution count, from while trip counts.

    A scan lowers to ``while(condition=C, body=B)``; the trip count is the
    iteration-bound constant in C.  Nested scans multiply recursively."""
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    mult: dict[str, float] = {}

    def trip(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            consts += [int(x) for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name]:
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                visit(body, m * trip(cond))
                continue
            # non-while computation references (fusions, reducers, calls)
            for ref in re.findall(r"(?:to_apply|calls|called_computations)="
                                  r"\{?%?([\w\.\-]+)", line):
                visit(ref, m)

    if entry:
        visit(entry, 1.0)
    # anything unreachable (shouldn't happen) counts once
    for name in comps:
        mult.setdefault(name, 1.0)
    return mult


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    comps = _computations(hlo_text)
    mult = _multipliers(comps)
    for comp_name, lines in comps.items():
        m_exec = mult.get(comp_name, 1.0)
        for line in lines:
            _accumulate(stats, line, m_exec)
    if not comps:                      # fallback: flat text
        for line in hlo_text.splitlines():
            _accumulate(stats, line, 1.0)
    return stats


def _accumulate(stats: CollectiveStats, line: str, m_exec: float) -> None:
    if "-done" in line:
        return
    m = _COLL_RE.search(line)
    if not m:
        return
    result_bytes = _shape_bytes(m.group(1))
    kind = m.group(2)
    g = _group_size(line)
    stats.counts[kind] += m_exec
    if kind == "all-reduce":
        op = result_bytes
        link = 2.0 * (g - 1) / max(g, 1) * result_bytes
    elif kind == "all-gather":
        op = result_bytes / max(g, 1)
        link = (g - 1) / max(g, 1) * result_bytes
    elif kind == "reduce-scatter":
        op = result_bytes * g
        link = (g - 1) * result_bytes
    elif kind == "all-to-all":
        op = result_bytes
        link = (g - 1) / max(g, 1) * result_bytes
    else:  # collective-permute
        op = result_bytes
        link = result_bytes
    stats.operand_bytes[kind] += op * m_exec
    stats.link_bytes[kind] += link * m_exec


def memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:            # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0))
    out["per_device_total_bytes"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    return out
