"""Training driver: step builder (used by the dry-run and examples) and a
CLI for small real runs on local devices.

The train step is one pure function: value_and_grad over the chunked-CE
loss, global-norm clip, cosine LR, AdamW.  Sharding comes entirely from
in_shardings/out_shardings at the jit boundary plus the model's internal
shard_map blocks (EP MoE).  Fault tolerance: checkpoint every
``--ckpt-every`` steps (async), deterministic data skip-ahead on restart.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from .. import dist
from ..ckpt import latest_step, restore_checkpoint, save_checkpoint
from ..configs import get_config
from ..data import SyntheticTokens
from ..models import api
from ..optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule


def build_train_step(cfg, *, peak_lr: float = 3e-4, warmup: int = 100,
                     total_steps: int = 10_000, clip: float = 1.0,
                     weight_decay: float = 0.1):
    def train_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, cfg, batch))(params)
        grads, gnorm = clip_by_global_norm(grads, clip)
        lr = cosine_schedule(step, peak_lr=peak_lr, warmup_steps=warmup,
                             total_steps=total_steps)
        params, opt_state = adamw_update(grads, opt_state, params, lr=lr,
                                         weight_decay=weight_decay)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return params, opt_state, metrics
    return train_step


def train_loop(cfg, *, steps: int, seq_len: int, global_batch: int,
               seed: int = 0, ckpt_dir: str | None = None,
               ckpt_every: int = 50, log_every: int = 10,
               peak_lr: float = 3e-4, resume: bool = True,
               on_metrics=None):
    """Single-host training loop (examples / integration tests)."""
    data = SyntheticTokens(cfg.vocab_size, seq_len, global_batch, seed=seed)
    params = api.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw_init(params)
    start = 0
    if ckpt_dir and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), meta, start = restore_checkpoint(
                ckpt_dir, last, (params, opt_state))
            start = int(start)
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(build_train_step(cfg, peak_lr=peak_lr,
                                       total_steps=steps))
    history = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch = data.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['gnorm']:.3f} lr {m['lr']:.2e}")
            if on_metrics:
                on_metrics(m)
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, (params, opt_state),
                            meta={"arch": cfg.name}, async_save=True)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, (params, opt_state),
                        meta={"arch": cfg.name})
    return params, opt_state, history


def main(argv=None):
    ap = argparse.ArgumentParser(description="repro trainer")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    train_loop(cfg, steps=args.steps, seq_len=args.seq_len,
               global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
               peak_lr=args.peak_lr)


if __name__ == "__main__":
    main()
