"""Exact jaxpr-level FLOP and byte accounting.

XLA's ``cost_analysis()`` counts a ``while`` (scan) body once, so for
scan-stacked models it under-reports flops by O(depth x inner-chunk
count).  The jaxpr, by contrast, carries every ``scan``'s static
``length`` — walking it gives exact dot_general flops with all loop
multipliers applied (including remat recompute, which appears as real
equations in the transposed jaxpr).

Accounting rules:

* ``dot_general``: 2 * batch * M * N * K flops.
* elementwise / reductions / cumsum: 1 flop per output (negligible next to
  the matmuls, included for honesty).
* ``scan``: length x body.
* ``shard_map``: body flops (local shapes) x mesh device count — global
  accounting; redundant replicated compute is counted as executed work.
* bytes ("fusion-adjusted"): for each equation, output bytes + input
  bytes, skipping pure layout/dtype ops (reshape/transpose/broadcast/
  convert/slice) which XLA fuses; scans multiply.  This approximates HBM
  traffic with perfect elementwise fusion but materialization at
  dot/reduce/collective boundaries.
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import numpy as np
from jax import core

_LAYOUT_OPS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "rev", "copy", "bitcast_convert_type",
    "expand_dims", "sharding_constraint",
}
_ZERO_FLOP = _LAYOUT_OPS | {
    "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
    "concatenate", "pad", "iota", "stop_gradient", "select_n",
    "split",
}


def _aval_size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _aval_bytes(aval) -> int:
    try:
        return _aval_size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(lhs.shape[i] for i in range(len(lhs.shape))
                  if i not in lb and i not in lc)
    n = math.prod(rhs.shape[i] for i in range(len(rhs.shape))
                  if i not in rb and i not in rc)
    return 2.0 * batch * m * n * contract


def _sub_jaxprs(eqn):
    """(multiplier, jaxpr) pairs of an equation's inner jaxprs."""
    prim = eqn.primitive.name
    p = eqn.params
    if prim == "scan":
        yield float(p["length"]), p["jaxpr"].jaxpr
        return
    if prim == "while":
        # our whiles all come from scan; if one appears directly, count
        # the body once (documented approximation)
        yield 1.0, p["body_jaxpr"].jaxpr
        return
    if prim == "cond":
        for br in p["branches"]:
            yield 1.0 / max(len(p["branches"]), 1), br.jaxpr
        return
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            yield 1.0, j.jaxpr if hasattr(j, "jaxpr") else j
            return


def analyze_jaxpr(jaxpr, *, shard_devices: int = 1) -> dict:
    """Returns {"flops": f, "bytes": b} for one jaxpr (global accounting)."""
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            flops += _dot_flops(eqn)
            byts += sum(_aval_bytes(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
            byts += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        if prim == "fft":
            # 5 n log2(n) flops per length-n transform (radix-2 butterfly
            # count), batched over the non-transformed dims; matters for
            # the sim executor's cost of the paper's row-FFT tasks
            n_t = math.prod(int(d) for d in eqn.params["fft_lengths"])
            out_size = sum(_aval_size(v.aval) for v in eqn.outvars)
            flops += 5.0 * out_size * max(math.log2(max(n_t, 2)), 1.0)
            byts += sum(_aval_bytes(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
            byts += sum(_aval_bytes(v.aval) for v in eqn.outvars)
            continue
        if prim == "shard_map":
            inner = analyze_jaxpr(eqn.params["jaxpr"],
                                  shard_devices=shard_devices)
            mesh = eqn.params.get("mesh")
            n = int(np.prod(list(mesh.shape.values()))) if mesh is not None \
                else shard_devices
            flops += inner["flops"] * n
            byts += inner["bytes"] * n
            continue
        subs = list(_sub_jaxprs(eqn))
        if subs:
            for mult, sub in subs:
                inner = analyze_jaxpr(sub, shard_devices=shard_devices)
                flops += mult * inner["flops"]
                byts += mult * inner["bytes"]
            # scan also streams its xs/ys once
            if prim == "scan":
                byts += sum(_aval_bytes(v.aval) for v in eqn.invars
                            if hasattr(v, "aval"))
            continue
        out_size = sum(_aval_size(v.aval) for v in eqn.outvars)
        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        if prim in _LAYOUT_OPS:
            continue
        if prim in _ZERO_FLOP:
            byts += out_bytes + in_bytes
            continue
        if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                    "reduce_and", "reduce_or", "argmax", "argmin",
                    "cumsum", "cumlogsumexp", "cummax", "cumprod"):
            flops += sum(_aval_size(v.aval) for v in eqn.invars
                         if hasattr(v, "aval"))
            byts += out_bytes + in_bytes
            continue
        # generic elementwise
        flops += out_size
        byts += out_bytes + in_bytes
    return {"flops": flops, "bytes": byts}


def count_step(fn, *abstract_args) -> dict:
    """Trace ``fn`` on abstract args and return global flops/bytes."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return analyze_jaxpr(closed.jaxpr)
