"""Public Cholesky tile ops.

``update`` dispatches to the Pallas trailing-update kernel
(:func:`repro.kernels.matmul.kernel.tile_update_pallas`); ``potrf`` and
``trsm`` stay on XLA's triangular primitives (see ref.py for why).
"""
from ..matmul import kernel as _mm_kernel
from . import ref

potrf = ref.potrf
trsm = ref.trsm


def update(c, a, b, *, use_pallas: bool = False, interpret: bool = False,
           bk: int = 128):
    if not use_pallas:
        return ref.update(c, a, b)
    return _mm_kernel.tile_update_pallas(c, a, b, bk=min(bk, a.shape[1]),
                                         interpret=interpret)
