"""Pure-jnp oracles for tiled right-looking Cholesky factorization.

The paper's benchmark: 2Kx2K doubles in 128x128 tiles.  Tile ops:

* ``potrf``  — Cholesky of a diagonal tile
* ``trsm``   — panel solve  X L^T = A  (X strictly below the diagonal tile)
* ``update`` — trailing update  C - A @ B^T  (SYRK on the diagonal, GEMM off)

FLOPs are dominated by ``update`` (O(n^3/3) of the total), which is the
Pallas kernel (shared with :mod:`repro.kernels.matmul`); ``potrf``/``trsm``
on 128-wide tiles are left to XLA's native triangular ops — on TPU their
sequential dependency chains do not map onto the MXU, so the tiled
decomposition (exactly the paper's task structure) is what exposes the
hardware-friendly work.
"""
import jax
import jax.numpy as jnp


def potrf(a):
    """Lower-triangular Cholesky factor of a (tile-sized) SPD matrix."""
    return jnp.linalg.cholesky(a)


def trsm(l, a):
    """Solve ``x @ l.T = a`` for x (l lower-triangular)."""
    return jax.scipy.linalg.solve_triangular(l, a.T, lower=True).T


def update(c, a, b):
    """Trailing update ``c - a @ b.T`` (f32/f64 accumulation)."""
    acc = jnp.promote_types(c.dtype, jnp.float32)
    prod = jnp.matmul(a, b.T, preferred_element_type=acc)
    return (c.astype(acc) - prod).astype(c.dtype)


def cholesky_blocked(a, tile: int):
    """Reference tiled right-looking Cholesky (sequential loop nest) —
    the oracle for the task-graph version."""
    n = a.shape[0]
    g = n // tile
    t = {}
    for i in range(g):
        for j in range(i + 1):
            t[i, j] = a[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile]
    for k in range(g):
        t[k, k] = potrf(t[k, k])
        for i in range(k + 1, g):
            t[i, k] = trsm(t[k, k], t[i, k])
        for i in range(k + 1, g):
            for j in range(k + 1, i + 1):
                t[i, j] = update(t[i, j], t[i, k], t[j, k])
    out = jnp.zeros_like(a)
    for i in range(g):
        for j in range(i + 1):
            out = out.at[i * tile:(i + 1) * tile,
                         j * tile:(j + 1) * tile].set(t[i, j])
    return jnp.tril(out)
