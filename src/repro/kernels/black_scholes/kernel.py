"""Pallas TPU kernel for Black-Scholes pricing.

Layout: options are reshaped to (rows, 128) so the last dimension fills TPU
vector lanes; the grid tiles rows in ``block_rows`` chunks (8-row multiples
-> full (8, 128) VREG tiles).  Purely elementwise, so one VMEM block per
input/output and no scratch.  The erf-based normal CDF runs on the VPU.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.special import erf

_SQRT2 = 1.4142135623730951


def _ncdf(x):
    return 0.5 * (1.0 + erf(x / _SQRT2))


def _bs_kernel(spot_ref, strike_ref, t_ref, rate_ref, vol_ref,
               call_ref, put_ref):
    spot = spot_ref[...]
    strike = strike_ref[...]
    t = t_ref[...]
    rate = rate_ref[...]
    vol = vol_ref[...]
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * t) / (vol * sqrt_t)
    d2 = d1 - vol * sqrt_t
    disc = strike * jnp.exp(-rate * t)
    call_ref[...] = spot * _ncdf(d1) - disc * _ncdf(d2)
    put_ref[...] = disc * _ncdf(-d2) - spot * _ncdf(-d1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def black_scholes_pallas(spot, strike, t, rate, vol, *, block_rows: int = 256,
                         interpret: bool = False):
    """Inputs: (rows, 128) float32 arrays.  Returns (call, put)."""
    rows, lanes = spot.shape
    if lanes != 128:
        raise ValueError("lane dimension must be 128 (reshape in ops.py)")
    block_rows = min(block_rows, rows)
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not divisible by block_rows {block_rows}")
    spec = pl.BlockSpec((block_rows, 128), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, 128), jnp.float32)
    return pl.pallas_call(
        _bs_kernel,
        grid=(rows // block_rows,),
        in_specs=[spec] * 5,
        out_specs=[spec] * 2,
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(spot, strike, t, rate, vol)
