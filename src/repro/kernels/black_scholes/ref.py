"""Pure-jnp oracle for Black-Scholes European option pricing.

The paper's Black-Scholes benchmark prices 2M options in tasks of 512
options — an embarrassingly parallel, VPU-bound elementwise workload.
"""
import jax.numpy as jnp
from jax.scipy.special import erf

_SQRT2 = 1.4142135623730951


def _ncdf(x):
    return 0.5 * (1.0 + erf(x / _SQRT2))


def black_scholes(spot, strike, t, rate, vol):
    """Returns (call, put) prices; all inputs broadcastable float arrays."""
    spot, strike, t, rate, vol = (jnp.asarray(a, jnp.float32)
                                  for a in (spot, strike, t, rate, vol))
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(spot / strike) + (rate + 0.5 * vol * vol) * t) / (vol * sqrt_t)
    d2 = d1 - vol * sqrt_t
    disc = strike * jnp.exp(-rate * t)
    call = spot * _ncdf(d1) - disc * _ncdf(d2)
    put = disc * _ncdf(-d2) - spot * _ncdf(-d1)
    return call, put
