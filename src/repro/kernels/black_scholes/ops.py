"""Public Black-Scholes op: flat option batches of any length."""
import jax
import jax.numpy as jnp

from . import kernel, ref


def black_scholes(spot, strike, t, rate, vol, *, use_pallas: bool = False,
                  interpret: bool = False, block_rows: int = 256):
    """Price a flat batch of options.  Inputs: 1-D arrays of equal length.

    ``use_pallas=False`` runs the jnp oracle path (the dry-run/CPU default);
    ``use_pallas=True`` runs the TPU kernel (``interpret=True`` on CPU).
    """
    if not use_pallas:
        return ref.black_scholes(spot, strike, t, rate, vol)
    n = spot.shape[0]
    lanes = 128
    block_rows = max(1, min(block_rows, -(-n // lanes)))
    pad = (-n) % (lanes * block_rows)
    args = [jnp.pad(jnp.asarray(a, jnp.float32), (0, pad),
                    constant_values=1.0).reshape(-1, lanes)
            for a in (spot, strike, t, rate, vol)]
    call, put = kernel.black_scholes_pallas(
        *args, block_rows=block_rows, interpret=interpret)
    return call.reshape(-1)[:n], put.reshape(-1)[:n]
