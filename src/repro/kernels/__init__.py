"""Pallas TPU kernels for the compute hot-spots.

The paper's five benchmarks (Black-Scholes, Matrix-Multiply, FFT, Jacobi,
Cholesky) are the workloads whose tile tasks dominate compute; each gets a
Pallas kernel (``kernel.py``), a jitted public wrapper (``ops.py``) and a
pure-jnp oracle (``ref.py``).  ``flash_attention`` / ``flash_decode`` are the
LM-substrate hot-spots.  All kernels target TPU (MXU-aligned BlockSpecs,
VMEM-resident working sets) and are validated on CPU in interpret mode
against the oracles.

Models and the dry-run use the jnp reference paths by default (this
container lowers for CPU); ``ops.py`` wrappers take ``use_pallas=...`` /
``interpret=...`` so the same call sites run the Pallas path on real TPU.
"""
