"""Pallas TPU kernel: one Jacobi 5-point sweep over a 2-D grid.

Halo exchange via BlockSpecs: the grid tiles rows; three input specs map the
*same* array at block rows (i-1, i, i+1) (clamped at the edges), so each
program sees its block plus the neighbouring row blocks already staged in
VMEM — the TPU analogue of the SCC tasks reading neighbour tiles from shared
DRAM.  Columns stay whole (the paper's 512-wide tiles fit VMEM: 3 blocks x
block_rows x width x 4 B).  Boundary rows/cols are kept fixed with iota
masks on the *global* row index.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params


def _jacobi_kernel(top_ref, mid_ref, bot_ref, out_ref, *, block_rows: int,
                   n_rows: int):
    i = pl.program_id(0)
    x = mid_ref[...]
    bm, w = x.shape
    # neighbour rows: from the adjacent blocks (clamped to self at the edges)
    up = jnp.concatenate([top_ref[...][-1:, :], x[:-1, :]], axis=0)
    down = jnp.concatenate([x[1:, :], bot_ref[...][:1, :]], axis=0)
    left = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
    right = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
    stencil = 0.25 * (up + down + left + right)
    # Dirichlet boundary: global first/last rows and first/last cols fixed
    grow = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, (bm, w), 0)
    gcol = jax.lax.broadcasted_iota(jnp.int32, (bm, w), 1)
    boundary = ((grow == 0) | (grow == n_rows - 1) |
                (gcol == 0) | (gcol == w - 1))
    out_ref[...] = jnp.where(boundary, x, stencil)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def jacobi_step_pallas(x, *, block_rows: int = 256, interpret: bool = False):
    n_rows, width = x.shape
    block_rows = min(block_rows, n_rows)
    if n_rows % block_rows:
        raise ValueError(f"rows {n_rows} not divisible by {block_rows}")
    n_blocks = n_rows // block_rows
    spec = lambda off: pl.BlockSpec(
        (block_rows, width),
        lambda i, _off=off: (jnp.clip(i + _off, 0, n_blocks - 1), 0))
    return pl.pallas_call(
        functools.partial(_jacobi_kernel, block_rows=block_rows,
                          n_rows=n_rows),
        grid=(n_blocks,),
        in_specs=[spec(-1), spec(0), spec(+1)],
        out_specs=pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, width), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, x, x)
