"""Pure-jnp oracle for the Jacobi 5-point stencil sweep.

Interior points become the mean of their four neighbours; boundary points
are fixed (Dirichlet), matching the paper's Jacobi-method benchmark
(4Kx4K floats, 512x512 tiles, 16 iterations).
"""
import jax.numpy as jnp


def jacobi_step(x):
    up = x[:-2, 1:-1]
    down = x[2:, 1:-1]
    left = x[1:-1, :-2]
    right = x[1:-1, 2:]
    interior = 0.25 * (up + down + left + right)
    return x.at[1:-1, 1:-1].set(interior)


def jacobi(x, iters: int = 1):
    for _ in range(iters):
        x = jacobi_step(x)
    return x
