"""Public Jacobi op."""
from . import kernel, ref


def jacobi_step(x, *, use_pallas: bool = False, interpret: bool = False,
                block_rows: int = 256):
    if not use_pallas:
        return ref.jacobi_step(x)
    return kernel.jacobi_step_pallas(x, block_rows=min(block_rows, x.shape[0]),
                                     interpret=interpret)


def jacobi(x, iters: int = 1, **kw):
    for _ in range(iters):
        x = jacobi_step(x, **kw)
    return x
