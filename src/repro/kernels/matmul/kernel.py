"""Pallas TPU kernel: blocked matmul ``c + a @ b``.

Grid = (M/bm, N/bn, K/bk), K innermost with "arbitrary" semantics so the
(bm, bn) output block stays resident in VMEM across the K sweep; a float32
VMEM scratch accumulator feeds the MXU via ``jnp.dot(...,
preferred_element_type=f32)``.  Block sizes default to (128, 128, 128) —
MXU-aligned (the systolic array is 128x128) and a working set of
3 * 128*128*4B = 192 KiB, comfortably inside the ~16 MiB/core VMEM with room
for double-buffered pipelining of the next A/B blocks.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params


def _mm_kernel(a_ref, b_ref, c_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(a, b, c, *, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """``c + a @ b`` with (M,K)x(K,N); M,N,K divisible by the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims {(m, n, k)} not divisible by blocks "
                         f"{(bm, bn, bk)}")
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)


def _update_kernel(c_ref, a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    """Trailing-update form ``c - a @ b^T`` (B arrives untransposed)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    acc_ref[...] -= jnp.dot(a_ref[...], b_ref[...].T,
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def tile_update_pallas(c, a, b, *, bk: int = 128, interpret: bool = False):
    """``c - a @ b^T`` for (m,k)x(n,k) tiles — the Cholesky/SYRK update."""
    m, k = a.shape
    n, k2 = b.shape
    assert k == k2 and c.shape == (m, n)
    bk = min(bk, k)
    if k % bk:
        raise ValueError(f"k={k} not divisible by bk={bk}")
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_update_kernel, n_k=n_k),
        grid=(1, n_k),
        in_specs=[
            pl.BlockSpec((m, n), lambda i, kk: (0, 0)),
            pl.BlockSpec((m, bk), lambda i, kk: (0, kk)),
            pl.BlockSpec((n, bk), lambda i, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda i, kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        scratch_shapes=[pltpu.VMEM((m, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(c, a, b)
