"""Pure-jnp oracle for the tiled matmul benchmark."""
import jax.numpy as jnp


def matmul(a, b, c=None):
    """``c + a @ b`` (``c`` defaults to zero), f32 accumulation."""
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    if c is not None:
        out = c.astype(jnp.float32) + out
    return out.astype(a.dtype)


def tile_update(c, a, b):
    """Cholesky-style trailing update: ``c - a @ b^T`` (f32 accumulation)."""
    prod = jnp.matmul(a, b.T, preferred_element_type=jnp.float32)
    return (c.astype(jnp.float32) - prod).astype(c.dtype)
