"""Public matmul ops used by the paper-benchmark tasks and the models."""
from . import kernel, ref


def matmul(a, b, c=None, *, use_pallas: bool = False,
           interpret: bool = False, bm: int = 128, bn: int = 128,
           bk: int = 128):
    """``c + a @ b`` (``c`` optional)."""
    if not use_pallas:
        return ref.matmul(a, b, c)
    import jax.numpy as jnp
    if c is None:
        c = jnp.zeros((a.shape[0], b.shape[1]), a.dtype)
    return kernel.matmul_pallas(a, b, c, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)


def tile_update(c, a, b, *, use_pallas: bool = False,
                interpret: bool = False, bk: int = 128):
    """``c - a @ b^T`` — GEMM/SYRK trailing update for tiled Cholesky."""
    if not use_pallas:
        return ref.tile_update(c, a, b)
    return kernel.tile_update_pallas(c, a, b, bk=bk, interpret=interpret)
