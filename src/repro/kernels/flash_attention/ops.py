"""Public attention op: Pallas flash kernel or jnp paths.

``chunked`` is the lax.scan online-softmax implementation used by the models
for prefill/training — it has flash's O(S) memory without Pallas, so it
lowers on any backend (this is what the multi-pod dry-run compiles); the
Pallas kernel is the TPU hot-spot implementation of the same math.
"""
import functools

import jax
import jax.numpy as jnp

from . import kernel, ref

_NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              impl: str = "chunked", q_chunk: int = 512, k_chunk: int = 1024,
              interpret: bool = False):
    if impl == "pallas":
        return kernel.flash_attention_pallas(q, k, v, causal=causal,
                                             scale=scale, interpret=interpret)
    if impl == "naive":
        return ref.mha(q, k, v, causal=causal, scale=scale)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, scale=scale,
                                 q_chunk=q_chunk, k_chunk=k_chunk)
    raise ValueError(f"unknown attention impl {impl!r}")


def chunked_attention(q, k, v, *, causal: bool = True,
                      scale: float | None = None, q_chunk: int = 512,
                      k_chunk: int = 1024):
    """Online-softmax attention via lax.scan over kv chunks, vmapped over q
    chunks.  Memory: O(bq * bk) scores per (b, h) instead of O(Sq * Skv).
    Supports d_v != d_qk (MLA-style asymmetric heads)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else float(d) ** -0.5
    bq = min(q_chunk, sq)
    bk = min(k_chunk, skv)
    if sq % bq or skv % bk:
        # fall back to one chunk rather than failing on odd lengths
        bq, bk = sq, skv
    nq, nk = sq // bq, skv // bk
    kv_off = skv - sq

    qc = q.reshape(b, hq, nq, bq, d).astype(jnp.float32)
    kc = k.reshape(b, hq, nk, bk, d).astype(jnp.float32)
    vc = v.reshape(b, hq, nk, bk, dv).astype(jnp.float32)

    @functools.partial(jax.checkpoint, policy=None)
    def q_block(iq, qb):
        # qb: (b, hq, bq, d).  checkpointed: backward recomputes the
        # (bq, bk) score blocks instead of saving them — flash-attention
        # memory behaviour without Pallas (the Pallas kernel is the TPU
        # hot-spot path; this is what every backend can lower).
        @jax.checkpoint
        def kv_step(carry, ik_kb_vb):
            m, l, acc = carry
            ik, kb, vb = ik_kb_vb
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb) * scale
            if causal:
                qpos = iq * bq + jnp.arange(bq)[:, None] + kv_off
                kpos = ik * bk + jnp.arange(bk)[None, :]
                s = jnp.where(kpos <= qpos, s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
            return (m_new, l, acc), None

        init = (jnp.full((b, hq, bq), _NEG_INF, jnp.float32),
                jnp.zeros((b, hq, bq), jnp.float32),
                jnp.zeros((b, hq, bq, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (jnp.arange(nk), jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0)))
        l = jnp.where(l == 0.0, 1.0, l)
        # cast per chunk: the stacked output stays in the compute dtype
        # (f32 stacking doubled the live set on 32k prefill)
        return (acc / l[..., None]).astype(q.dtype)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(nq), jnp.moveaxis(qc, 2, 0)))
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sq, dv)
    return out
