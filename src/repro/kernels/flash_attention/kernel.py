"""Pallas TPU kernel: FlashAttention-style blocked causal attention.

Grid = (B*Hq, Sq/bq, Skv/bk), kv innermost ("arbitrary" semantics) so the
running max / sum / accumulator persist in VMEM scratch across the kv sweep
(the online-softmax recurrence).  GQA is free: the K/V BlockSpec index maps
divide the head coordinate by the group size, so shared KV blocks are
fetched once per group without materializing repeated heads in HBM.

Block sizes default to (bq, bk) = (256, 256): the MXU sees (256, D)x(D, 256)
and (256, 256)x(256, D) matmuls; the VMEM working set is
q + k + v + acc + p ~ 5 * 256*128*4B ~ 0.7 MiB, leaving headroom for the
pipeline's double buffering.  Fully-masked causal blocks are skipped with
``pl.when`` — on TPU the block's DMas still run but the MXU work is elided.

m/l statistics live in (bq, 128) lane-replicated scratch, the standard
Mosaic-friendly layout for row statistics.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

_NEG_INF = -1e30  # avoids -inf - -inf = nan in fully-masked rows


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_k: int, causal: bool, scale: float,
                  kv_offset: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: block (iq, ik) participates iff its first kv pos
    # can be visible to its last q pos
    first_k = ik * bk
    last_q = iq * bq + bq - 1 + kv_offset
    run = (first_k <= last_q) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)             # (bq, d)
        k = k_ref[0].astype(jnp.float32)             # (bk, d)
        v = v_ref[0].astype(jnp.float32)             # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
                + kv_offset
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, _NEG_INF)
        m_prev = m_ref[:, :1]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)    # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "scale"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None, bq: int = 256,
                           bk: int = 256, interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    Causal masking aligns the query suffix to the kv end (Sq == Skv in
    training; Sq < Skv for chunked prefill continuation).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lens {(sq, skv)} not divisible by {(bq, bk)}")
    scale = scale if scale is not None else float(d) ** -0.5
    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)
    n_k = skv // bk
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, n_k=n_k,
                          causal=causal, scale=scale, kv_offset=skv - sq),
        grid=(b * hq, sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, iq, ik, _g=group: (h // _g, ik, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, iq, ik, _g=group: (h // _g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max m
            pltpu.VMEM((bq, 128), jnp.float32),   # running sum l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
