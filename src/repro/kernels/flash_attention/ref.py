"""Naive-softmax oracle for multi-head attention (small shapes only)."""
import jax.numpy as jnp


def mha(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); GQA via head repetition.

    Returns (B, Hq, Sq, D) in q's dtype; f32 softmax internally.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        # query i attends to keys <= i + (skv - sq)  (suffix alignment)
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
