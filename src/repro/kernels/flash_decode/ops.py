"""Public decode-attention ops, including the sequence-sharded form."""
import jax.numpy as jnp

from . import kernel, ref


def decode_attention(q, k, v, *, scale: float | None = None,
                     use_pallas: bool = False, interpret: bool = False,
                     bk: int = 512):
    """Full (unsharded) decode attention for one new token."""
    if not use_pallas:
        return ref.decode_mha(q, k, v, scale=scale)
    o, lse = kernel.flash_decode_pallas(q, k, v, scale=scale, bk=bk,
                                        interpret=interpret)
    return o.astype(q.dtype)


def decode_partial(q, k, v, *, scale: float | None = None, mask=None,
                   use_pallas: bool = False, interpret: bool = False,
                   bk: int = 512):
    """Per-shard partial: (o_f32, lse).  Combine with
    :func:`ref.combine_partials` or a psum-based merge under shard_map."""
    if not use_pallas:
        return ref.decode_partial(q, k, v, scale=scale, mask=mask)
    if mask is not None:
        raise NotImplementedError("mask only on the jnp path; pad KV shards "
                                  "to the block size instead")
    return kernel.flash_decode_pallas(q, k, v, scale=scale, bk=bk,
                                      interpret=interpret)


combine_partials = ref.combine_partials
