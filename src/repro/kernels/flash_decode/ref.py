"""Oracles for single-token decode attention and its sharded combine.

Decode attention is memory-bound (the whole KV cache streams past one
query), so BDDT-SCC's placement lesson applies directly: the KV cache is
*striped along the sequence axis* across devices (the "memory controllers"),
each shard computes a partial attention, and the partials combine exactly
via log-sum-exp — the explicit-communication analogue of the paper's
balanced memory traffic.
"""
import jax.numpy as jnp


def decode_mha(q, k, v, *, scale: float | None = None):
    """q: (B, Hq, D) one new token; k, v: (B, Hkv, S, D) -> (B, Hq, D)."""
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else float(d) ** -0.5
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    w = jnp.exp(logits - logits.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_partial(q, k, v, *, scale: float | None = None,
                   mask=None):
    """Partial attention over a KV shard.

    Returns (o, lse): o is the shard-normalized output (B, Hq, D) in f32 and
    lse the shard log-sum-exp (B, Hq).  ``mask``: optional (B, S) bool of
    valid positions (False entries are padding).
    """
    b, hq, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else float(d) ** -0.5
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, :], logits, -1e30)
    m = logits.max(-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = p.sum(-1, keepdims=True)
    safe_l = jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhs,bhsd->bhd", p / safe_l, v.astype(jnp.float32))
    lse = (m + jnp.log(safe_l))[..., 0]
    lse = jnp.where(l[..., 0] == 0.0, -1e30, lse)
    return o, lse


def combine_partials(outs, lses):
    """Combine shard partials: outs (N, B, Hq, D) f32, lses (N, B, Hq)."""
    m = lses.max(0)
    w = jnp.exp(lses - m)                       # (N, B, Hq)
    denom = w.sum(0)
    out = (outs * w[..., None]).sum(0) / denom[..., None]
    return out
