"""Pallas TPU kernel: flash-decode over one KV shard.

Grid = (B, Hkv, S/bk), kv innermost.  Each program handles the G = Hq/Hkv
query heads that share a KV head: q block (1, 1, G, D) against kv blocks
(1, 1, bk, D).  G x bk and G x D matmuls are thin — decode is HBM-bandwidth
bound, and the kernel's job is to stream K/V through VMEM exactly once
(the explicit DMA pipeline standing in for the paper's invalidate-read
fences).  Emits the shard-normalized output and the log-sum-exp so shards
striped across devices combine exactly (see ref.combine_partials).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

_NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                   acc_ref, *, n_k: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)
        lse = jnp.where(l == 0.0, _NEG_INF, m_ref[:, :1] + jnp.log(safe_l))
        lse_ref[0, 0] = jnp.broadcast_to(lse, lse_ref[0, 0].shape)


@functools.partial(jax.jit, static_argnames=("bk", "interpret", "scale"))
def flash_decode_pallas(q, k, v, *, scale: float | None = None,
                        bk: int = 512, interpret: bool = False):
    """q: (B, Hq, D); k, v: (B, Hkv, S, D) -> (o (B,Hq,D) f32, lse (B,Hq))."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    bk = min(bk, s)
    if s % bk:
        raise ValueError(f"kv length {s} not divisible by block {bk}")
    scale = scale if scale is not None else float(d) ** -0.5
    qr = q.reshape(b, hkv, g, d)
    n_k = s // bk
    o, lse = pl.pallas_call(
        functools.partial(_decode_kernel, n_k=n_k, scale=scale),
        grid=(b, hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, ik: (ib, ih, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, g, 128), lambda ib, ih, ik: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, k, v)
    return o.reshape(b, hq, d), lse[..., 0].reshape(b, hq)
