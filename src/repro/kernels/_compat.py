"""Pallas-TPU API drift shims.

``pltpu.CompilerParams`` was ``pltpu.TPUCompilerParams`` on older jax;
kernels route through :func:`tpu_compiler_params` so the same source
lowers on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CP = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    if _CP is None:  # pragma: no cover - ancient pallas
        return None
    return _CP(**kwargs)
