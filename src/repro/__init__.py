"""BDDT-SCC reproduction: task-parallel dataflow runtime + multi-pod JAX
LM framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "1.0.0"
