"""BDDT-SCC reproduction: task-parallel dataflow runtime + multi-pod JAX
LM framework.  See README.md / DESIGN.md / EXPERIMENTS.md.

The canonical import surface (docs/API.md) — batch programs::

    from repro import RuntimeConfig, TaskRuntime, task, wait_on

and serving loops::

    from repro.serve import ServeConfig, Session

Deeper modules (``repro.core.*``, ``repro.obs``, ``repro.ckpt``) stay
importable for extension work, but examples, benchmarks and docs only
use the names re-exported here.
"""

from . import jax_compat as _jax_compat

_jax_compat.install()

from .core import (AccessMode, BlockArray, DEP_MANAGERS, DEP_PUMPS,  # noqa: E402
                   EXECUTORS, ExecutorKind, DepManagerKind, DepPumpKind,
                   Executor, In, InOut, KERNEL_BACKENDS, KernelBackend,
                   Out, PLACEMENTS, PlacementKind, Region, RuntimeConfig,
                   RuntimeStats, SCHEDULING_POLICIES, STATS_SCHEMA,
                   SchedulingPolicy, TaskFuture, TaskRuntime,
                   current_runtime, task, wait_on)

__version__ = "1.0.0"

__all__ = [
    # entry points
    "TaskRuntime", "task", "wait_on", "current_runtime",
    # data + footprints
    "BlockArray", "Region", "AccessMode", "In", "Out", "InOut",
    # configuration + results
    "RuntimeConfig", "RuntimeStats", "STATS_SCHEMA", "TaskFuture",
    # typed configuration choices
    "ExecutorKind", "DepManagerKind", "DepPumpKind", "SchedulingPolicy",
    "PlacementKind", "KernelBackend", "EXECUTORS", "DEP_MANAGERS",
    "DEP_PUMPS", "SCHEDULING_POLICIES", "PLACEMENTS", "KERNEL_BACKENDS",
    # extension surface
    "Executor",
    "__version__",
]
