"""BDDT-SCC reproduction: task-parallel dataflow runtime + multi-pod JAX
LM framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""

from . import jax_compat as _jax_compat

_jax_compat.install()

__version__ = "1.0.0"
