"""GQA attention: training/prefill (chunked flash) and decode (KV cache).

The decode path computes attention with plain einsums over the (possibly
sequence-sharded) KV cache: under pjit, softmax reductions over the sharded
sequence axis lower to the same small all-reduce pattern as the explicit
flash-decode LSE combine (see ``repro.kernels.flash_decode``), so the model
code stays backend-agnostic while the Pallas kernel remains the TPU
hot-spot implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import dist
from ..kernels.flash_attention import ops as fa_ops
from . import rope as rope_mod
from .layers import init_linear, init_norm, linear, norm


def init_attention(key, cfg, dtype=jnp.float32):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, hq * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, hkv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, hkv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], hq * dh, d, dtype=dtype),
    }


def _split_heads(x, n_heads, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _position_encode(q, k, cfg, positions):
    if cfg.rope_type == "rope":
        q = rope_mod.apply_rope(q, positions, theta=cfg.rope_theta)
        k = rope_mod.apply_rope(k, positions, theta=cfg.rope_theta)
    elif cfg.rope_type == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = rope_mod.apply_mrope(q, pos3, cfg.mrope_sections,
                                 theta=cfg.rope_theta)
        k = rope_mod.apply_mrope(k, pos3, cfg.mrope_sections,
                                 theta=cfg.rope_theta)
    return q, k


def attention_train(p, x, cfg, positions, *, causal: bool = True,
                    kv_override=None):
    """Full-sequence attention.  ``kv_override``: (k, v) already in head
    layout — used for cross-attention (whisper decoder)."""
    q = dist.constrain_heads(
        _split_heads(linear(p["wq"], x), cfg.n_heads, cfg.head_dim))
    if kv_override is None:
        k = _split_heads(linear(p["wk"], x), cfg.n_kv_heads, cfg.head_dim)
        v = _split_heads(linear(p["wv"], x), cfg.n_kv_heads, cfg.head_dim)
        q, k = _position_encode(q, k, cfg, positions)
        k = dist.constrain_heads(k)
        v = dist.constrain_heads(v)
    else:
        k, v = kv_override
        if cfg.rope_type != "none":
            q, _ = _position_encode(q, q, cfg, positions)
    out = fa_ops.attention(q, k, v, causal=causal, impl=cfg.attn_impl,
                           q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    out = dist.constrain_heads(out)
    return linear(p["wo"], _merge_heads(out))


def attention_prefill(p, x, cfg, positions, *, causal: bool = True):
    """Like train, but also returns the KV cache contents."""
    q = dist.constrain_heads(
        _split_heads(linear(p["wq"], x), cfg.n_heads, cfg.head_dim))
    k = _split_heads(linear(p["wk"], x), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(linear(p["wv"], x), cfg.n_kv_heads, cfg.head_dim)
    q, k = _position_encode(q, k, cfg, positions)
    k = dist.constrain_heads(k)
    v = dist.constrain_heads(v)
    out = fa_ops.attention(q, k, v, causal=causal, impl=cfg.attn_impl,
                           q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    out = dist.constrain_heads(out)
    return linear(p["wo"], _merge_heads(out)), {"k": k, "v": v}


def _decode_sp(q, k_new, v_new, cache, pos, cfg, ctx):
    """Sequence-parallel decode over the ``model``-sharded KV cache.

    Each shard updates its own slice *locally* (no resharding — the SPMD
    partitioner otherwise all-gathers the cache to apply a traced-index
    dynamic_update_slice, ~6.6 GiB/token on command-r decode_32k) and
    computes a partial attention; partials combine exactly via the
    flash-decode log-sum-exp merge (psum/pmax over the shard axis).
    This is the paper's memory-controller striping applied to the KV data
    plane, with the explicit small-message combine as the only traffic."""
    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    m_axis = ctx.model_axis
    n_m = ctx.axis_size(m_axis)
    dp = ctx.all_data_axes
    b = q.shape[0]
    dp_ok = b % int(np.prod([mesh.shape[a] for a in dp])) == 0
    bspec = dp if dp_ok else None
    scale = cfg.head_dim ** -0.5
    g = cfg.n_heads // cfg.n_kv_heads

    def body(q_l, kn, vn, kc, vc, pos_):
        # kc/vc: (B_l, Hkv, S_l, D) local shard
        s_l = kc.shape[2]
        idx = jax.lax.axis_index(m_axis)
        start = idx * s_l
        local_pos = pos_ - start
        in_range = (local_pos >= 0) & (local_pos < s_l)
        safe = jnp.clip(local_pos, 0, s_l - 1)
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            kc, kn.astype(kc.dtype), safe, axis=2)
        upd_v = jax.lax.dynamic_update_slice_in_dim(
            vc, vn.astype(vc.dtype), safe, axis=2)
        kc = jnp.where(in_range, upd_k, kc)
        vc = jnp.where(in_range, upd_v, vc)
        # partial attention over the local slice
        b_l = q_l.shape[0]
        qg = q_l[:, :, 0, :].reshape(b_l, cfg.n_kv_heads, g, cfg.head_dim)
        s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        valid = (start + jnp.arange(s_l)) <= pos_
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        mx = s.max(-1, keepdims=True)
        p_ = jnp.exp(s - mx)
        l_ = p_.sum(-1, keepdims=True)
        o_part = jnp.einsum("bhgs,bhsd->bhgd", p_, vc.astype(jnp.float32))
        # exact LSE combine across shards
        m_glob = jax.lax.pmax(mx, m_axis)
        w = jnp.exp(mx - m_glob)
        denom = jax.lax.psum(l_ * w, m_axis)
        o = jax.lax.psum(o_part * w, m_axis) / denom
        o = o.reshape(b_l, cfg.n_heads, 1, cfg.head_dim)
        return o.astype(q_l.dtype), kc, vc

    o, kc, vc = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, m_axis, None), P(bspec, None, m_axis, None),
                  P()),
        out_specs=(P(bspec, None, None, None), P(bspec, None, m_axis, None),
                   P(bspec, None, m_axis, None)),
        check_vma=False)(q, k_new, v_new, cache["k"], cache["v"], pos)
    return o, {"k": kc, "v": vc}


def attention_decode(p, x, cfg, cache, pos, *, update_cache: bool = True,
                     kv_override=None):
    """One-token decode.  x: (B, 1, d); cache: {"k","v"} (B, Hkv, S, D);
    pos: scalar int32 — the index of this token (cache holds `pos` valid
    entries before the update)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _split_heads(linear(p["wq"], x), cfg.n_heads, cfg.head_dim)
    if kv_override is None:
        k_new = _split_heads(linear(p["wk"], x), cfg.n_kv_heads, cfg.head_dim)
        v_new = _split_heads(linear(p["wv"], x), cfg.n_kv_heads, cfg.head_dim)
        q, k_new = _position_encode(q, k_new, cfg, positions)
        ctx = dist.current()
        if (update_cache and ctx is not None and not ctx.model_in_batch
                and cache["k"].shape[2] % ctx.axis_size(ctx.model_axis)
                == 0):
            o, cache = _decode_sp(q, k_new, v_new, cache, pos, cfg, ctx)
            return linear(p["wo"], _merge_heads(o)), cache
        if update_cache:
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_new.astype(cache["k"].dtype), pos, axis=2),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_new.astype(cache["v"].dtype), pos, axis=2),
            }
        k, v = cache["k"], cache["v"]
        valid = jnp.arange(k.shape[2]) <= pos           # (S,)
    else:
        if cfg.rope_type != "none":
            q, _ = _position_encode(q, q, cfg, positions)
        k, v = kv_override
        valid = jnp.ones((k.shape[2],), bool)

    # GQA decode: (B, Hq, 1, D) x (B, Hkv, S, D)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, g, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(jnp.float32))
    o = o.reshape(b, cfg.n_heads, 1, cfg.head_dim).astype(x.dtype)
    return linear(p["wo"], _merge_heads(o)), cache
