"""Mixture-of-Experts FFN with expert parallelism.

Two implementations of top-k token-choice routing:

* :func:`moe_ffn_ref` — exact dense-gather reference (no capacity drops);
  O(N * k * d * d_ff) memory for gathered weights, fine for tests/smoke.
* :func:`moe_ffn_ep` — production path: local counting-sort of token-choices
  into per-expert capacity buckets, ``all_to_all`` over the EP (``model``)
  axis to expert owners, expert FFN on contiguous buffers, reverse
  ``all_to_all``, local weighted un-scatter.  Sort-based dispatch is
  O(N * k * d) — no one-hot (N, E, C) tensors.  Under a trivial mesh this
  degenerates to the local computation, so the same code runs everywhere.

The EP layout *is* the paper's placement story: experts are blocks homed on
"memory controllers" (EP ranks); the router is the allocator striping tokens
across them; the aux loss keeps the stripes balanced (the paper's uniform-
distribution requirement); the all-to-all is the explicit communication the
SCC runtime performs instead of coherence traffic.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import dist
from .layers import init_linear, linear


def init_moe(key, cfg, dtype=jnp.float32):
    d, e, dff = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": init_linear(ks[0], d, e, dtype=dtype),
        "gate": jax.random.truncated_normal(ks[1], -2, 2, (e, d, dff),
                                            dtype) * scale,
        "up": jax.random.truncated_normal(ks[2], -2, 2, (e, d, dff),
                                          dtype) * scale,
        "down": jax.random.truncated_normal(ks[3], -2, 2, (e, dff, d),
                                            dtype) * (dff ** -0.5),
    }
    if cfg.n_shared_experts:
        dsh = cfg.d_expert * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": init_linear(kss[0], d, dsh, dtype=dtype),
            "up": init_linear(kss[1], d, dsh, dtype=dtype),
            "down": init_linear(kss[2], dsh, d, dtype=dtype),
        }
    return p


def _router(p, xt, cfg):
    """xt: (N, d) -> (topv, topi): (N, k) gates and expert ids."""
    gates = jax.nn.softmax(linear(p["router"], xt).astype(jnp.float32), -1)
    topv, topi = jax.lax.top_k(gates, cfg.top_k)
    if cfg.moe_renorm:
        topv = topv / topv.sum(-1, keepdims=True)
    return topv, topi, gates


def _shared(p, xt):
    sh = p["shared"]
    return linear(sh["down"],
                  jax.nn.silu(linear(sh["gate"], xt)) * linear(sh["up"], xt))


def _expert_ffn(xe, gate_w, up_w, down_w, dtype):
    """xe: (E_l, T, d); weights (E_l, d, dff)/(E_l, dff, d)."""
    h = jax.nn.silu(jnp.einsum("etd,edf->etf", xe, gate_w.astype(dtype))) \
        * jnp.einsum("etd,edf->etf", xe, up_w.astype(dtype))
    return jnp.einsum("etf,efd->etd", h, down_w.astype(dtype))


# ---------------------------------------------------------------------------
def moe_ffn_ref(p, x, cfg):
    """Exact reference: gather each token's k expert weight blocks."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    topv, topi, _ = _router(p, xt, cfg)

    def per_choice(j):
        gw = p["gate"][topi[:, j]]                     # (N, d, dff)
        uw = p["up"][topi[:, j]]
        dw = p["down"][topi[:, j]]
        h = jax.nn.silu(jnp.einsum("nd,ndf->nf", xt, gw.astype(x.dtype))) \
            * jnp.einsum("nd,ndf->nf", xt, uw.astype(x.dtype))
        return jnp.einsum("nf,nfd->nd", h, dw.astype(x.dtype))

    out = sum(topv[:, j, None].astype(x.dtype) * per_choice(j)
              for j in range(cfg.top_k))
    if cfg.n_shared_experts:
        out = out + _shared(p, xt)
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
def _dispatch_local(xt, topv, topi, e: int, capacity: int, dtype):
    """Counting-sort token-choices into (E, C, d) buckets.  Returns the
    buffer plus (slot, keep, gate) per choice for the un-scatter."""
    n, k = topi.shape
    flat_e = topi.reshape(-1)                           # (N*k,)
    # stable sort by expert; position within expert via sorted enumeration
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within run of equal experts
    pos_sorted = jnp.arange(n * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                      side="left")
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos < capacity
    slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)  # (N*k,)
    src = jnp.repeat(jnp.arange(n), k)
    buf = jnp.zeros((e * capacity, xt.shape[1]), dtype)
    buf = buf.at[jnp.where(keep, slot, e * capacity)].add(
        xt[src], mode="drop")
    return buf.reshape(e, capacity, -1), slot, keep


def _unscatter_local(ye_flat, slot, keep, topv, n: int, k: int, dtype):
    """ye_flat: (E*C, d) expert outputs -> (N, d) combined by gates."""
    gathered = jnp.where(keep[:, None], ye_flat[slot], 0.0)    # (N*k, d)
    w = topv.reshape(-1)[:, None].astype(dtype)
    return (gathered * w).reshape(n, k, -1).sum(1)


def moe_ffn_ep(p, x, cfg, *, capacity_factor: float | None = None):
    """Expert-parallel MoE.  Uses the ambient mesh context; if none (or the
    EP axis has size 1) the all_to_alls degenerate to local copies."""
    ctx = dist.current()
    cf = capacity_factor if capacity_factor is not None \
        else cfg.moe_capacity_factor
    if ctx is None:
        return _moe_local(p, x, cfg, cf)

    mesh = ctx.mesh
    ep = ctx.model_axis
    n_ep = ctx.axis_size(ep)
    e = cfg.n_experts
    assert e % n_ep == 0, (e, n_ep)

    batch_axes = ctx.all_data_axes

    def body(p_local, xl):
        # xl: (b_l, s_l, d); expert weights sharded on E (axis 0)
        b_l, s_l, d = xl.shape
        xt = xl.reshape(-1, d)
        n_l = xt.shape[0]
        topv, topi, _ = _router(p_local, xt, cfg)
        capacity = max(1, math.ceil(cf * n_l * cfg.top_k / e))
        buf, slot, keep = _dispatch_local(xt, topv, topi, e, capacity,
                                          xl.dtype)
        # send expert buckets to their owners: (E, C, d) -> (n_ep*E_l, C, d)
        recv = jax.lax.all_to_all(buf, ep, split_axis=0, concat_axis=0,
                                  tiled=True)
        e_l = e // n_ep
        # (n_ep, E_l, C, d) -> (E_l, n_ep*C, d)
        recv = recv.reshape(n_ep, e_l, capacity, d).transpose(1, 0, 2, 3) \
                   .reshape(e_l, n_ep * capacity, d)
        ye = _expert_ffn(recv, p_local["gate"], p_local["up"],
                         p_local["down"], xl.dtype)
        # reverse route
        back = ye.reshape(e_l, n_ep, capacity, d).transpose(1, 0, 2, 3) \
                 .reshape(n_ep * e_l, capacity, d)
        mine = jax.lax.all_to_all(back, ep, split_axis=0, concat_axis=0,
                                  tiled=True)
        out = _unscatter_local(mine.reshape(e * capacity, d), slot, keep,
                               topv, n_l, cfg.top_k, xl.dtype)
        if cfg.n_shared_experts:
            out = out + _shared(p_local, xt)
        return out.reshape(b_l, s_l, d)

    # seq shards over the EP axis when divisible (prefill/train); decode
    # (s == 1) replicates over EP — each rank then redundantly dispatches
    # the same tokens, which is correct and negligible for one token.
    seq_axis = ep if x.shape[1] % n_ep == 0 else None
    pspec_w = P(ep, None, None)
    in_specs = (
        {"router": {"w": P(None, None)},
         "gate": pspec_w, "up": pspec_w, "down": pspec_w,
         **({"shared": {k: {"w": P(None, None)} for k in
             ("gate", "up", "down")}} if cfg.n_shared_experts else {})},
        P(batch_axes, seq_axis, None),  # batch over DP axes, seq over EP
    )
    return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=P(batch_axes, seq_axis, None),
                         check_vma=False)(p, x)


def _moe_local(p, x, cfg, cf):
    """Single-device sort-based path (identical math, no collectives)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    n_l = xt.shape[0]
    e = cfg.n_experts
    topv, topi, _ = _router(p, xt, cfg)
    capacity = max(1, math.ceil(cf * n_l * cfg.top_k / e))
    buf, slot, keep = _dispatch_local(xt, topv, topi, e, capacity, x.dtype)
    ye = _expert_ffn(buf, p["gate"], p["up"], p["down"], x.dtype)
    out = _unscatter_local(ye.reshape(e * capacity, d), slot, keep, topv,
                           n_l, cfg.top_k, x.dtype)
    if cfg.n_shared_experts:
        out = out + _shared(p, xt)
    return out.reshape(b, s, d)


def moe_ffn(p, x, cfg):
    if cfg.moe_impl == "ref":
        return moe_ffn_ref(p, x, cfg)
    return moe_ffn_ep(p, x, cfg)


def load_balance_loss(p, x, cfg):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    topv, topi, gates = _router(p, xt, cfg)
    frac = jnp.mean(jax.nn.one_hot(topi, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    prob = gates.mean(0)
    return cfg.n_experts * jnp.sum(frac * prob)
