"""Model zoo: the assigned architectures as composable functional JAX modules.

Everything is a pure function over parameter pytrees; layers stack via
``lax.scan`` over stacked per-layer params (compile-time O(1) in depth) with
configurable remat.  Attention runs through the chunked online-softmax path
(Pallas flash kernel on real TPU); decode uses the sequence-sharded
flash-decode partials.
"""
from .api import (count_params, decode_step, forward_logits, init_cache,
                  init_params, loss_fn, prefill_step)

__all__ = ["init_params", "count_params", "loss_fn", "forward_logits",
           "prefill_step", "decode_step", "init_cache"]
