"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head dimension into (temporal, height, width) sections,
each rotated by its own position stream.  For the text/stub modality the
three streams coincide (documented stub: ``input_specs`` provides
precomputed patch embeddings, so spatial positions degenerate to sequence
positions), but the section machinery is implemented faithfully so real
(t, h, w) streams drop in.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(d_head: int, theta: float = 1e4):
    return theta ** (-jnp.arange(0, d_head // 2, dtype=jnp.float32)
                     / (d_head // 2))


def apply_rope(x, positions, *, theta: float = 1e4):
    """x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, sections, *, theta: float = 1e4):
    """x: (B, H, S, D); positions_thw: (3, B, S); sections: per-stream
    half-dim sizes summing to D/2 (Qwen2-VL: (16, 24, 24) for D=128)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                        # (D/2,)
    # build the per-frequency position stream by section
    parts = []
    off = 0
    for s_idx, sec in enumerate(sections):
        pos = positions_thw[s_idx]                      # (B, S)
        ang = pos[:, None, :, None].astype(jnp.float32) * freqs[off:off + sec]
        parts.append(ang)
        off += sec
    ang = jnp.concatenate(parts, -1)                    # (B, 1, S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int):
    """Whisper-style fixed sinusoidal embeddings (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    ang = pos / (1e4 ** (dim / (d_model // 2)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def sinusoidal_position_at(pos, d_model: int):
    """One sinusoidal embedding row for a (traced) scalar position."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) if hasattr(pos, "astype") \
        else jnp.float32(pos)
    ang = ang / (1e4 ** (dim / (d_model // 2)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
