"""Mamba2 (SSD) block — chunked state-space duality algorithm.

Training/prefill uses the SSD chunked form evaluated under a ``lax.scan``
over chunks: within a chunk the recurrence is a masked attention-like
quadratic (MXU-friendly); across chunks a compact (B, H, N, dh) state is
carried.  Only one chunk's (B, L, L, H) decay tensor is ever live — the
scan is the memory fence, exactly the paper's discipline of bounded
working sets per task.  Decode is the O(1) recurrent update.

Simplifications vs the reference CUDA implementation (recorded in
DESIGN.md §Arch-applicability): single B/C group (n_groups=1, as in
zamba2-1.2b), zero initial state, softplus dt with learned per-head bias.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear, init_norm, linear, norm


def init_mamba(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    ks = jax.random.split(key, 3)
    d_proj = 2 * d_in + 2 * n + h          # [z, x, B, C, dt]
    return {
        "in_proj": init_linear(ks[0], d, d_proj, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_d_conv,
                                            d_in + 2 * n), dtype) * 0.1,
        "conv_b": jnp.zeros((d_in + 2 * n,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), dtype),
        "D": jnp.ones((h,), dtype),
        "out_norm": init_norm(d_in, "rmsnorm", dtype),
        "out_proj": init_linear(ks[2], d_in, d, dtype=dtype),
    }


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).
    With ``state`` (B, K-1, C) given, acts as a streaming step."""
    w = w.astype(x.dtype)
    b = b.astype(x.dtype)
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    y = y + b[None, None, :]
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return y, new_state


def _split_proj(p, u, cfg):
    d_in, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = linear(p["in_proj"], u)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., -h:]
    return z, xbc, dt_raw


def _gates(p, dt_raw):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))     # (..., H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    la = dt * a                                                # log decay
    return dt, la


def mamba_chunked(p, u, cfg, *, state=None, conv_state=None,
                  return_state: bool = False):
    """u: (B, S, d_model) -> (B, S, d_model).  SSD chunked scan."""
    b, s, _ = u.shape
    d_in, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    dh = d_in // h
    chunk = min(cfg.ssm_chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk

    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=conv_state)
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :d_in].reshape(b, s, h, dh)
    Bm = xbc[..., d_in:d_in + n]                               # (B,S,N)
    Cm = xbc[..., d_in + n:]                                   # (B,S,N)
    dt, la = _gates(p, dt_raw)                                 # (B,S,H)

    # per-chunk views, chunk axis leading for the scan
    def chunked(t, shape):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + shape), 1, 0)

    xc = chunked(x.astype(jnp.float32), (h, dh))               # (nc,B,L,H,dh)
    Bc = chunked(Bm.astype(jnp.float32), (n,))
    Cc = chunked(Cm.astype(jnp.float32), (n,))
    dtc = chunked(dt, (h,))
    lac = chunked(la, (h,))

    if state is None:
        state = jnp.zeros((b, h, n, dh), jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_body(st, inp):
        xk, bk, ck, dk, lk = inp
        cum = jnp.cumsum(lk, axis=1)                           # (B,L,H)
        total = cum[:, -1, :]                                  # (B,H)
        # intra-chunk masked quadratic
        gap = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L,L,H)
        gap = jnp.where(tri[None, :, :, None], gap, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", ck, bk)                # (B,L,L)
        m = jnp.exp(gap) * (cb[..., None] * dk[:, None, :, :])
        y = jnp.einsum("btsh,bshd->bthd", m, xk)
        # inter-chunk: read the carried state
        y = y + jnp.einsum("btn,bhnd->bthd", ck, st) \
            * jnp.exp(cum)[..., None]
        # new carried state
        w_state = jnp.exp(total[:, None, :] - cum) * dk        # (B,L,H)
        s_c = jnp.einsum("blh,bln,blhd->bhnd", w_state, bk, xk)
        st = st * jnp.exp(total)[:, :, None, None] + s_c
        return st, y

    state_f, ys = jax.lax.scan(chunk_body, state,
                               (xc, Bc, Cc, dtc, lac))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh)
    y = y + x.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None,
                                                               :, None]
    y = y.reshape(b, s, d_in).astype(u.dtype)
    y = norm(p["out_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = linear(p["out_proj"], y)
    if return_state:
        return out, state_f, conv_state
    return out


def mamba_decode(p, u, cfg, state, conv_state):
    """One-token recurrent update.  u: (B, 1, d); state (B,H,N,dh) f32;
    conv_state (B, K-1, d_in + 2N)."""
    b = u.shape[0]
    d_in, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    dh = d_in // h
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=conv_state)
    xbc = jax.nn.silu(xbc)
    x = xbc[:, 0, :d_in].reshape(b, h, dh).astype(jnp.float32)
    Bm = xbc[:, 0, d_in:d_in + n].astype(jnp.float32)          # (B,N)
    Cm = xbc[:, 0, d_in + n:].astype(jnp.float32)              # (B,N)
    dt, la = _gates(p, dt_raw)                                 # (B,1,H)
    dec = jnp.exp(la[:, 0, :])                                 # (B,H)
    state = state * dec[:, :, None, None] + jnp.einsum(
        "bn,bhd,bh->bhnd", Bm, x, dt[:, 0, :])
    y = jnp.einsum("bn,bhnd->bhd", Cm, state)
    y = y + x * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_in).astype(u.dtype)
    y = norm(p["out_norm"], y * jax.nn.silu(z), "rmsnorm")
    return linear(p["out_proj"], y), state, conv_state


def mamba_recurrent_ref(p, u, cfg):
    """Step-by-step oracle for tests."""
    b, s, _ = u.shape
    d_in, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    state = jnp.zeros((b, h, n, d_in // h), jnp.float32)
    conv_state = jnp.zeros((b, cfg.ssm_d_conv - 1, d_in + 2 * n), u.dtype)
    outs = []
    for t in range(s):
        o, state, conv_state = mamba_decode(p, u[:, t:t + 1], cfg, state,
                                            conv_state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
