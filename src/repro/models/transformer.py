"""Architecture assembly: decoder-only, hybrid (zamba2), xLSTM and
encoder-decoder (whisper) stacks.

Homogeneous layer runs are stacked (params stacked on a leading axis) and
executed with ``lax.scan`` — compile time is O(#segment kinds), not
O(depth) — with optional ``jax.checkpoint`` (remat) around the block body.
Heterogeneous patterns (deepseek's leading dense layer, zamba2's shared
attention every 6 mamba blocks, xLSTM's 7:1 mLSTM:sLSTM interleave) become
*segments*: slices of the stacked params run by separate scans.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .. import dist
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import xlstm as xlstm_mod
from .layers import (cross_entropy_loss, embed, ffn, init_embedding,
                     init_ffn, init_linear, init_norm, linear, logits_out,
                     norm)
from .rope import sinusoidal_position_at, sinusoidal_positions


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _seg(tree, lo, hi):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


def _prep_stack(stacked, cfg):
    """Cast stacked block params to the compute dtype OUTSIDE the layer
    scan (FSDP all-gathers then move half the bytes), and pin expert
    weights to the EP layout so the gather over the FSDP axis is hoisted
    out of the loop instead of repeated per layer (+remat)."""
    cd = _cdtype(cfg)
    ctx = dist.current()

    def visit(path, leaf):
        if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        out = leaf.astype(cd)
        name = ""
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        if (ctx is not None and name in ("gate", "up", "down")
                and leaf.ndim == 4):
            from jax.sharding import NamedSharding, PartitionSpec as P
            e = leaf.shape[1]
            m = ctx.model_axis if e % ctx.axis_size(ctx.model_axis) == 0 \
                else None
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(ctx.mesh, P(None, m, None, None)))
        return out

    return jax.tree_util.tree_map_with_path(visit, stacked)


# ---------------------------------------------------------------------------
# the standard pre-norm attention block (dense / moe / mla / vlm)
def init_block(key, cfg, *, moe_layer: bool, d_ff: int | None = None):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": init_norm(cfg.d_model, cfg.norm)}
    if cfg.mla:
        p["attn"] = mla_mod.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    if not cfg.parallel_block:
        p["ln2"] = init_norm(cfg.d_model, cfg.norm)
    if moe_layer:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        import dataclasses
        ff_cfg = cfg if d_ff is None else dataclasses.replace(cfg,
                                                              d_ff=d_ff)
        p["ffn"] = init_ffn(ks[1], cfg.d_model, ff_cfg.d_ff, cfg.act)
    return p


def _block_mix(p, h, cfg, positions, mode, cache, pos):
    """The attention (or MLA) sub-layer in the given mode."""
    if cfg.mla:
        if mode == "train":
            return mla_mod.mla_train(p["attn"], h, cfg, positions), None
        if mode == "prefill":
            return mla_mod.mla_prefill(p["attn"], h, cfg, positions)
        return mla_mod.mla_decode(p["attn"], h, cfg, cache, pos)
    if mode == "train":
        return attn_mod.attention_train(p["attn"], h, cfg, positions), None
    if mode == "prefill":
        return attn_mod.attention_prefill(p["attn"], h, cfg, positions)
    return attn_mod.attention_decode(p["attn"], h, cfg, cache, pos)


def block_apply(p, x, cfg, positions, *, moe_layer: bool, mode: str = "train",
                cache=None, pos=None):
    """Returns (x, new_cache)."""
    if cfg.parallel_block:                 # command-r style
        h = norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        a, new_cache = _block_mix(p, h, cfg, positions, mode, cache, pos)
        f = moe_mod.moe_ffn(p["moe"], h, cfg) if moe_layer \
            else ffn(p["ffn"], h, cfg.act)
        return x + a + f, new_cache
    h = norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    a, new_cache = _block_mix(p, h, cfg, positions, mode, cache, pos)
    x = x + a
    h = norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    f = moe_mod.moe_ffn(p["moe"], h, cfg) if moe_layer \
        else ffn(p["ffn"], h, cfg.act)
    return x + f, new_cache


# ---------------------------------------------------------------------------
# segments: (kind, count) derived from the config
def segments(cfg) -> list[tuple[str, int]]:
    if cfg.family in ("dense", "vlm"):
        return [("block", cfg.n_layers)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense:
            segs.append(("dense_block", cfg.first_dense))
        segs.append(("moe_block", cfg.n_layers - cfg.first_dense))
        return segs
    if cfg.family == "hybrid":          # zamba2
        return [("zamba", cfg.n_layers)]
    if cfg.family == "ssm":             # xlstm
        return [("xlstm", cfg.n_layers)]
    if cfg.family == "audio":
        return [("whisper", cfg.n_layers)]
    raise ValueError(cfg.family)


def _zamba_attn_positions(cfg) -> list[int]:
    """Mamba-layer indices before which the shared attention block runs."""
    return [i for i in range(cfg.attn_every, cfg.n_layers, cfg.attn_every)]


def _xlstm_slstm_count(cfg) -> int:
    return cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0


# ---------------------------------------------------------------------------
def init_decoder(key, cfg):
    """Full parameter pytree for any family."""
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(ks[1], cfg.d_model, cfg.padded_vocab)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: init_block(k, cfg, moe_layer=False))
    elif fam == "moe":
        if cfg.first_dense:
            p["dense_blocks"] = _stack_init(
                ks[3], cfg.first_dense,
                lambda k: init_block(k, cfg, moe_layer=False,
                                     d_ff=cfg.first_dense_ff))
        p["moe_blocks"] = _stack_init(
            ks[2], cfg.n_layers - cfg.first_dense,
            lambda k: init_block(k, cfg, moe_layer=True))
    elif fam == "hybrid":
        p["mamba"] = _stack_init(
            ks[2], cfg.n_layers, lambda k: mamba_mod.init_mamba(k, cfg))
        # one shared attention block + its 2d -> d input projection
        p["shared_in"] = init_linear(ks[4], 2 * cfg.d_model, cfg.d_model)
        p["shared_attn"] = init_block(ks[3], cfg, moe_layer=False)
    elif fam == "ssm":
        n_s = _xlstm_slstm_count(cfg)
        p["mlstm"] = _stack_init(
            ks[2], cfg.n_layers - n_s,
            lambda k: xlstm_mod.init_mlstm(k, cfg))
        if n_s:
            p["slstm"] = _stack_init(
                ks[3], n_s, lambda k: xlstm_mod.init_slstm(k, cfg))
    elif fam == "audio":
        import dataclasses
        enc_cfg = dataclasses.replace(cfg, rope_type="none")
        p["enc_blocks"] = _stack_init(
            ks[2], cfg.encoder_layers,
            lambda k: init_block(k, enc_cfg, moe_layer=False))
        p["enc_norm"] = init_norm(cfg.d_model, cfg.norm)
        p["dec_blocks"] = _stack_init(
            ks[3], cfg.n_layers, lambda k: _init_whisper_dec_block(k, cfg))
    else:
        raise ValueError(fam)
    return p


def _init_whisper_dec_block(key, cfg):
    ks = jax.random.split(key, 4)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "ln_x": init_norm(cfg.d_model, cfg.norm),
        "xattn": attn_mod.init_attention(ks[1], cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm),
        "ffn": init_ffn(ks[2], cfg.d_model, cfg.d_ff, cfg.act),
    }


# ---------------------------------------------------------------------------
# scanned segment runners
def _remat(f, cfg):
    if not cfg.remat:
        return f
    if cfg.remat_policy == "dots":
        # save matmul outputs; recompute only elementwise chains — trades
        # HBM for a large cut in backward recompute flops (§Perf)
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _run_scan(stacked, x, body, cfg, *, collect=False, caches=None,
              length=None):
    """Scan a homogeneous stack.  body(x, p_l, cache_l) -> (x, new_cache)."""
    def f(carry, inp):
        p_l, c_l = inp if caches is not None else (inp, None)
        out, new_c = body(carry, p_l, c_l)
        return out, new_c

    f = _remat(f, cfg)
    xs = (stacked, caches) if caches is not None else stacked
    x, cs = jax.lax.scan(f, x, xs, length=length)
    return (x, cs) if (collect or caches is not None) else (x, None)


def _positions(tokens_shape, offset=0):
    b, s = tokens_shape
    return jnp.arange(s, dtype=jnp.int32)[None, :] + offset


# ---------------------------------------------------------------------------
# forward (train) / prefill / decode for each family
def _embed_tokens(p, cfg, tokens, vision_embeds=None):
    x = embed(p["embed"], tokens,
              scale=cfg.d_model ** 0.5 if cfg.embed_scale else None)
    x = x.astype(_cdtype(cfg))
    if vision_embeds is not None and cfg.vision_seq:
        nv = vision_embeds.shape[1]
        x = jnp.concatenate(
            [vision_embeds.astype(_cdtype(cfg)), x[:, nv:]], axis=1)
    return dist.constrain_seq(x)


def forward(p, cfg, tokens, *, vision_embeds=None, enc_frames=None,
            mode: str = "train", caches=None, pos=None):
    """Unified entry.  Returns (hidden, caches):

    * train:   hidden (B, S, d), caches None
    * prefill: hidden (B, S, d), fresh caches
    * decode:  hidden (B, 1, d), updated caches   (pos: scalar index)
    """
    fam = cfg.family
    if fam == "audio":
        return _whisper_forward(p, cfg, tokens, enc_frames, mode, caches,
                                pos)
    x = _embed_tokens(p, cfg, tokens, vision_embeds)
    positions = _positions(tokens.shape) if mode != "decode" else None

    if fam in ("dense", "vlm"):
        x, caches = _run_attn_stack(p["blocks"], x, cfg, positions, mode,
                                    caches, pos, moe_layer=False)
        out_caches = caches
    elif fam == "moe":
        out_caches = {}
        if cfg.first_dense:
            x, c = _run_attn_stack(p["dense_blocks"], x, cfg, positions,
                                   mode, caches and caches.get("dense"),
                                   pos, moe_layer=False)
            out_caches["dense"] = c
        x, c = _run_attn_stack(p["moe_blocks"], x, cfg, positions, mode,
                               caches and caches.get("moe"), pos,
                               moe_layer=True)
        out_caches["moe"] = c
        if not cfg.first_dense:
            out_caches = {"moe": out_caches["moe"]}
    elif fam == "hybrid":
        x, out_caches = _zamba_forward(p, cfg, x, positions, mode, caches,
                                       pos)
    elif fam == "ssm":
        x, out_caches = _xlstm_forward(p, cfg, x, mode, caches)
    else:
        raise ValueError(fam)

    x = norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x, out_caches


def _run_attn_stack(stacked, x, cfg, positions, mode, caches, pos, *,
                    moe_layer: bool):
    stacked = _prep_stack(stacked, cfg)
    if mode == "train":
        def body(h, p_l, _):
            out, _ = block_apply(p_l, h, cfg, positions,
                                 moe_layer=moe_layer, mode="train")
            return dist.constrain_seq(out), 0.0
        x, _ = _run_scan(stacked, x, body, cfg)
        return x, None
    if mode == "prefill":
        def body(h, p_l, _):
            out, c = block_apply(p_l, h, cfg, positions,
                                 moe_layer=moe_layer, mode="prefill")
            return dist.constrain_seq(out), c
        def f(carry, p_l):
            return body(carry, p_l, None)
        f = _remat(f, cfg)
        x, caches = jax.lax.scan(f, x, stacked)
        return x, caches
    # decode
    def f(carry, inp):
        p_l, c_l = inp
        out, new_c = block_apply(p_l, carry, cfg, None,
                                 moe_layer=moe_layer, mode="decode",
                                 cache=c_l, pos=pos)
        return out, new_c
    x, new_caches = jax.lax.scan(f, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
def _zamba_forward(p, cfg, x, positions, mode, caches, pos):
    """38 mamba blocks; before every ``attn_every``-th block the shared
    attention block runs on concat(hidden, embeddings)."""
    x0 = x
    attn_at = _zamba_attn_positions(cfg)
    bounds = [0] + attn_at + [cfg.n_layers]
    n_attn = len(attn_at)
    b = x.shape[0]

    new_caches: dict[str, Any] = {"mamba": [], "conv": [], "attn": []}

    for si in range(len(bounds) - 1):
        lo, hi = bounds[si], bounds[si + 1]
        if si > 0:
            # shared attention block with its own cache per call site
            h = linear(p["shared_in"],
                       jnp.concatenate([x, x0], axis=-1))
            a_cache = caches["attn"][si - 1] if mode == "decode" else None
            h, c = block_apply(p["shared_attn"], h, cfg, positions,
                               moe_layer=False, mode=mode, cache=a_cache,
                               pos=pos)
            x = h  # block_apply carries its own residual stream
            if mode != "train":
                new_caches["attn"].append(c)
        seg = _seg(p["mamba"], lo, hi)
        if mode == "train":
            def body(h, p_l, _):
                return dist.constrain_seq(
                    mamba_mod.mamba_chunked(p_l, h, cfg)), 0.0
            x, _ = _run_scan(seg, x, body, cfg)
        elif mode == "prefill":
            def f(carry, p_l):
                out, st, cs = mamba_mod.mamba_chunked(
                    p_l, carry, cfg, return_state=True)
                return out, (st, cs)
            x, (sts, css) = jax.lax.scan(f, x, seg)
            new_caches["mamba"].append(sts)
            new_caches["conv"].append(css)
        else:
            def f(carry, inp):
                p_l, st, cs = inp
                out, st2, cs2 = mamba_mod.mamba_decode(p_l, carry, cfg,
                                                       st, cs)
                return out, (st2, cs2)
            x, (sts, css) = jax.lax.scan(
                f, x, (seg, caches["mamba"][si], caches["conv"][si]))
            new_caches["mamba"].append(sts)
            new_caches["conv"].append(css)
    if mode == "train":
        return x, None
    return x, new_caches


# ---------------------------------------------------------------------------
def _xlstm_forward(p, cfg, x, mode, caches):
    """Repeats of (slstm_every - 1) scanned mLSTM blocks + one sLSTM."""
    n_s = _xlstm_slstm_count(cfg)
    per = (cfg.slstm_every - 1) if n_s else cfg.n_layers
    n_m = cfg.n_layers - n_s
    reps = n_s if n_s else 1
    new_caches: dict[str, Any] = {"mlstm": [], "mconv": [], "slstm": []}

    for r in range(reps):
        lo, hi = r * per, min((r + 1) * per, n_m)
        seg = _seg(p["mlstm"], lo, hi)
        if mode == "train":
            def f(carry, p_l):
                out = carry + xlstm_mod.mlstm_chunked(p_l, carry, cfg)
                return dist.constrain_seq(out), 0.0
            f = _remat(f, cfg)
            x, _ = jax.lax.scan(f, x, seg)
        elif mode == "prefill":
            def f(carry, p_l):
                out, st, cs = xlstm_mod.mlstm_chunked(
                    p_l, carry, cfg, return_state=True)
                return carry + out, (st, cs)
            x, (sts, css) = jax.lax.scan(f, x, seg)
            new_caches["mlstm"].append(sts)
            new_caches["mconv"].append(css)
        else:
            def f(carry, inp):
                p_l, st, cs = inp
                out, st2, cs2 = xlstm_mod.mlstm_decode(p_l, carry, cfg,
                                                       st, cs)
                return carry + out, (st2, cs2)
            x, (sts, css) = jax.lax.scan(
                f, x, (seg, caches["mlstm"][r], caches["mconv"][r]))
            new_caches["mlstm"].append(sts)
            new_caches["mconv"].append(css)
        if n_s:
            p_s = _seg(p["slstm"], r, r + 1)
            p_s = jax.tree_util.tree_map(lambda a: a[0], p_s)
            if mode == "train":
                x = x + xlstm_mod.slstm_scan(p_s, x, cfg)
            elif mode == "prefill":
                out, st = xlstm_mod.slstm_scan(p_s, x, cfg,
                                               return_state=True)
                x = x + out
                new_caches["slstm"].append(st)
            else:
                out, st = xlstm_mod.slstm_decode(p_s, x, cfg,
                                                 caches["slstm"][r])
                x = x + out
                new_caches["slstm"].append(st)
    if mode == "train":
        return x, None
    return x, new_caches


# ---------------------------------------------------------------------------
def _whisper_forward(p, cfg, tokens, enc_frames, mode, caches, pos):
    """Encoder-decoder.  enc_frames: (B, S_enc, d) precomputed frame
    embeddings (the conv frontend stub per the assignment)."""
    cd = _cdtype(cfg)

    def encode(frames):
        x = frames.astype(cd) + sinusoidal_positions(
            frames.shape[1], cfg.d_model).astype(cd)[None]
        def f(carry, p_l):
            h = norm(p_l["ln1"], carry, cfg.norm, cfg.norm_eps)
            a = attn_mod.attention_train(p_l["attn"], h, cfg, None,
                                         causal=False)
            carry = carry + a
            h = norm(p_l["ln2"], carry, cfg.norm, cfg.norm_eps)
            return dist.constrain_seq(carry + ffn(p_l["ffn"], h,
                                                  cfg.act)), 0.0
        f = _remat(f, cfg)
        x, _ = jax.lax.scan(f, x, p["enc_blocks"])
        return norm(p["enc_norm"], x, cfg.norm, cfg.norm_eps)

    if mode == "decode":
        enc_out = caches["enc_out"]
    else:
        enc_out = encode(enc_frames)

    x = embed(p["embed"], tokens,
              scale=cfg.d_model ** 0.5 if cfg.embed_scale else None)
    x = x.astype(cd)
    if mode == "decode":
        # sinusoid evaluated at the (traced) decode position
        x = x + sinusoidal_position_at(pos, cfg.d_model).astype(cd)[None,
                                                                    None, :]
    else:
        x = x + sinusoidal_positions(tokens.shape[1],
                                     cfg.d_model).astype(cd)[None]
    positions = _positions(tokens.shape)

    def dec_block(p_l, h, mode, cache, pos):
        hh = norm(p_l["ln1"], h, cfg.norm, cfg.norm_eps)
        if mode == "train":
            a, new_self = attn_mod.attention_train(
                p_l["attn"], hh, cfg, None, causal=True), None
        elif mode == "prefill":
            a, new_self = attn_mod.attention_prefill(p_l["attn"], hh, cfg,
                                                     None, causal=True)
        else:
            a, new_self = attn_mod.attention_decode(
                p_l["attn"], hh, cfg, cache["self"], pos)
        h = h + a
        hh = norm(p_l["ln_x"], h, cfg.norm, cfg.norm_eps)
        # cross attention against encoder output
        k = attn_mod._split_heads(linear(p_l["xattn"]["wk"], enc_out),
                                  cfg.n_kv_heads, cfg.head_dim)
        v = attn_mod._split_heads(linear(p_l["xattn"]["wv"], enc_out),
                                  cfg.n_kv_heads, cfg.head_dim)
        if mode == "decode":
            xa, _ = attn_mod.attention_decode(p_l["xattn"], hh, cfg, None,
                                              pos, kv_override=(k, v))
        else:
            xa = attn_mod.attention_train(p_l["xattn"], hh, cfg, None,
                                          causal=False, kv_override=(k, v))
        h = h + xa
        hh = norm(p_l["ln2"], h, cfg.norm, cfg.norm_eps)
        h = h + ffn(p_l["ffn"], hh, cfg.act)
        return h, new_self

    if mode == "train":
        def f(carry, p_l):
            out, _ = dec_block(p_l, carry, "train", None, None)
            return dist.constrain_seq(out), 0.0
        f = _remat(f, cfg)
        x, _ = jax.lax.scan(f, x, p["dec_blocks"])
        new_caches = None
    elif mode == "prefill":
        def f(carry, p_l):
            out, c = dec_block(p_l, carry, "prefill", None, None)
            return out, c
        x, selfs = jax.lax.scan(f, x, p["dec_blocks"])
        new_caches = {"self": selfs, "enc_out": enc_out}
    else:
        def f(carry, inp):
            p_l, c_l = inp
            out, c = dec_block(p_l, carry, "decode", {"self": c_l}, pos)
            return out, c
        x, selfs = jax.lax.scan(f, x, (p["dec_blocks"], caches["self"]))
        new_caches = {"self": selfs, "enc_out": enc_out}

    x = norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x, new_caches
