"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV states are compressed into a per-token latent ``c_kv`` (kv_lora_rank wide)
plus one shared RoPE key (rope_head_dim); per-head K/V are up-projections of
the latent.  Training materializes per-head K/V and runs flash attention
with asymmetric head dims (qk = nope+rope, v = v_head_dim).  Decode runs in
the *absorbed* form: queries are pushed through the K up-projection so
attention happens directly against the latent cache — the cache is
(kv_lora_rank + rope_head_dim) per token instead of 2*H*D, which is the
technique's entire point and maps beautifully onto BDDT-SCC's lesson of
keeping the data plane small and local.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import dist
from ..kernels.flash_attention import ops as fa_ops
from . import rope as rope_mod
from .layers import init_linear, init_norm, linear, norm


def init_mla(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        # queries: full-rank in V2-Lite (q_lora_rank == 0)
        "wq": init_linear(ks[0], d, h * (dn + dr), dtype=dtype),
        # latent down-projection + shared rope key
        "wkv_a": init_linear(ks[1], d, r + dr, dtype=dtype),
        "kv_norm": init_norm(r, "rmsnorm", dtype),
        # per-head up-projections from the latent
        "wk_b": init_linear(ks[2], r, h * dn, dtype=dtype),
        "wv_b": init_linear(ks[3], r, h * dv, dtype=dtype),
        "wo": init_linear(ks[4], h * dv, d, dtype=dtype),
    }


def _project_latent(p, x, cfg, positions):
    """x -> (c_kv normalized, k_rope rotated): the cacheable quantities."""
    r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    kv = linear(p["wkv_a"], x)                          # (B, S, r + dr)
    c_kv = norm(p["kv_norm"], kv[..., :r], "rmsnorm")
    k_rope = kv[..., r:][:, :, None, :].transpose(0, 2, 1, 3)  # (B,1,S,dr)
    k_rope = rope_mod.apply_rope(k_rope, positions, theta=cfg.rope_theta)
    return c_kv, k_rope


def _project_q(p, x, cfg, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q = linear(p["wq"], x).reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope_mod.apply_rope(q_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope                               # (B,H,S,dn),(B,H,S,dr)


def mla_train(p, x, cfg, positions, *, causal: bool = True):
    """Materialized path: build per-head K/V from the latent, flash-attend."""
    b, s, _ = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _project_q(p, x, cfg, positions)
    c_kv, k_rope = _project_latent(p, x, cfg, positions)
    k_nope = linear(p["wk_b"], c_kv).reshape(b, s, h, dn).transpose(0, 2, 1, 3)
    v = linear(p["wv_b"], c_kv).reshape(b, s, h, dv).transpose(0, 2, 1, 3)
    q = dist.constrain_heads(jnp.concatenate([q_nope, q_rope], -1))
    k = dist.constrain_heads(jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, h, s, dr)).astype(k_nope.dtype)], -1))
    v = dist.constrain_heads(v)
    scale = (dn + dr) ** -0.5
    out = dist.constrain_heads(
        fa_ops.attention(q, k, v, causal=causal, scale=scale,
                         impl="chunked", q_chunk=cfg.attn_q_chunk,
                         k_chunk=cfg.attn_k_chunk))
    return linear(p["wo"], out.transpose(0, 2, 1, 3).reshape(b, s, h * dv))


def mla_prefill(p, x, cfg, positions, *, causal: bool = True):
    out = mla_train(p, x, cfg, positions, causal=causal)
    c_kv, k_rope = _project_latent(p, x, cfg, positions)
    return out, {"c_kv": c_kv, "k_rope": k_rope[:, 0]}   # (B,S,r), (B,S,dr)


def mla_decode(p, x, cfg, cache, pos, *, update_cache: bool = True):
    """Absorbed decode against the latent cache.

    cache: {"c_kv": (B, S, r), "k_rope": (B, S, dr)}.
    scores_h(t) = q_nope_h . (W_uk_h c_t) + q_rope_h . k_rope_t
                = (W_uk_h^T q_nope_h) . c_t + q_rope_h . k_rope_t
    out_h = W_uv_h (sum_t softmax_t c_t)  — all against the latent.
    """
    b = x.shape[0]
    h, dn, dr, dv = (cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _project_q(p, x, cfg, positions)   # (B,H,1,dn/dr)
    c_new, k_rope_new = _project_latent(p, x, cfg, positions)
    if update_cache:
        cache = {
            "c_kv": jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1),
            "k_rope": jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], k_rope_new[:, 0].astype(
                    cache["k_rope"].dtype), pos, axis=1),
        }
    c_kv, k_rope = cache["c_kv"], cache["k_rope"]       # (B,S,r),(B,S,dr)
    s_len = c_kv.shape[1]
    # absorb q through the K up-projection: (B,H,dn) @ (r,H,dn) -> (B,H,r)
    wk_b = p["wk_b"]["w"].reshape(r, h, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat, c_kv.astype(jnp.float32)) +
              jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(jnp.float32),
                         k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(s_len) <= pos
    logits = jnp.where(valid[None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, c_kv.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].reshape(r, h, dv)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv_b.astype(jnp.float32))
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    return linear(p["wo"], o), cache
