"""Public model API: build init / loss / prefill / decode callables from a
ModelConfig.  Everything is functional; the trainer and dry-run attach
shardings at the jit boundary."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import transformer
from .layers import cross_entropy_loss, logits_out


def init_params(key, cfg):
    return transformer.init_decoder(key, cfg)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def _logits_fn(params, cfg):
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    head = params.get("lm_head")

    def f(hidden):
        lg = logits_out(head, hidden, tied_table=tied)
        if cfg.logit_softcap:
            lg = cfg.logit_softcap * jnp.tanh(lg / cfg.logit_softcap)
        return lg
    return f


# ---------------------------------------------------------------------------
def loss_fn(params, cfg, batch):
    """batch: {"tokens": (B, S) int32, "loss_mask": (B, S) opt,
    "vision_embeds"/"enc_frames": modality stubs}.  Next-token CE."""
    tokens = batch["tokens"]
    hidden, _ = transformer.forward(
        params, cfg, tokens,
        vision_embeds=batch.get("vision_embeds"),
        enc_frames=batch.get("enc_frames"),
        mode="train")
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], dtype=jnp.float32),
         jnp.zeros_like(tokens[:, :1], dtype=jnp.float32)], axis=1)
    if batch.get("loss_mask") is not None:
        mask = mask * batch["loss_mask"].astype(jnp.float32)
    if cfg.vision_seq:
        # vision stub positions carry no token labels
        vis = jnp.arange(tokens.shape[1]) < cfg.vision_seq
        mask = mask * (~vis[None, :]).astype(jnp.float32)
    return cross_entropy_loss(_logits_fn(params, cfg), hidden, labels, mask)


def forward_logits(params, cfg, batch):
    """Full-sequence logits (small configs / tests only)."""
    hidden, _ = transformer.forward(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        enc_frames=batch.get("enc_frames"), mode="train")
    return _logits_fn(params, cfg)(hidden)


# ---------------------------------------------------------------------------
def prefill_step(params, cfg, batch):
    """Run the prompt; return (last-token logits, caches)."""
    hidden, caches = transformer.forward(
        params, cfg, batch["tokens"],
        vision_embeds=batch.get("vision_embeds"),
        enc_frames=batch.get("enc_frames"), mode="prefill")
    logits = _logits_fn(params, cfg)(hidden[:, -1:])
    return logits, caches


def decode_step(params, cfg, token, caches, pos):
    """One decode step.  token: (B, 1) int32; pos: scalar int32 (the index
    this token occupies; the KV cache holds `pos` valid entries)."""
    hidden, caches = transformer.forward(
        params, cfg, token, mode="decode", caches=caches, pos=pos)
    logits = _logits_fn(params, cfg)(hidden)
    return logits, caches


# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, seq_len: int, dtype=None):
    """Abstract-friendly cache allocation for decode-shape dry-runs (filled
    by prefill in real serving)."""
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    fam = cfg.family
    b, s = batch, seq_len

    def attn_cache(n_layers):
        shape = (n_layers, b, cfg.n_kv_heads, s, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    if fam in ("dense", "vlm"):
        return transformer_cache_tree(attn_cache(cfg.n_layers))
    if fam == "moe":
        if cfg.mla:
            def mla_cache(n):
                return {"c_kv": jnp.zeros((n, b, s, cfg.kv_lora_rank), dt),
                        "k_rope": jnp.zeros((n, b, s, cfg.rope_head_dim),
                                            dt)}
            out = {"moe": mla_cache(cfg.n_layers - cfg.first_dense)}
            if cfg.first_dense:
                out["dense"] = mla_cache(cfg.first_dense)
            return out
        out = {"moe": attn_cache(cfg.n_layers - cfg.first_dense)}
        if cfg.first_dense:
            out["dense"] = attn_cache(cfg.first_dense)
        return out
    if fam == "hybrid":
        attn_at = transformer._zamba_attn_positions(cfg)
        bounds = [0] + attn_at + [cfg.n_layers]
        d_in, n_ssm, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        dh = d_in // h
        mamba, conv, attn = [], [], []
        for si in range(len(bounds) - 1):
            nl = bounds[si + 1] - bounds[si]
            mamba.append(jnp.zeros((nl, b, h, n_ssm, dh), jnp.float32))
            conv.append(jnp.zeros((nl, b, cfg.ssm_d_conv - 1,
                                   d_in + 2 * n_ssm), dt))
            if si > 0:
                attn.append({"k": jnp.zeros((b, cfg.n_kv_heads, s,
                                             cfg.head_dim), dt),
                             "v": jnp.zeros((b, cfg.n_kv_heads, s,
                                             cfg.head_dim), dt)})
        return {"mamba": mamba, "conv": conv, "attn": attn}
    if fam == "ssm":
        n_s = transformer._xlstm_slstm_count(cfg)
        per = (cfg.slstm_every - 1) if n_s else cfg.n_layers
        n_m = cfg.n_layers - n_s
        reps = n_s if n_s else 1
        d_in = cfg.xlstm_d_inner
        dh = d_in // cfg.n_heads
        dmh = cfg.d_model // cfg.n_heads
        ml, mc, sl = [], [], []
        for r in range(reps):
            nl = min((r + 1) * per, n_m) - r * per
            ml.append((jnp.zeros((nl, b, cfg.n_heads, dh, dh), jnp.float32),
                       jnp.zeros((nl, b, cfg.n_heads, dh), jnp.float32),
                       jnp.full((nl, b, cfg.n_heads), -1e30, jnp.float32)))
            mc.append(jnp.zeros((nl, b, cfg.xlstm_d_conv - 1, d_in), dt))
            if n_s:
                sl.append((jnp.zeros((b, cfg.n_heads, dmh), jnp.float32),
                           jnp.zeros((b, cfg.n_heads, dmh), jnp.float32),
                           jnp.full((b, cfg.n_heads, dmh), -1e30,
                                    jnp.float32),
                           jnp.zeros((b, cfg.n_heads, dmh), jnp.float32)))
        return {"mlstm": ml, "mconv": mc, "slstm": sl}
    if fam == "audio":
        return {
            "self": {"k": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s,
                                     cfg.head_dim), dt),
                     "v": jnp.zeros((cfg.n_layers, b, cfg.n_kv_heads, s,
                                     cfg.head_dim), dt)},
            "enc_out": jnp.zeros((b, cfg.encoder_seq, cfg.d_model), dt),
        }
    raise ValueError(fam)


def transformer_cache_tree(c):
    return c


def pad_caches(caches, target_len: int):
    """Grow every sequence-indexed cache leaf (k/v/c_kv/k_rope, seq axis -2)
    to ``target_len`` so decode can continue past the prompt length."""
    def visit(path, leaf):
        name = ""
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        if name in ("k", "v", "c_kv", "k_rope"):
            s = leaf.shape[-2]
            if s < target_len:
                pad = [(0, 0)] * leaf.ndim
                pad[-2] = (0, target_len - s)
                return jnp.pad(leaf, pad)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, caches)
