"""Shared layers: norms, linears, FFN variants, embeddings, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Init = jax.nn.initializers


# -- primitives ---------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                scale: float | None = None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.truncated_normal(key, -2, 2, (d_in, d_out),
                                          dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_norm(d: int, kind: str = "rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p, x, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- FFN variants ---------------------------------------------------------------
def init_ffn(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"gate": init_linear(ks[0], d_model, d_ff, dtype=dtype),
                "up": init_linear(ks[1], d_model, d_ff, dtype=dtype),
                "down": init_linear(ks[2], d_ff, d_model, dtype=dtype)}
    # gelu / relu2: plain 2-layer MLP
    return {"up": init_linear(ks[0], d_model, d_ff, dtype=dtype),
            "down": init_linear(ks[1], d_ff, d_model, dtype=dtype)}


def ffn(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    elif act == "gelu":
        h = jax.nn.gelu(linear(p["up"], x), approximate=True)
    elif act == "relu2":                      # Nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(linear(p["up"], x)))
    else:
        raise ValueError(act)
    return linear(p["down"], h)


# -- embeddings / logits -----------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(p, tokens, scale: float | None = None):
    e = jnp.take(p["table"], tokens, axis=0)
    if scale is not None:
        e = e * scale
    return e


def logits_out(p_head, x, *, tied_table=None, scale: float | None = None):
    """Project hidden states to the (padded) vocabulary."""
    if tied_table is not None:
        w = tied_table.T
    else:
        w = p_head["w"]
    y = x @ w.astype(x.dtype)
    if scale is not None:
        y = y * scale
    return y


# -- loss ------------------------------------------------------------------------
def cross_entropy_loss(logits_fn, hidden, labels, mask, *,
                       chunk: int = 1024):
    """Next-token CE computed in sequence chunks so the (B, S, V) logits
    tensor never materializes (vital for 100k+ vocabularies).

    ``logits_fn``: hidden chunk (B, c, D) -> logits (B, c, V) (possibly
    vocab-sharded; the max/sum reductions then induce small all-reduces).
    ``labels``/``mask``: (B, S) int / bool.
    """
    b, s, _ = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    n_chunks = s // chunk

    @jax.checkpoint
    def body(carry, i):
        # checkpointed: the (B, c, V) logits chunk is recomputed in the
        # backward pass instead of being stashed once per chunk
        tot, cnt = carry
        h = jax.lax.dynamic_slice_in_dim(hidden, i * chunk, chunk, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        m = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        lg = logits_fn(h).astype(jnp.float32)          # (B, c, V)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (tot + nll.sum(), cnt + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 jnp.arange(n_chunks))
    return tot / jnp.maximum(cnt, 1.0)
