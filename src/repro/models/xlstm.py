"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, inherently recurrent), arXiv:2405.04517.

mLSTM training uses the stabilized chunkwise form (TFLA-style): within a
chunk the gated outer-product recurrence is evaluated as a masked
attention-like quadratic; across chunks the (dk, dv) matrix memory, the
normalizer and the log-space stabilizer are carried by ``lax.scan``.
Decode is the O(1) recurrent update.  sLSTM has true recurrent weights
(h_{t-1} feeds the gates), so it runs as a per-step scan — that
sequential spine is the architecture's design, not an implementation
shortcut; the 7:1 mLSTM:sLSTM interleave keeps it off the critical path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_linear, init_norm, linear, norm

_NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM
def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in = cfg.xlstm_d_inner
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "up": init_linear(ks[0], d, 2 * d_in, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.xlstm_d_conv, d_in),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((d_in,), dtype),
        # q/k/v are per-head block-diagonal (the mLSTM multihead design;
        # dense d_in x d_in would triple the block's parameter count)
        "wq": jax.random.normal(ks[2], (h, d_in // h, d_in // h),
                                dtype) * (d_in // h) ** -0.5,
        "wk": jax.random.normal(ks[3], (h, d_in // h, d_in // h),
                                dtype) * (d_in // h) ** -0.5,
        "wv": jax.random.normal(ks[4], (h, d_in // h, d_in // h),
                                dtype) * (d_in // h) ** -0.5,
        "w_if": init_linear(ks[5], d_in, 2 * h, dtype=dtype),
        "skip": jnp.ones((d_in,), dtype) * 0.5,
        "out_norm": init_norm(d_in, "rmsnorm", dtype),
        "down": init_linear(ks[6], d_in, d, dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    w = w.astype(x.dtype)
    b = b.astype(x.dtype)
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    return y + b[None, None, :], (xp[:, -(k - 1):, :] if k > 1 else None)


def _mlstm_qkvif(p, u, cfg, conv_state=None):
    b, s, _ = u.shape
    h = cfg.n_heads
    d_in = cfg.xlstm_d_inner
    dh = d_in // h
    up = linear(p["up"], u)
    x_m, z = up[..., :d_in], up[..., d_in:]
    x_c, conv_state = _causal_conv(x_m, p["conv_w"], p["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)
    def headproj(w, t):
        return jnp.einsum("bshd,hde->bshe",
                          t.reshape(b, s, h, dh), w.astype(t.dtype))

    q = headproj(p["wq"], x_c)
    k = headproj(p["wk"], x_c) * (dh ** -0.5)
    v = headproj(p["wv"], x_m)
    i_f = linear(p["w_if"], x_m).astype(jnp.float32)
    i_pre, f_pre = i_f[..., :h], i_f[..., h:]              # (B,S,H)
    f_log = jax.nn.log_sigmoid(f_pre)
    return q, k, v, i_pre, f_log, x_c, z, conv_state


def mlstm_chunked(p, u, cfg, *, state=None, return_state: bool = False,
                  conv_state=None):
    """u: (B, S, d) -> (B, S, d)."""
    b, s, _ = u.shape
    h = cfg.n_heads
    d_in = cfg.xlstm_d_inner
    dh = d_in // h
    chunk = min(cfg.xlstm_chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk

    q, k, v, i_pre, f_log, x_c, z, conv_state = _mlstm_qkvif(
        p, u, cfg, conv_state)

    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),   # C (dk, dv)
                 jnp.zeros((b, h, dh), jnp.float32),       # n
                 jnp.full((b, h), _NEG, jnp.float32))      # m

    def chunked(t, shape):
        return jnp.moveaxis(
            t.reshape((b, nc, chunk) + shape), 1, 0).astype(jnp.float32)

    qc, kc, vc = (chunked(t, (h, dh)) for t in (q, k, v))
    ic = chunked(i_pre, (h,))
    fc = chunked(f_log, (h,))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        C, n, m = carry
        qk_, kk_, vk_, ik_, fk_ = inp                      # (B,L,H,*)
        F = jnp.cumsum(fk_, axis=1)                        # (B,L,H)
        # intra logits D[t,s] = F_t - F_s + i_s  (s <= t)
        D = F[:, :, None, :] - F[:, None, :, :] + ik_[:, None, :, :]
        D = jnp.where(tri[None, :, :, None], D, _NEG)
        A = F + m[:, None, :]                              # inter decay logit
        m_loc = jnp.maximum(D.max(axis=2), A)              # (B,L,H)
        d_w = jnp.exp(D - m_loc[:, :, None, :])            # (B,L,L,H)
        a_w = jnp.exp(A - m_loc)                           # (B,L,H)
        qk = jnp.einsum("blhd,bshd->blsh", qk_, kk_)       # (B,L,L,H)
        num = jnp.einsum("blsh,blsh,bshd->blhd", qk, d_w, vk_) \
            + a_w[..., None] * jnp.einsum("blhd,bhde->blhe", qk_, C)
        den = jnp.einsum("blsh,blsh->blh", qk, d_w) \
            + a_w * jnp.einsum("blhd,bhd->blh", qk_, n)
        hs = num / jnp.maximum(jnp.abs(den),
                               jnp.exp(-m_loc))[..., None]
        # end-of-chunk state
        Fl = F[:, -1, :]                                   # (B,H)
        w_s = Fl[:, None, :] - F + ik_                     # (B,L,H)
        m_new = jnp.maximum(Fl + m, w_s.max(axis=1))
        s_w = jnp.exp(w_s - m_new[:, None, :])
        C_new = C * jnp.exp(Fl + m - m_new)[:, :, None, None] \
            + jnp.einsum("blh,blhd,blhe->bhde", s_w, kk_, vk_)
        n_new = n * jnp.exp(Fl + m - m_new)[:, :, None] \
            + jnp.einsum("blh,blhd->bhd", s_w, kk_)
        return (C_new, n_new, m_new), hs

    (C, n, m), hs = jax.lax.scan(body, state, (qc, kc, vc, ic, fc))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d_in).astype(u.dtype)
    hs = hs + p["skip"].astype(u.dtype) * x_c
    hs = norm(p["out_norm"], hs, "rmsnorm") * jax.nn.silu(z)
    out = linear(p["down"], hs)
    if return_state:
        return out, (C, n, m), conv_state
    return out


def mlstm_decode(p, u, cfg, state, conv_state):
    """One-token update.  state = (C, n, m)."""
    b = u.shape[0]
    h = cfg.n_heads
    d_in = cfg.xlstm_d_inner
    dh = d_in // h
    C, n, m = state
    q, k, v, i_pre, f_log, x_c, z, conv_state = _mlstm_qkvif(
        p, u, cfg, conv_state)
    qt = q[:, 0].astype(jnp.float32)                       # (B,H,dh)
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    it = i_pre[:, 0]                                       # (B,H)
    ft = f_log[:, 0]
    m_new = jnp.maximum(ft + m, it)
    f_w = jnp.exp(ft + m - m_new)
    i_w = jnp.exp(it - m_new)
    C = C * f_w[..., None, None] + i_w[..., None, None] \
        * kt[..., :, None] * vt[..., None, :]
    n = n * f_w[..., None] + i_w[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, C)
    den = jnp.einsum("bhd,bhd->bh", qt, n)
    hs = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    hs = hs.reshape(b, 1, d_in).astype(u.dtype)
    hs = hs + p["skip"].astype(u.dtype) * x_c
    hs = norm(p["out_norm"], hs, "rmsnorm") * jax.nn.silu(z)
    return linear(p["down"], hs), (C, n, m_new), conv_state


def mlstm_recurrent_ref(p, u, cfg):
    b, s, _ = u.shape
    h, d_in = cfg.n_heads, cfg.xlstm_d_inner
    dh = d_in // h
    state = (jnp.zeros((b, h, dh, dh), jnp.float32),
             jnp.zeros((b, h, dh), jnp.float32),
             jnp.full((b, h), _NEG, jnp.float32))
    conv_state = jnp.zeros((b, cfg.xlstm_d_conv - 1, d_in), u.dtype)
    outs = []
    for t in range(s):
        o, state, conv_state = mlstm_decode(p, u[:, t:t + 1], cfg, state,
                                            conv_state)
        outs.append(o)
    return jnp.concatenate(outs, 1)


# ---------------------------------------------------------------------------
# sLSTM
def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    d_up = int(d * 4 / 3 / 64) * 64 * 2 or 2 * d
    return {
        "w_in": init_linear(ks[0], d, 4 * d, dtype=dtype),   # z i f o
        # block-diagonal recurrence: per head (dh -> 4*dh)
        "r": jax.random.normal(ks[1], (h, dh, 4 * dh), dtype) * (dh ** -0.5),
        "out_norm": init_norm(d, "rmsnorm", dtype),
        "up": init_linear(ks[2], d, d_up, dtype=dtype),
        "down": init_linear(ks[3], d_up // 2, d, dtype=dtype),
    }


def _slstm_recurrence(r, x_in, state):
    """Per-step scan over (B, S, 4d) pre-activations.  Separated so it can
    run under shard_map: the recurrent-weight gradient then psums ONCE at
    the shard_map boundary instead of all-reducing every timestep inside
    the transposed scan (S x n_layers all-reduces of the (H, dh, 4dh)
    partial — 384 GiB/step on xlstm train_4k; EXPERIMENTS.md §Perf)."""
    b = x_in.shape[0]
    h, dh = r.shape[0], r.shape[1]

    def step(carry, xt):
        c, n, m, hp = carry
        rec = jnp.einsum("bhd,hdk->bhk", hp, r)            # (B,H,4dh)
        pre = xt.astype(jnp.float32).reshape(b, h, 4 * dh) + rec
        zt = jnp.tanh(pre[..., 0 * dh:1 * dh])
        it = pre[..., 1 * dh:2 * dh]
        ft = jax.nn.log_sigmoid(pre[..., 2 * dh:3 * dh])
        ot = jax.nn.sigmoid(pre[..., 3 * dh:4 * dh])
        m_new = jnp.maximum(ft + m, it)
        c = c * jnp.exp(ft + m - m_new) + jnp.exp(it - m_new) * zt
        n = n * jnp.exp(ft + m - m_new) + jnp.exp(it - m_new)
        ht = ot * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, ht), ht

    return jax.lax.scan(step, state, jnp.moveaxis(x_in, 1, 0))


def _dp_total(ctx):
    import numpy as np
    return int(np.prod([ctx.mesh.shape[a] for a in ctx.batch_axes_full]))


def slstm_scan(p, u, cfg, *, state=None, return_state: bool = False):
    """u: (B, S, d) -> (B, S, d).  Per-step scan (true recurrence)."""
    from .. import dist
    b, s, d = u.shape
    h = cfg.n_heads
    dh = d // h
    # stream the pre-activations in the compute dtype (the scan reads
    # them once per step; f32 doubled the dominant memory term), gate
    # math upcasts to f32 inside the step
    x_in = linear(p["w_in"], u)                            # (B,S,4d)
    if state is None:
        state = (jnp.zeros((b, h, dh), jnp.float32),       # c
                 jnp.zeros((b, h, dh), jnp.float32),       # n
                 jnp.full((b, h, dh), _NEG, jnp.float32),  # m
                 jnp.zeros((b, h, dh), jnp.float32))       # h_prev

    r = p["r"].astype(jnp.float32)

    ctx = dist.current()
    if ctx is not None and b % _dp_total(ctx) == 0:
        from jax.sharding import PartitionSpec as P
        dp = ctx.batch_axes_full
        bspec = P(dp, None, None)
        st_spec = (bspec,) * 4
        state_f, hs = jax.shard_map(
            _slstm_recurrence, mesh=ctx.mesh,
            in_specs=(P(), bspec, st_spec),
            out_specs=(st_spec, P(None, dp, None, None)),
            check_vma=False)(r, x_in, state)
    else:
        state_f, hs = _slstm_recurrence(r, x_in, state)
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(u.dtype)
    hs = norm(p["out_norm"], hs, "rmsnorm")
    # gated post-MLP (proj factor ~4/3)
    up = linear(p["up"], hs)
    g, v = jnp.split(up, 2, axis=-1)
    out = linear(p["down"], jax.nn.gelu(g, approximate=True) * v)
    if return_state:
        return out, state_f
    return out


def slstm_decode(p, u, cfg, state):
    out, state = slstm_scan(p, u, cfg, state=state, return_state=True)
    return out, state
