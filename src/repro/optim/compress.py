"""Gradient compression: per-tensor int8 quantization with error feedback.

For cross-pod gradient reduction the ICI/DCN link is the scarce resource
(the paper's memory-controller contention, one level up).  int8 + error
feedback cuts the all-reduce payload 4x vs f32 (2x vs bf16) while the
residual buffer keeps the update unbiased over time.  The trainer applies
this on the pod axis only — intra-pod reductions stay full precision.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any


def ef_init(grads) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def compress_int8(g):
    """g (f32/bf16) -> (int8 values, f32 scale).  Symmetric per-tensor."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(grads, ef: ErrorFeedbackState):
    """Returns (quantized tree of (q, scale), new error-feedback state).
    The caller all-reduces the int8 payloads (summing dequantized values),
    and the residual = g - dequant(q) re-enters the next step's gradients.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = compress_int8(corrected)
        residual = corrected - decompress_int8(q, scale)
        return (q, scale), residual

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    qs, rs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    return treedef.unflatten(list(qs)), \
        ErrorFeedbackState(treedef.unflatten(list(rs)))
