"""AdamW with decoupled weight decay, f32 master statistics.

State is a pytree mirroring params (mu, nu) plus a scalar step — sharded
identically to the params by the trainer's sharding rules (ZeRO over the
``data`` axis under the fsdp policy).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: Any
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), gnorm


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        update = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if p.ndim >= 2:          # decay matrices only (norms/bias exempt)
            update = update + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
