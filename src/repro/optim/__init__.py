"""Optimizer substrate: AdamW + schedules + gradient transforms."""
from .adamw import adamw_init, adamw_update, clip_by_global_norm
from .schedule import cosine_schedule
from .compress import compress_int8, decompress_int8, ErrorFeedbackState

__all__ = ["adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "compress_int8", "decompress_int8",
           "ErrorFeedbackState"]
