"""Deterministic synthetic token pipeline with O(1) skip-ahead.

Every batch is a pure function of (seed, step), generated with counter-based
threefry — no state files, no epochs.  Fault-tolerance story: after a
restart at step k the pipeline resumes at step k by construction; no
replayed or skipped samples (the "deterministic data skip-ahead" leg of the
checkpoint/restart design).  Each host generates only its shard
(``host_slice``), so the pipeline scales with the fleet.

The synthetic stream is Zipf-ish over the vocabulary with injected n-gram
structure so losses decrease meaningfully during example training runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1):
        """Tokens for this host's slice of global batch at ``step``."""
        per_host = self.global_batch // host_count
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, host_index)
        k1, k2 = jax.random.split(key)
        # Zipf via inverse-CDF on uniform
        u = jax.random.uniform(k1, (per_host, self.seq_len),
                               minval=1e-6, maxval=1.0)
        ranks = jnp.floor(
            (self.vocab_size ** (1 - self.zipf_a) +
             u * (1 - self.vocab_size ** (1 - self.zipf_a)))
            ** (1 / (1 - self.zipf_a))).astype(jnp.int32)
        tokens = jnp.clip(ranks - 1, 0, self.vocab_size - 1)
        # inject learnable bigram structure: even positions predict odd
        shift = jax.random.randint(k2, (per_host, 1), 1, 17)
        predictable = (tokens[:, ::2] + shift) % self.vocab_size
        tokens = tokens.at[:, 1::2].set(
            predictable[:, :tokens[:, 1::2].shape[1]])
        return {"tokens": tokens}

    def stream(self, start_step: int = 0, **kw):
        step = start_step
        while True:
            yield self.batch_at(step, **kw)
            step += 1


def make_batch_specs(cfg, seq_len: int, global_batch: int):
    """ShapeDtypeStructs for one training batch (dry-run input stand-ins)."""
    out = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len),
                                          jnp.int32)}
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.vision_seq:
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.vision_seq, cfg.d_model), cd)
    if cfg.family == "audio":
        out["enc_frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder_seq, cfg.d_model), cd)
    return out
