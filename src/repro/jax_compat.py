"""Forward-compatibility shims for older jax (this container ships 0.4.x).

The model and launch layers are written against the current jax surface
(``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, positional ``AbstractMesh(shape, names)``).  On a jax
that predates those, installing the shims below keeps the same source
running: the shard_map alias translates ``check_vma`` to the old
``check_rep`` flag, ``AxisType`` becomes an inert enum, and the mesh
constructors accept-and-drop ``axis_types``.  On a current jax every shim
is a no-op, so this module is safe to import unconditionally.
"""
from __future__ import annotations

import enum
import functools

import jax

_installed = False


class _AxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def install() -> None:
    global _installed
    if _installed:
        return
    _installed = True

    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kw):
            # honor either spelling; remaining kwargs are forwarded so
            # unsupported ones fail loudly instead of being dropped
            if check_vma is None:
                check_vma = True if check_rep is None else check_rep
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma,
                              **kw)

        jax.shard_map = shard_map

    import inspect
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    try:
        params = inspect.signature(
            jax.sharding.AbstractMesh.__init__).parameters
    except (TypeError, ValueError):  # pragma: no cover
        params = {}
    if "axis_names" not in params and "shape_tuple" in params:
        _AbstractMesh = jax.sharding.AbstractMesh

        class AbstractMesh(_AbstractMesh):
            """Accepts the modern ``AbstractMesh(shape, names)`` call."""

            def __init__(self, axis_shapes, axis_names=None, *,
                         axis_types=None):
                if axis_names is not None:
                    axis_shapes = tuple(zip(axis_names, axis_shapes))
                super().__init__(tuple(axis_shapes))

        jax.sharding.AbstractMesh = AbstractMesh
