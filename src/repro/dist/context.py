"""The ambient mesh context: which mesh axes carry what.

``MeshContext`` is the one object the model and launch layers consult for
distribution decisions.  It names the axes (data / model / optional pod)
and answers the two derived questions every call site has:

* ``all_data_axes``   — every axis that carries pure data parallelism
  (the pod axis joins it when present);
* ``batch_axes_full`` — the axes a batch dimension may shard over; when
  ``model_in_batch`` is set (recurrent families in train/prefill, where
  per-step tensor parallelism would reshard pathologically) the model
  axis joins the batch too.

A context is installed with :func:`repro.dist.use_mesh` and read back with
:func:`repro.dist.current`; with no context installed every distribution
hook degrades to a local no-op, which is what the single-device tests rely
on.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class MeshContext:
    """An activated mesh plus the axis roles."""
    mesh: object
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    pod_axis: str | None = None
    model_in_batch: bool = False

    def __init__(self, mesh, data_axes=("data",), model_axis="model",
                 pod_axis=None, model_in_batch=False):
        if isinstance(data_axes, str):
            data_axes = (data_axes,)
        object.__setattr__(self, "mesh", mesh)
        object.__setattr__(self, "data_axes", tuple(data_axes))
        object.__setattr__(self, "model_axis", model_axis)
        object.__setattr__(self, "pod_axis", pod_axis)
        object.__setattr__(self, "model_in_batch", bool(model_in_batch))

    # -- axis queries -------------------------------------------------------
    def axis_size(self, name: str | None) -> int:
        if name is None:
            return 1
        return int(self.mesh.shape[name])

    @property
    def all_data_axes(self) -> tuple[str, ...]:
        """Axes carrying data parallelism (pod included when present)."""
        axes = self.data_axes
        if self.pod_axis is not None:
            axes = (self.pod_axis,) + axes
        return axes

    @property
    def batch_axes_full(self) -> tuple[str, ...]:
        """Axes a batch dim may shard over (model joins under
        ``model_in_batch``)."""
        axes = self.all_data_axes
        if self.model_in_batch:
            axes = axes + (self.model_axis,)
        return axes

    def dp_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.all_data_axes)

    def full_batch_size(self) -> int:
        return math.prod(self.axis_size(a) for a in self.batch_axes_full)
