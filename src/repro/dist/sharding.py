"""Sharding rules: pytree -> NamedSharding trees for params, batches and
KV caches.

The rules are deliberately *divisibility-guarded*: a dimension is only
assigned to a mesh axis when the axis size divides it, so any config can
be lowered on any mesh shape without per-architecture special cases (the
qwen 20-head configs are the canonical awkward divisor).  Policies:

* ``fsdp`` — 2-D sharding: one dim tensor-parallel over the model axis,
  one dim fully-sharded over the data axes (params + optimizer state).
* ``tp``   — model-axis tensor parallelism only; serving loads (no
  optimizer state to shard) use this so FSDP doesn't all-gather weights
  every layer for nothing.
* ``replicated`` — everything everywhere (tiny configs, tests).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .context import MeshContext

__all__ = ["default_policy", "param_shardings", "batch_shardings",
           "cache_shardings"]

POLICIES = ("fsdp", "tp", "replicated")


def default_policy(cfg) -> str:
    """FSDP everywhere by default; tiny/test configs stay replicated."""
    if getattr(cfg, "d_model", 0) and cfg.d_model < 128:
        return "replicated"
    return "fsdp"


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def _assign(shape, dims, axis_or_axes, size, spec, *, skip=()):
    """Put ``axis_or_axes`` on the largest still-free dim it divides."""
    if size <= 1:
        return None
    for d in sorted(dims, key=lambda d: -shape[d]):
        if d in skip or spec[d] is not None:
            continue
        if shape[d] % size == 0:
            spec[d] = axis_or_axes
            return d
    return None


def param_shardings(cfg, params, ctx: MeshContext, *, policy: str | None = None):
    """NamedSharding tree matching ``params`` leaf-for-leaf."""
    policy = policy or default_policy(cfg)
    if policy not in POLICIES:
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    mesh = ctx.mesh
    data = ctx.all_data_axes
    d_size = _axes_size(mesh, data)
    m_axis = ctx.model_axis
    m_size = int(mesh.shape[m_axis])
    # under model_in_batch the model axis carries batch, not TP: fold it
    # into the FSDP group instead so the weights still spread
    if ctx.model_in_batch:
        data = data + (m_axis,)
        d_size *= m_size
        m_size = 1

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        spec = [None] * len(shape)
        if (policy != "replicated" and len(shape) >= 2
                and jax.numpy.issubdtype(leaf.dtype, jax.numpy.floating)):
            # dim 0 of a >=3-D leaf is the stacked-layer axis: never shard
            # it, scan slices it per step
            skip = {0} if len(shape) >= 3 else set()
            dims = range(len(shape))
            _assign(shape, dims, m_axis, m_size, spec, skip=skip)
            if policy == "fsdp":
                _assign(shape, dims, data, d_size, spec, skip=skip)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, params)


def batch_shardings(cfg, batch, ctx: MeshContext):
    """Shard every batch leaf's leading dim over the full batch axes."""
    mesh = ctx.mesh
    axes = ctx.batch_axes_full
    size = _axes_size(mesh, axes)

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        spec = [None] * len(shape)
        if shape and shape[0] % size == 0 and size > 1:
            spec[0] = axes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, batch)


# cache leaves that carry a sequence axis at position -2, by dict key
_SEQ_CACHE_KEYS = ("k", "v", "c_kv", "k_rope")


def cache_shardings(cfg, caches, ctx: MeshContext):
    """KV caches: batch over the data axes, sequence striped over the
    model axis (the runtime's memory-controller striping applied to the
    KV data plane; ``attention._decode_sp`` updates each stripe locally).
    Recurrent states and anything unrecognized stay replicated."""
    mesh = ctx.mesh
    data = ctx.all_data_axes
    d_size = _axes_size(mesh, data)
    m_axis = ctx.model_axis
    m_size = int(mesh.shape[m_axis])
    seq_on_model = m_size > 1 and not ctx.model_in_batch

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        spec = [None] * len(shape)
        key = ""
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        if key in _SEQ_CACHE_KEYS and len(shape) >= 3:
            # (B, H, S, D) per layer or (L, B, H, S, D) stacked; the batch
            # dim sits 3 ranks left of the trailing (S, D) pair for k/v
            # and 2 left for the mla latents
            b_dim = len(shape) - (4 if key in ("k", "v") else 3)
            if b_dim >= 0 and d_size > 1 and shape[b_dim] % d_size == 0:
                spec[b_dim] = data
            if seq_on_model and shape[-2] % m_size == 0:
                spec[-2] = m_axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, caches)
