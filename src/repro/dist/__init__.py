"""``repro.dist`` — mesh context and sharding helpers.

The model code is written against three tiny hooks so it runs unchanged
from a single-device pytest to a multi-pod mesh:

* :func:`use_mesh` / :func:`current` — install / read the ambient
  :class:`~repro.dist.context.MeshContext`;
* :func:`constrain_seq` — pin a (B, S, d) activation's batch dim to the
  batch axes;
* :func:`constrain_heads` — pin a (B, H, S, D) attention tensor's head
  dim to the model axis (tensor parallelism) and batch dim to the data
  axes.

Both constraints are divisibility-guarded no-ops without a mesh, so
importing this module never forces a distribution choice.
"""
from __future__ import annotations

import contextlib
import threading

from .. import jax_compat

jax_compat.install()

import jax  # noqa: E402  (after compat shims)
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from .context import MeshContext  # noqa: E402
from . import sharding  # noqa: E402,F401

__all__ = ["MeshContext", "use_mesh", "current", "constrain_seq",
           "constrain_heads", "sharding", "single_device_mesh"]

_state = threading.local()


def current() -> MeshContext | None:
    """The innermost active mesh context, or None."""
    stack = getattr(_state, "stack", None)
    return stack[-1] if stack else None


def single_device_mesh(axis: str = "data"):
    """A one-device mesh over the default local device.

    This is the smallest mesh the mesh-dependent code paths accept — the
    :class:`~repro.core.sharded.ShardedExecutor`'s shard_map dispatch, the
    sharding rules, ``placement.device_assignment`` — so single-device
    tests and CI exercise the *same* code the real mesh runs, not a
    separate branch.  Install it with :func:`use_mesh`.
    """
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), (axis,))


@contextlib.contextmanager
def use_mesh(mesh, **ctx_kw):
    """Activate ``mesh`` (with axis roles per ``MeshContext``) for the
    dynamic extent of the block; yields the context."""
    ctx = MeshContext(mesh, **ctx_kw)
    stack = getattr(_state, "stack", None)
    if stack is None:
        stack = _state.stack = []
    stack.append(ctx)
    try:
        yield ctx
    finally:
        stack.pop()


def _constrain(x, spec_builder):
    ctx = current()
    if ctx is None:
        return x
    spec = spec_builder(ctx, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def constrain_seq(x):
    """(B, S, d) activations: batch over the full batch axes."""
    def build(ctx, shape):
        if len(shape) < 2:
            return None
        axes = ctx.batch_axes_full
        if shape[0] % ctx.full_batch_size() != 0:
            return None
        return P(axes, *([None] * (len(shape) - 1)))
    return _constrain(x, build)


def constrain_heads(x):
    """(B, H, S, D) attention tensors: heads over the model axis, batch
    over the data axes."""
    def build(ctx, shape):
        if len(shape) != 4 or ctx.model_in_batch:
            return None
        b = ctx.all_data_axes if shape[0] % ctx.dp_size() == 0 else None
        m = ctx.model_axis \
            if shape[1] % ctx.axis_size(ctx.model_axis) == 0 else None
        if b is None and m is None:
            return None
        return P(b, m, None, None)
    return _constrain(x, build)
