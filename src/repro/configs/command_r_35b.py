"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — parallel attn+FFN block, LayerNorm, no bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    parallel_block=True,
    tie_embeddings=True,
    norm="layernorm",
    act="swiglu",
    rope_theta=8e6,
)
