"""zamba2-1.2b [hybrid] — 38 Mamba2 blocks d_model=2048, shared attention
block (32H MHA + d_ff=8192 MLP) every 6 blocks, vocab=32000, ssm_state=64.
[arXiv:2411.15242]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_d_inner=4096,
    ssm_state=64,
    ssm_heads=64,               # headdim 64
    ssm_d_conv=4,
    attn_every=6,
    tie_embeddings=True,
    act="gelu",                 # zamba2 shared MLP uses gelu
    norm="rmsnorm",
    rope_theta=1e4,
)
