"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4H vocab=50304 — mLSTM with
projection factor 2 plus sLSTM every 8th block (7:1).  [arXiv:2405.04517]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                     # blocks are self-contained
    vocab_size=50304,
    xlstm_d_inner=4096,
    xlstm_d_conv=4,
    slstm_every=8,
    tie_embeddings=True,
    norm="rmsnorm",
    rope_type="none",
)
