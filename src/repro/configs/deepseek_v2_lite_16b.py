"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MLA kv_lora=512, 2 shared + 64 routed experts top-6, first layer dense.
[arXiv:2405.04434]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,               # nope 128 + rope 64
    d_ff=1408,
    d_expert=1408,
    vocab_size=102400,
    moe=True,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense=1,
    first_dense_ff=10944,
    moe_renorm=False,           # deepseek scales, does not renormalize
    mla=True,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e4,
)
