"""whisper-tiny [audio] — 4L enc + 4L dec, d_model=384 6H d_ff=1536
vocab=51865 — encoder-decoder; conv frontend stubbed (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    encoder_layers=4,
    encoder_seq=1500,
    is_encoder_decoder=True,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    rope_type="sinusoidal",
)
