"""ModelConfig: one dataclass covering all assigned architecture families,
plus the assigned input-shape suite."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # block structure
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu | relu2
    qkv_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False    # command-r: attn & ffn share the norm
    norm_eps: float = 1e-5

    # positions
    rope_type: str = "rope"         # rope | mrope | sinusoidal | none
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()

    # attention impl knobs
    attn_impl: str = "chunked"
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_dense: int = 0            # leading dense layers (deepseek: 1)
    first_dense_ff: int = 0         # their FFN width
    moe_renorm: bool = True
    moe_capacity_factor: float = 1.25
    moe_impl: str = "ep"            # ep | ref

    # MLA
    mla: bool = False
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM / hybrid (zamba2)
    ssm_d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0             # zamba2: shared attn block period

    # xLSTM
    xlstm_d_inner: int = 0
    xlstm_d_conv: int = 4
    xlstm_chunk: int = 256
    slstm_every: int = 0            # every k-th block is sLSTM

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500

    # VLM stub
    vision_seq: int = 0

    # numerics / staging
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save matmul outputs)
    logit_softcap: float = 0.0
    embed_scale: bool = False       # whisper/gemma style sqrt(d) scaling

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _ceil_to(self.vocab_size, 128)

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("hybrid", "ssm")

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized sibling of this config (same family/topology,
        tiny dims).  Used by per-arch smoke tests on CPU."""
        small = dict(
            n_layers=min(self.n_layers, 4) if not self.attn_every
            else min(self.n_layers, 2 * self.attn_every),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4
                                  // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=512,
        )
        if self.moe:
            # capacity_factor = n_experts -> provably drop-free, so smoke
            # tests can assert exact prefill/decode vs forward equivalence
            small.update(n_experts=min(self.n_experts, 8),
                         top_k=min(self.top_k, 2), d_expert=64,
                         first_dense_ff=min(self.first_dense_ff, 256),
                         moe_capacity_factor=8.0)
        if self.mla:
            small.update(kv_lora_rank=32, rope_head_dim=16,
                         nope_head_dim=32, v_head_dim=32)
        if self.ssm_d_inner:
            small.update(ssm_d_inner=256, ssm_state=16, ssm_heads=8,
                         ssm_chunk=16)
        if self.xlstm_d_inner:
            small.update(xlstm_d_inner=256, xlstm_chunk=16)
        if self.is_encoder_decoder:
            small.update(encoder_layers=2, encoder_seq=64)
        if self.vision_seq:
            small.update(vision_seq=16)
        if self.mrope_sections:
            small.update(mrope_sections=(4, 6, 6))
        # CPU-friendly numerics for smoke tests
        small.update(compute_dtype="float32", attn_q_chunk=64,
                     attn_k_chunk=64)
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """The assignment's skip rules: long_500k only for sub-quadratic
    families; decode shapes for anything with a decoder (all 10 archs)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names
