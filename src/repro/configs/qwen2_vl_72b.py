"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution (patch embeds stubbed).
[arXiv:2409.12191]
"""
from .base import ModelConfig

ARCH = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_type="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    vision_seq=256,             # stub: precomputed patch embeddings
    act="swiglu",
    norm="rmsnorm",
)
