"""Architecture registry: ``get_config("<arch-id>")`` + input_specs.

All 10 assigned architectures (plus the paper's own benchmark suite, see
``paper_suite``) are selectable by id, e.g. ``--arch qwen2-vl-72b``.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from .base import SHAPES, ModelConfig, ShapeSpec, applicable_shapes

_MODULES = {
    "granite-moe-1b-a400m": ".granite_moe_1b_a400m",
    "deepseek-v2-lite-16b": ".deepseek_v2_lite_16b",
    "qwen2-vl-72b": ".qwen2_vl_72b",
    "command-r-35b": ".command_r_35b",
    "qwen1.5-4b": ".qwen15_4b",
    "mistral-nemo-12b": ".mistral_nemo_12b",
    "nemotron-4-15b": ".nemotron_4_15b",
    "zamba2-1.2b": ".zamba2_1p2b",
    "xlstm-1.3b": ".xlstm_1p3b",
    "whisper-tiny": ".whisper_tiny",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    try:
        mod = importlib.import_module(_MODULES[arch_id], __package__)
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; one of {ARCH_IDS}") \
            from None
    return mod.ARCH


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell — weak-
    type-correct, shardable, no device allocation.

    * train/prefill -> {"batch": {"tokens", modality stubs...}}
    * decode        -> {"token", "caches", "pos"}
    """
    from ..models import api

    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    cd = jnp.dtype(cfg.compute_dtype)

    def batch_specs(seq):
        out = {"tokens": jax.ShapeDtypeStruct((b, seq), i32)}
        if cfg.vision_seq:
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_seq, cfg.d_model), cd)
        if cfg.family == "audio":
            out["enc_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), cd)
        return out

    if spec.kind in ("train", "prefill"):
        return {"batch": batch_specs(s)}
    # decode: one new token against a seq_len cache
    caches = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), i32),
    }


__all__ = ["ARCH_IDS", "get_config", "input_specs", "ModelConfig",
           "ShapeSpec", "SHAPES", "applicable_shapes"]
