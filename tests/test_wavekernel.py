"""The pallas wave-kernel backend: eligibility edges, fallback
accounting, bit-identity against the staged reference, sim charging.

Every ineligible shape must take the XLA fallback — *named*, counted in
``RuntimeStats.kernel_fallbacks``, tagged on the ``kernel_dispatch``
event — and produce numerics identical to the staged path, because the
fallback *is* the staged path.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro import dist
from repro.core import RuntimeConfig, TaskRuntime, task
from repro.core import wavekernel
from repro.core.blocks import FootprintSpec
from repro.obs import InMemoryTracker


@task(inout="c", in_=("x", "y"))
def _gemm(c, x, y):
    return c + jnp.dot(x, y, preferred_element_type=jnp.float32)


@task(inout="c", in_="a")
def _add(c, a):
    return c + a


@task(inout="c", in_="m")
def _add_int(c, m):
    return c + m.astype(jnp.float32)


@task(inout="v", in_="w")
def _add1d(v, w):
    return v + w


def _gemm_run(backend, n=64, tile=16, tracker=None, executor="staged"):
    rng = np.random.default_rng(7)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    rt = TaskRuntime(RuntimeConfig(executor=executor,
                                   kernel_backend=backend,
                                   tracker=tracker))
    g = n // tile
    with rt.scope():
        A = rt.from_array(a, (tile, tile))
        B = rt.from_array(b, (tile, tile))
        C = rt.zeros((n, n), (tile, tile))
        for k in range(g):
            for i in range(g):
                for j in range(g):
                    _gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        out = np.asarray(C.gather())
    stats = rt.stats()
    rt.shutdown()
    return out, stats


# ---------------------------------------------------------------------------
class TestAcceptance:
    def test_striped_gemm_bit_identical_one_dispatch_per_wave(self):
        """The issue's acceptance bar: on the striped gemm program the
        pallas backend is bit-identical to staged and every eligible wave
        dispatches exactly once (one fused grid per wave)."""
        ref, ref_stats = _gemm_run("xla")
        out, stats = _gemm_run("pallas")
        np.testing.assert_array_equal(out, ref)
        # every wave is one homogeneous group -> one fused dispatch each
        assert stats.kernel_dispatches == stats.waves == ref_stats.waves
        assert stats.kernel_fallbacks == 0
        assert stats.grouped_dispatches == stats.waves

    def test_xla_backend_leaves_kernel_counters_inert(self):
        _, stats = _gemm_run("xla")
        assert stats.kernel_dispatches is None
        assert stats.kernel_fallbacks is None

    def test_jacobi_app_fuses_every_group(self):
        from benchmarks.apps import run_app
        stats = run_app("jacobi", "staged", kernel_backend="pallas")
        assert stats.kernel_fallbacks == 0
        assert stats.kernel_dispatches > 0

    def test_kernel_dispatch_events(self):
        trk = InMemoryTracker()
        _, stats = _gemm_run("pallas", tracker=trk)
        evs = trk.events_of("kernel_dispatch")
        assert len(evs) == stats.kernel_dispatches
        assert all(e.data["backend"] == "pallas" and e.data["reason"] == ""
                   for e in evs)
        assert all(e.data["executor"] == "staged" for e in evs)


# ---------------------------------------------------------------------------
def _edge_run(spawn, backend, tracker=None, **cfg):
    rt = TaskRuntime(RuntimeConfig(executor="staged",
                                   kernel_backend=backend,
                                   tracker=tracker, **cfg))
    with rt.scope():
        arrays = spawn(rt)
        rt.barrier()
        outs = [np.asarray(a.gather()) for a in arrays]
    stats = rt.stats()
    rt.shutdown()
    return outs, stats


def _fallback_reasons(tracker):
    return [e.data["reason"] for e in tracker.events_of("kernel_dispatch")
            if e.data["backend"] == "xla"]


class TestEligibilityEdges:
    """Each ineligible shape: fallback taken (counted + named), numerics
    still match the staged run of the identical program."""

    def _both(self, spawn):
        trk = InMemoryTracker()
        ref, _ = _edge_run(spawn, "xla")
        out, stats = _edge_run(spawn, "pallas", tracker=trk)
        for got, want in zip(out, ref):
            np.testing.assert_array_equal(got, want)
        assert stats.kernel_fallbacks > 0
        return stats, _fallback_reasons(trk)

    def test_single_task_group(self):
        def spawn(rt):
            C = rt.zeros((8, 8), (8, 8))
            A = rt.full((8, 8), (8, 8), 2.0)
            _add(C[0, 0], A[0, 0])
            return [C]

        stats, reasons = self._both(spawn)
        assert reasons == ["single_task"]
        assert stats.kernel_dispatches == 0

    def test_non_rectangular_footprint(self):
        def spawn(rt):
            V = rt.zeros((32,), (8,))
            W = rt.full((32,), (8,), 1.5)
            for i in range(4):
                _add1d(V[i], W[i])
            return [V]

        _, reasons = self._both(spawn)
        assert "non_rectangular" in reasons

    def test_mixed_dtype_wave(self):
        def spawn(rt):
            C = rt.zeros((32, 8), (8, 8))
            M = rt.from_array(np.arange(256, dtype=np.int32).reshape(32, 8),
                              (8, 8))
            for i in range(4):
                _add_int(C[i, 0], M[i, 0])
            return [C]

        _, reasons = self._both(spawn)
        assert "mixed_dtype" in reasons

    def test_grid_dim_overflow(self, monkeypatch):
        monkeypatch.setattr(wavekernel, "MAX_GRID_TASKS", 2)

        def spawn(rt):
            C = rt.zeros((32, 8), (8, 8))
            A = rt.full((32, 8), (8, 8), 3.0)
            for i in range(4):
                _add(C[i, 0], A[i, 0])
            return [C]

        _, reasons = self._both(spawn)
        assert "grid_overflow" in reasons

    def test_ungrouped_waves_fall_back(self):
        def spawn(rt):
            C = rt.zeros((32, 8), (8, 8))
            A = rt.full((32, 8), (8, 8), 1.0)
            for i in range(4):
                _add(C[i, 0], A[i, 0])
            return [C]

        trk = InMemoryTracker()
        ref, _ = _edge_run(spawn, "xla", group_waves=False)
        out, stats = _edge_run(spawn, "pallas", tracker=trk,
                               group_waves=False)
        np.testing.assert_array_equal(out[0], ref[0])
        assert stats.kernel_fallbacks > 0
        assert set(_fallback_reasons(trk)) == {"ungrouped"}

    def test_sharded_under_mesh_names_its_fallback(self):
        """With a live mesh the sharded executor keeps the shard_map
        hybrid (owner-computes would break under a one-device fused
        grid) and names the fallback."""
        rng = np.random.default_rng(9)
        a = rng.standard_normal((64, 64), dtype=np.float32)
        b = rng.standard_normal((64, 64), dtype=np.float32)

        def run(backend, mesh, tracker=None):
            import contextlib
            cm = (dist.use_mesh(dist.single_device_mesh()) if mesh
                  else contextlib.nullcontext())
            with cm:
                rt = TaskRuntime(RuntimeConfig(
                    executor="sharded", kernel_backend=backend,
                    tracker=tracker))
                with rt.scope():
                    A = rt.from_array(a, (16, 16))
                    B = rt.from_array(b, (16, 16))
                    C = rt.zeros((64, 64), (16, 16))
                    for k in range(4):
                        for i in range(4):
                            for j in range(4):
                                _gemm(C[i, j], A[i, k], B[k, j])
                    rt.barrier()
                    out = np.asarray(C.gather())
                stats = rt.stats()
                rt.shutdown()
                return out, stats

        trk = InMemoryTracker()
        ref, _ = run("xla", mesh=True)
        out, stats = run("pallas", mesh=True, tracker=trk)
        np.testing.assert_array_equal(out, ref)
        assert stats.kernel_dispatches == 0
        assert stats.kernel_fallbacks > 0
        assert set(_fallback_reasons(trk)) == {"sharded_mesh"}
        # without a mesh the same program fuses via the staged fallback
        out2, stats2 = run("pallas", mesh=False)
        np.testing.assert_array_equal(out2, ref)
        assert stats2.kernel_dispatches == stats2.waves


# ---------------------------------------------------------------------------
class TestEligibilityUnit:
    def test_footprint_spec(self):
        rt = TaskRuntime(RuntimeConfig(executor="sequential"))
        with rt.scope():
            A = rt.zeros((32, 16), (8, 8))
            spec = A[1:3, 0:2].footprint_spec()
        rt.shutdown()
        assert spec == FootprintSpec((16, 16), "float32", (2, 2))
        assert spec.rank == 2 and spec.n_tiles == 4

    def test_interpret_mode_env_flag(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert wavekernel.interpret_mode() is True

    def test_config_validation(self):
        with pytest.raises(ValueError, match="kernel_backend"):
            RuntimeConfig(kernel_backend="vulkan").validate()
        assert RuntimeConfig(kernel_backend="pallas").validate()

    def test_infer_out_structs_rejects_untraceable_bodies(self):
        import jax

        def bad(x):
            return float(np.asarray(x).sum())    # concretizes the tracer

        with pytest.raises(wavekernel.WaveKernelError):
            wavekernel.infer_out_structs(
                bad, [jax.ShapeDtypeStruct((4, 4), np.float32)], 1, "bad")

    def test_build_wave_kernel_matches_vmap(self):
        import jax

        def body(c, x, s):
            return c + s * x

        n, h = 5, 8
        rng = np.random.default_rng(3)
        C = jnp.asarray(rng.standard_normal((n, h, h)).astype(np.float32))
        X = jnp.asarray(rng.standard_normal((n, h, h)).astype(np.float32))
        S = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        structs = [jax.ShapeDtypeStruct((h, h), np.float32),
                   jax.ShapeDtypeStruct((h, h), np.float32),
                   jax.ShapeDtypeStruct((), np.float32)]
        outs = wavekernel.infer_out_structs(body, structs, 1, "body")
        run = wavekernel.build_wave_kernel(body, n, structs, outs,
                                           interpret=True, label="body")
        want = jax.jit(jax.vmap(body))(C, X, S)
        np.testing.assert_array_equal(np.asarray(run(C, X, S)),
                                      np.asarray(want))


# ---------------------------------------------------------------------------
class TestSimCharging:
    def test_fused_waves_predicted_cheaper(self):
        """The DES charges fused waves on-chip: no per-task L2 flush and
        write-backs at MPB cost, so the pallas prediction undercuts the
        XLA prediction for the same program."""
        from benchmarks.apps import run_app

        xla = run_app("matmul", "sim", kernel_backend="xla")
        pal = run_app("matmul", "sim", kernel_backend="pallas")
        assert xla.kernel_dispatches is None
        assert pal.kernel_dispatches > 0
        assert pal.kernel_fallbacks == 0
        assert pal.predicted_total_s < xla.predicted_total_s

    def test_sim_fallback_prediction_matches_real_split(self):
        """The DES's predicted fuse/fallback split uses the same shared
        eligibility as the real dispatch, so on the same app the counts
        agree (cholesky mixes fused waves with single-task fallbacks)."""
        from benchmarks.apps import run_app

        real = run_app("cholesky", "staged", kernel_backend="pallas")
        sim = run_app("cholesky", "sim", kernel_backend="pallas")
        assert sim.kernel_dispatches == real.kernel_dispatches
        assert sim.kernel_fallbacks == real.kernel_fallbacks
