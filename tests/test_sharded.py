"""Placement -> sharding: the home-aware mesh execution layer.

Covers the properties ISSUE 3 pins down: ``device_assignment`` round-trips
(every home maps to a device; the block-cyclic layout matches
``home_histogram``), owner-computes traffic accounting, the
shard_map/vmap hybrid dispatch under a mesh, the single-device fallback
(no mesh installed at all), and sharded-vs-sequential numerics on the
cholesky and jacobi benchmark apps.
"""
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro import dist
from repro.core import RuntimeConfig, TaskRuntime, task
from repro.core.blocks import BlockArray
from repro.core.placement import (assign_homes, device_assignment,
                                  home_histogram, home_sharding)
from repro.core.sharded import ShardedExecutor, owner_home


@task(inout="c", in_=("a", "b"))
def _gemm(c, a, b):
    return c + a @ b


@task(inout="x")
def _bump(x):
    return x + 1.0


def _gemm_program(rt, a, b, tile=32):
    n = a.shape[0]
    g = n // tile
    with rt.scope():
        A = rt.from_array(a, (tile, tile), name="A")
        B = rt.from_array(b, (tile, tile), name="B")
        C = rt.zeros((n, n), (tile, tile), name="C")
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    _gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        return np.asarray(C.gather())


# ---------------------------------------------------------------------------
class TestDeviceAssignment:
    def test_no_mesh_every_home_maps_to_default_device(self):
        assert dist.current() is None
        devs = device_assignment(4)
        assert len(devs) == 4
        assert all(d is jax.devices()[0] for d in devs)

    def test_block_cyclic_over_mesh_devices(self):
        with dist.use_mesh(dist.single_device_mesh()) as ctx:
            devs = device_assignment(4, ctx)
            mesh_devs = list(np.asarray(ctx.mesh.devices).flat)
            for h, d in enumerate(devs):
                assert d is mesh_devs[h % len(mesh_devs)]

    def test_roundtrip_matches_home_histogram(self):
        """Pushing every home's block count through the assignment must
        conserve blocks: per-device totals sum to the histogram's total,
        and striped homes spread as evenly over devices as over homes."""
        ba = BlockArray((32, 32), (4, 4))          # 64 blocks
        assign_homes(ba, "striped", n_homes=4)
        hist = home_histogram(ba, 4)
        assert hist == [16, 16, 16, 16]
        with dist.use_mesh(dist.single_device_mesh()) as ctx:
            devs = device_assignment(4, ctx)
            per_dev: dict = {}
            for h, d in enumerate(devs):
                per_dev[d] = per_dev.get(d, 0) + hist[h]
        assert sum(per_dev.values()) == sum(hist) == 64
        # block-cyclic: with ndev dividing n_homes, every device carries
        # the same number of blocks
        counts = list(per_dev.values())
        assert max(counts) == min(counts)

    def test_every_block_home_is_assigned(self):
        """Round-trip property: any home assign_homes produced indexes
        into the device map (no orphan homes)."""
        for policy in ("single", "striped", "striped_diag"):
            ba = BlockArray((24, 24), (4, 4))
            assign_homes(ba, policy, n_homes=4)
            devs = device_assignment(4)
            for idx, h in ba.home.items():
                assert devs[h % len(devs)] is not None

    def test_home_sharding_divisibility_guard(self):
        ba = BlockArray((32, 32), (4, 4))          # 64 blocks: divisible
        assert home_sharding(ba) is None           # no mesh -> fallback
        with dist.use_mesh(dist.single_device_mesh()) as ctx:
            s = home_sharding(ba, ctx)
            assert s.mesh is ctx.mesh
            assert tuple(s.spec) == (("data",),)   # block axis sharded


# ---------------------------------------------------------------------------
class TestOwnerComputes:
    def test_owner_is_home_of_first_output_block(self):
        with TaskRuntime(executor="sharded", placement="striped",
                         n_controllers=4) as rt:
            A = rt.zeros((16, 16), (4, 4))         # homes 0..3 striped
            B = rt.zeros((16, 16), (4, 4))
            f = _gemm(A[1, 2], B[0, 0], B[0, 1])   # output block (1, 2)
            assert owner_home(f.descriptor) == A.home[(1, 2)]
            rt.barrier()

    def test_cross_home_bytes_single_placement_is_zero(self):
        """With everything homed on controller 0 (the paper's contended
        baseline) owner-computes never crosses homes."""
        rng = np.random.default_rng(0)
        a = rng.standard_normal((64, 64), dtype=np.float32)
        rt = TaskRuntime(executor="sharded", placement="single")
        _gemm_program(rt, a, a)
        s = rt.stats()
        assert s.cross_home_bytes == 0
        assert s.local_home_bytes > 0

    def test_cross_home_bytes_striped_gemm_exact(self):
        """Striped homes on the gemm task grid: C[i,j] and B[k,j] share
        the owner's home column, A[i,k] crosses whenever k != j — the
        count is exact, like sim.py's per-home contention charge."""
        rng = np.random.default_rng(1)
        a = rng.standard_normal((128, 128), dtype=np.float32)
        rt = TaskRuntime(executor="sharded", placement="striped",
                        n_controllers=4)
        _gemm_program(rt, a, a, tile=32)
        s = rt.stats()
        g, block_bytes = 4, 32 * 32 * 4
        # g^3 tasks; A-read crosses for the g^2 * (g-1) tasks with k != j
        assert s.cross_home_bytes == g * g * (g - 1) * block_bytes
        assert s.local_home_bytes == (3 * g ** 3 - g * g * (g - 1)) \
            * block_bytes

    def test_accounting_identical_with_and_without_mesh(self):
        """Home traffic is a placement-policy quantity: the single-device
        fallback must report the same bytes a mesh run does."""
        rng = np.random.default_rng(2)
        a = rng.standard_normal((64, 64), dtype=np.float32)
        rt1 = TaskRuntime(executor="sharded", placement="striped_diag")
        _gemm_program(rt1, a, a)
        with dist.use_mesh(dist.single_device_mesh()):
            rt2 = TaskRuntime(executor="sharded", placement="striped_diag")
            _gemm_program(rt2, a, a)
        s1, s2 = rt1.stats(), rt2.stats()
        assert s1.cross_home_bytes == s2.cross_home_bytes
        assert s1.local_home_bytes == s2.local_home_bytes


# ---------------------------------------------------------------------------
class TestShardedExecutor:
    def test_registered_in_config(self):
        cfg = RuntimeConfig(executor="sharded").validate()
        rt = TaskRuntime(cfg)
        assert isinstance(rt._exec, ShardedExecutor)
        assert rt._exec.n_homes == cfg.n_controllers

    def test_single_device_fallback_no_mesh(self):
        """No mesh installed: dispatch degrades to the staged path (no
        shard_map), numerics match sequential bit-for-bit, and the stats
        carry the sharded section."""
        rng = np.random.default_rng(3)
        a = rng.standard_normal((128, 128), dtype=np.float32)
        b = rng.standard_normal((128, 128), dtype=np.float32)
        ref = _gemm_program(TaskRuntime(executor="sequential"), a, b)
        rt = TaskRuntime(executor="sharded")
        got = _gemm_program(rt, a, b)
        np.testing.assert_array_equal(ref, got)
        s = rt.stats()
        assert s.sharded_dispatches == 0           # fallback: plain staged
        assert s.grouped_dispatches and s.grouped_dispatches > 0
        assert s.cross_home_bytes is not None

    def test_shard_map_hybrid_under_mesh(self):
        """With a mesh context active every grouped wavefront dispatch
        goes through the shard_map/vmap hybrid, and results still match
        sequential bit-for-bit."""
        rng = np.random.default_rng(4)
        a = rng.standard_normal((128, 128), dtype=np.float32)
        b = rng.standard_normal((128, 128), dtype=np.float32)
        ref = _gemm_program(TaskRuntime(executor="sequential"), a, b)
        with dist.use_mesh(dist.single_device_mesh()):
            rt = TaskRuntime(executor="sharded")
            got = _gemm_program(rt, a, b)
        np.testing.assert_array_equal(ref, got)
        s = rt.stats()
        assert s.sharded_dispatches == s.grouped_dispatches > 0

    def test_firstprivate_values_ride_the_sharded_dispatch(self):
        """Index-parameterized tasks batch through the hybrid with their
        values stacked as sharded operands (the staged grouping reused)."""
        @task(in_="x", out="y", firstprivate="k")
        def affine(x, k, y=None):
            return x * k

        def run(executor, mesh):
            import contextlib
            ctx = dist.use_mesh(dist.single_device_mesh()) if mesh \
                else contextlib.nullcontext()
            with ctx:
                with TaskRuntime(executor=executor) as rt:
                    X = rt.full((16, 16), (4, 4), 1.0)
                    Y = rt.zeros((16, 16), (4, 4))
                    for n, (i, j) in enumerate(
                            (i, j) for i in range(4) for j in range(4)):
                        affine(X[i, j], float(n), Y[i, j])
                    rt.barrier()
                    return np.asarray(Y.gather()), rt.stats()

        ref, _ = run("sequential", mesh=False)
        got, s = run("sharded", mesh=True)
        np.testing.assert_array_equal(ref, got)
        assert s.sharded_dispatches == 1           # one wave, one hybrid

    def test_wait_on_and_futures_still_region_scoped(self):
        """The sharded executor inherits cone-scoped synchronization."""
        with dist.use_mesh(dist.single_device_mesh()):
            with TaskRuntime(executor="sharded") as rt:
                A = rt.zeros((4, 4), (4, 4))
                B = rt.zeros((4, 4), (4, 4))
                f = _bump(A[0, 0])
                g = _bump(B[0, 0])
                assert not (f.done() or g.done())
                np.testing.assert_allclose(np.asarray(f.result()), 1.0)
                assert not g.done(), "unrelated task was forced"


# ---------------------------------------------------------------------------
class TestShardedApps:
    """Sharded-vs-sequential numerics on the paper apps the issue names.
    Each app also self-verifies against its reference kernel inside
    run_app, so these runs assert correctness twice over."""

    @pytest.mark.parametrize("mesh", [False, True])
    def test_cholesky(self, mesh):
        from benchmarks.apps import run_app
        import contextlib
        ctx = dist.use_mesh(dist.single_device_mesh()) if mesh \
            else contextlib.nullcontext()
        with ctx:
            s = run_app("cholesky", "sharded",
                        placement="striped_diag")
        assert s.cross_home_bytes is not None and s.cross_home_bytes > 0
        if mesh:
            assert s.sharded_dispatches and s.sharded_dispatches > 0

    @pytest.mark.parametrize("mesh", [False, True])
    def test_jacobi(self, mesh):
        from benchmarks.apps import run_app
        import contextlib
        ctx = dist.use_mesh(dist.single_device_mesh()) if mesh \
            else contextlib.nullcontext()
        with ctx:
            s = run_app("jacobi", "sharded")
        assert s.cross_home_bytes is not None and s.cross_home_bytes > 0

    def test_cholesky_matches_sequential_gather(self):
        """Beyond the apps' reference checks: the factor the sharded
        executor leaves in memory equals the sequential executor's."""
        from repro.kernels.cholesky import ops as chol_ops

        @task(inout="a")
        def potrf(a):
            return chol_ops.potrf(a)

        @task(in_="l", inout="a")
        def trsm(l, a):
            return chol_ops.trsm(l, a)

        @task(inout="c", in_=("x", "y"))
        def update(c, x, y):
            return chol_ops.update(c, x, y)

        n, tile = 128, 32
        g = n // tile
        rng = np.random.default_rng(5)
        m = rng.standard_normal((n, n)).astype(np.float32)
        spd = m @ m.T + n * np.eye(n, dtype=np.float32)

        def run(executor, mesh=False):
            import contextlib
            ctx = dist.use_mesh(dist.single_device_mesh()) if mesh \
                else contextlib.nullcontext()
            with ctx:
                with TaskRuntime(executor=executor,
                                 placement="striped_diag") as rt:
                    A = rt.from_array(spd, (tile, tile))
                    for k in range(g):
                        potrf(A[k, k])
                        for i in range(k + 1, g):
                            trsm(A[k, k], A[i, k])
                        for i in range(k + 1, g):
                            for j in range(k + 1, i + 1):
                                update(A[i, j], A[i, k], A[j, k])
                    rt.barrier()
                    return np.asarray(A.gather())

        ref = run("sequential")
        np.testing.assert_allclose(run("sharded"), ref,
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(run("sharded", mesh=True), ref,
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_sharded_on_four_devices_matches_sequential():
    """The real thing: 4 host devices (subprocess sets XLA_FLAGS), blocks
    striped over 4 homes -> 4 devices, shard_map hybrid waves, an uneven
    wave hitting the per-owner-device fallback, cross-device multi-block
    materialize, and a mixed-device gather — all bit-identical to
    sequential."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro import dist
from repro.core import TaskRuntime, task

assert jax.device_count() == 4
mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(4), ("data",))

@task(inout="c", in_=("a", "b"))
def gemm(c, a, b):
    return c + a @ b

@task(in_="halo", out="dest")
def avg(halo, dest=None):
    return halo[:4] * 0.5 + halo[4:] * 0.5

rng = np.random.default_rng(0)
a = rng.standard_normal((128, 128), dtype=np.float32)
b = rng.standard_normal((128, 128), dtype=np.float32)

def prog(rt, tile=32):
    g = 128 // tile
    with rt.scope():
        A = rt.from_array(a, (tile, tile)); B = rt.from_array(b, (tile, tile))
        C = rt.zeros((128, 128), (tile, tile))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        return np.asarray(C.gather())

ref = prog(TaskRuntime(executor="sequential"))
with dist.use_mesh(mesh):
    rt = TaskRuntime(executor="sharded", placement="striped", n_controllers=4)
    got = prog(rt)
np.testing.assert_array_equal(ref, got)
s = rt.stats()
assert s.sharded_dispatches > 0, s
assert s.cross_home_bytes > 0, s

# uneven wave (5 % 4 != 0) + multi-block reads spanning owner devices
with dist.use_mesh(mesh):
    with TaskRuntime(executor="sharded", placement="striped",
                     n_controllers=4) as rt:
        X = rt.full((24, 4), (4, 4), 1.0)    # 6 blocks on 4 devices
        Y = rt.zeros((20, 4), (4, 4))
        for i in range(5):
            avg(X[i:i + 2, 0], Y[i, 0])
        rt.barrier()
        assert np.allclose(np.asarray(Y.gather()), 1.0)
print("SHARDED-4DEV-OK")
"""
    out = subprocess.run([sys.executable, "-c", code],
                         cwd=pathlib.Path(__file__).resolve().parent.parent,
                         capture_output=True, text=True, timeout=300)
    assert "SHARDED-4DEV-OK" in out.stdout, out.stderr[-2000:]
