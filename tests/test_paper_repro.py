"""Validation of the paper's findings via the DES + cost model, plus the
master-placement and microbenchmark shapes (Figs 3-7, §4.1-§4.3)."""
import numpy as np
import pytest

from repro.core.costmodel import (SCCParams, core_mc_hops,
                                  master_core_choice, worker_order)
from repro.core.sim import sequential_time, simulate

import sys
sys.path.insert(0, ".")
from benchmarks.workloads import WORKLOADS  # noqa: E402


@pytest.fixture(scope="module")
def params():
    return SCCParams()


def _speedup(name, workers, placement="striped", p=None):
    p = p or SCCParams()
    gen = WORKLOADS[name]
    seq = sequential_time(gen(placement), p)
    r = simulate(gen(placement), workers, p)
    return seq / r.total_s


class TestCostModel:
    def test_fig3_monotone_in_hops(self, params):
        times = [params.mem_time_s(2**20, h) for h in range(10)]
        assert all(b > a for a, b in zip(times, times[1:]))

    def test_fig4_monotone_in_contention(self, params):
        times = [params.mem_time_s(2**20, 9, concurrent=c)
                 for c in range(1, 33)]
        assert all(b > a for a, b in zip(times, times[1:]))
        assert times[-1] / times[0] > 5     # strong effect, per the paper

    def test_master_is_middle_core(self):
        """§4.1: master at a middle core (16-19 on the SCC)."""
        assert master_core_choice() in (16, 17, 18, 19, 28, 29, 30, 31)

    def test_workers_sorted_by_distance(self):
        m = master_core_choice()
        order = worker_order(m)
        d = [abs(core_mc_hops(c, 0) - core_mc_hops(m, 0)) for c in order]
        from repro.core.costmodel import core_core_hops
        hops = [core_core_hops(m, c) for c in order]
        assert hops == sorted(hops)
        assert len(order) == 47


class TestScalability:
    """Fig 5: the shape of each application's scaling curve."""

    def test_blackscholes_near_linear(self):
        s43 = _speedup("black_scholes", 43)
        s16 = _speedup("black_scholes", 16)
        assert 10 <= s43 <= 25               # paper: ~16x
        assert s43 > s16                     # still climbing at 43

    def test_matmul_scales_best(self):
        s43 = _speedup("matmul", 43)
        assert 25 <= s43 <= 40               # paper: ~33x
        for other in ("black_scholes", "fft", "jacobi", "cholesky"):
            assert s43 > _speedup(other, 43)

    def test_fft_saturates_early(self):
        s16 = _speedup("fft", 16)
        s43 = _speedup("fft", 43)
        assert s43 <= s16 * 1.25             # paper: flat past 16 workers

    def test_jacobi_contention_limited(self):
        s22 = _speedup("jacobi", 22)
        s43 = _speedup("jacobi", 43)
        assert s43 <= s22 * 1.4              # paper: max ~22 workers

    def test_striping_beats_single_controller(self):
        """§4.2: distributing data across all four MCs is the fix."""
        for name in ("fft", "jacobi"):
            assert _speedup(name, 43, "single") < \
                0.7 * _speedup(name, 43, "striped")

    def test_single_worker_overhead_bounded(self):
        # parallel runtime on one worker pays flush + scheduling only
        s1 = _speedup("matmul", 1)
        assert 0.5 < s1 <= 1.05


class TestBreakdowns:
    """Figs 6-7: idle/app/flush decomposition and load balance."""

    def test_contention_grows_app_time(self):
        p = SCCParams()
        gen = WORKLOADS["jacobi"]
        r8 = simulate(gen("striped"), 8, p)
        r43 = simulate(gen("striped"), 43, p)
        # same total work, more expensive accesses (Fig 6d)
        assert sum(r43.worker_busy_s) > 1.15 * sum(r8.worker_busy_s)

    def test_flush_constant_per_task(self):
        p = SCCParams()
        gen = WORKLOADS["black_scholes"]
        r8 = simulate(gen("striped"), 8, p)
        r43 = simulate(gen("striped"), 43, p)
        assert sum(r43.worker_flush_s) == pytest.approx(
            sum(r8.worker_flush_s), rel=0.01)   # flushes = #tasks

    def test_bs_mm_balanced_at_43(self):
        p = SCCParams()
        for name in ("black_scholes", "matmul"):
            r = simulate(WORKLOADS[name]("striped"), 43, p)
            busy = np.array(r.worker_busy_s)
            assert busy.std() / busy.mean() < 0.2, name

    def test_master_bottleneck_idles_workers(self):
        """Fine granularity -> master cannot feed 43 workers (§4.3)."""
        from benchmarks.workloads import matmul
        p = SCCParams()
        r = simulate(matmul("striped", tile=16), 43, p)
        tot = (sum(r.worker_idle_s) + sum(r.worker_busy_s)
               + sum(r.worker_flush_s))
        assert sum(r.worker_idle_s) / tot > 0.4


class TestWorkloads:
    def test_sizes_match_paper(self):
        assert len(WORKLOADS["black_scholes"]("striped")) == 2_000_000 // 512
        assert len(WORKLOADS["matmul"]("striped")) == 16 ** 3
        assert len(WORKLOADS["jacobi"]("striped")) == 8 * 8 * 16
        g = 16
        n_chol = g + g * (g - 1) // 2 + sum(
            (g - k - 1) * (g - k) // 2 for k in range(g))
        assert len(WORKLOADS["cholesky"]("striped")) == n_chol

    def test_graphs_are_dags(self):
        for name, gen in WORKLOADS.items():
            tasks = gen("striped")
            ids = {t.tid for t in tasks}
            for t in tasks:
                for d in t.deps:
                    assert d in ids and d < t.tid, name
