"""Minimal stand-in for ``hypothesis`` when it isn't installed.

Implements just the surface this suite uses — ``given``/``settings`` and
the ``integers``/``floats``/``tuples``/``lists``/``sampled_from``
strategies — as a deterministic seeded-random example generator.  The
real hypothesis is preferred whenever importable (see ``conftest.py``);
this keeps the property tests running in hermetic containers without
turning them into no-ops.
"""
from __future__ import annotations

import random
import sys
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def tuples(*strategies):
    return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))


def lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10
    return _Strategy(
        lambda r: [elements.draw(r) for _ in range(r.randint(min_size, hi))])


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda r: r.choice(options))


_DEFAULT_EXAMPLES = 20


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kw):
    def deco(fn):
        # NOT functools.wraps: pytest must not see the strategy params in
        # the signature, or it would treat them as fixtures
        def run(*args, **kw):
            n = getattr(run, "_stub_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(1234)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                fn(*args, **kw, **drawn)
        run.__name__ = fn.__name__
        run.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        run.__doc__ = fn.__doc__
        return run
    return deco


def install() -> None:
    """Register this stub as ``hypothesis`` + ``hypothesis.strategies``.

    Refuses to install when the *real* hypothesis is importable: the stub
    exists only for hermetic containers, and silently shadowing the real
    package would downgrade the property tests' example generation on CI
    without anyone noticing (``conftest.py`` asserts this never happens).
    Stub modules carry ``IS_REPRO_STUB = True`` so any test can tell which
    implementation is active."""
    import importlib.util
    if importlib.util.find_spec("hypothesis") is not None:
        raise RuntimeError(
            "refusing to install the hypothesis stub: the real hypothesis "
            "package is importable and must take precedence")
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.IS_REPRO_STUB = True
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "tuples", "lists", "sampled_from"):
        setattr(st, name, globals()[name])
    st.IS_REPRO_STUB = True
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
