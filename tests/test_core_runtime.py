"""Core runtime behaviour: dependence analysis, MPB protocol, executors.

The central property is *serial elision*: for any task program, executing
through the dynamic host runtime or the staged wavefront runtime produces
bit-identical results to running the tasks sequentially in program order.
Task programs are built on the declarative ``@task`` front-end
(footprint-declared functions spawned inside a runtime scope); the old
imperative ``rt.spawn(fn, In(...), ...)`` shim is gone — one test below
pins the removal.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import TaskRuntime, task
from repro.core.blocks import BlockArray
from repro.core.graph import DescriptorPool, TaskState
from repro.core.mpb import MPBQueue, SlotState


# ---------------------------------------------------------------------------
# deterministic, order-sensitive task functions (footprint-declared)
@task(inout="prev", in_="x")
def _acc(prev, x):
    return prev * jnp.float32(0.5) + x


@task(in_=("a", "b"), out="o")
def _combine(a, b, o=None):
    return a - jnp.float32(2.0) * b


@task(in_="a", out="o")
def _scale(a, o=None):
    return a * jnp.float32(1.25) + jnp.float32(1.0)


@task(inout="x")
def _fill7(x):
    return jnp.full_like(x, 7.0)


# ---------------------------------------------------------------------------
# unit: blocks / regions
class TestBlocks:
    def test_roundtrip(self):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        ba = BlockArray.from_array(a, (4, 4))
        assert ba.grid == (2, 2)
        np.testing.assert_array_equal(np.asarray(ba.gather()), a)

    def test_region_materialize_store(self):
        a = np.arange(64, dtype=np.float32).reshape(8, 8)
        ba = BlockArray.from_array(a, (4, 4))
        reg = ba[0:2, 1]                      # a 2x1 block column
        assert reg.shape == (8, 4)
        np.testing.assert_array_equal(np.asarray(reg.materialize()),
                                      a[:, 4:8])
        reg.store(jnp.zeros((8, 4), jnp.float32))
        assert np.asarray(ba.gather())[:, 4:8].sum() == 0

    def test_bad_block_shape(self):
        with pytest.raises(ValueError):
            BlockArray((10, 10), (4, 4))

    def test_footprint_ids_unique_per_array(self):
        x = BlockArray((8, 8), (4, 4))
        y = BlockArray((8, 8), (4, 4))
        assert set(x.whole.block_ids).isdisjoint(set(y.whole.block_ids))


# ---------------------------------------------------------------------------
# unit: the MPB SPSC protocol (§3.4-3.5)
class TestMPB:
    def _td(self, pool, i=0):
        return pool.acquire(_scale.fn, (), name=f"t{i}")

    def test_fill_reject_complete_reuse(self):
        pool = DescriptorPool(64)
        q = MPBQueue(0, n_slots=2)
        t0, t1, t2 = (self._td(pool, i) for i in range(3))
        assert q.try_put(t0) == (True, None)
        assert q.try_put(t1) == (True, None)
        ok, col = q.try_put(t2)              # ring full -> reject
        assert not ok and col is None
        assert q.full_rejections == 1
        # worker consumes t0, marks completed; master's next put reclaims it
        w = q.next_ready(timeout=0)
        assert w is t0
        q.mark_completed(t0)
        ok, col = q.try_put(t2)
        assert ok and col is t0

    def test_collect_completed(self):
        pool = DescriptorPool(64)
        q = MPBQueue(0, n_slots=4)
        tds = [self._td(pool, i) for i in range(3)]
        for td in tds:
            q.try_put(td)
        for td in tds:
            assert q.next_ready(timeout=0) is td
            q.mark_completed(td)
        assert q.collect_completed() == tds
        assert q.occupancy() == 0


# ---------------------------------------------------------------------------
# unit: dependence orderings
class TestDependences:
    def _rt(self):
        return TaskRuntime(executor="staged")

    def _edges(self, rt):
        edges = []
        orig = rt.analyzer.analyze

        def wrapped(td):
            deps = orig(td)
            edges.extend((d.tid, td.tid) for d in deps)
            return deps

        rt.analyzer.analyze = wrapped
        return edges

    def test_raw(self):
        rt = self._rt()
        edges = self._edges(rt)
        with rt.scope():
            A = rt.zeros((4, 4), (4, 4))
            t0 = _fill7(A[0, 0])
            t1 = _scale(A[0, 0], A[0, 0])
            assert (t0.tid, t1.tid) in edges
            rt.barrier()
        np.testing.assert_allclose(np.asarray(A.gather()), 7 * 1.25 + 1)

    def test_war_and_waw(self):
        rt = self._rt()
        edges = self._edges(rt)
        with rt.scope():
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((4, 4), (4, 4))
            r = _scale(A[0, 0], B[0, 0])       # reader of A
            w1 = _fill7(A[0, 0])               # WAR on r, WAW later
            w2 = _fill7(A[0, 0])
            assert (r.tid, w1.tid) in edges                # WAR
            assert (w1.tid, w2.tid) in edges               # WAW
            rt.barrier()

    def test_disjoint_footprints_no_deps(self):
        rt = self._rt()
        edges = self._edges(rt)
        with rt.scope():
            A = rt.zeros((8, 8), (4, 4))
            _fill7(A[0, 0])
            _fill7(A[1, 1])
            assert edges == []
            rt.barrier()

    def test_multiblock_region_overlap(self):
        rt = self._rt()
        edges = self._edges(rt)
        with rt.scope():
            A = rt.zeros((8, 8), (4, 4))
            t0 = _fill7(A[0, 0:2])   # row of blocks
            t1 = _fill7(A[0:2, 1])   # column of blocks, overlaps
            assert (t0.tid, t1.tid) in edges
            rt.barrier()


# ---------------------------------------------------------------------------
# descriptor pool exhaustion (§3.3): master blocks until recycling
@pytest.mark.parametrize("kind", ["host", "staged"])
def test_pool_exhaustion_recycles(kind):
    rt = TaskRuntime(executor=kind, n_workers=2, pool_capacity=4,
                     mpb_slots=2)
    with rt.scope():
        A = rt.zeros((4, 4), (4, 4))
        for _ in range(20):
            _scale(A[0, 0], A[0, 0])
        rt.barrier()
    got = np.asarray(A.gather())
    expect = np.zeros((4, 4), np.float32)
    for _ in range(20):
        expect = expect * 0.5 * 0 + expect * 1.25 + 1  # _scale repeatedly
    np.testing.assert_allclose(got, expect, rtol=1e-6)
    rt.shutdown()


# ---------------------------------------------------------------------------
# the deprecated imperative shim is gone (window closed after one PR of
# DeprecationWarning); @task is the only spawn surface
def test_spawn_shim_removed():
    with TaskRuntime(executor="staged") as rt:
        assert not hasattr(rt, "spawn")


# ---------------------------------------------------------------------------
# property: serial elision equivalence on random task programs
def _random_program(rt, ops):
    """Replay a generated op list onto a runtime; return its arrays."""
    with rt.scope():
        A = rt.zeros((12, 12), (4, 4), name="A")
        B = rt.full((12, 12), (4, 4), 1.0, name="B")
        arrays = [A, B]
        for op in ops:
            kind, src_a, si, sj, dst_a, di, dj = op
            src, dst = arrays[src_a], arrays[dst_a]
            if kind == 0:
                _acc(dst[di, dj], src[si, sj])
            elif kind == 1:
                _combine(src[si, sj], dst[di, dj], dst[di, dj])
            elif kind == 2:
                _scale(src[si, sj], dst[di, dj])
            else:
                _fill7(dst[di, dj])
        rt.barrier()
    return [np.asarray(a.gather()) for a in arrays]


_op = st.tuples(st.integers(0, 3), st.integers(0, 1), st.integers(0, 2),
                st.integers(0, 2), st.integers(0, 1), st.integers(0, 2),
                st.integers(0, 2))


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=40))
def test_serial_elision_staged(ops):
    ref = _random_program(TaskRuntime(executor="sequential"), ops)
    got = _random_program(TaskRuntime(executor="staged"), ops)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g)


@settings(max_examples=12, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=25))
def test_serial_elision_host(ops):
    ref = _random_program(TaskRuntime(executor="sequential"), ops)
    rt = TaskRuntime(executor="host", n_workers=3, mpb_slots=2)
    try:
        got = _random_program(rt, ops)
    finally:
        rt.shutdown()
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(r, g)


# ---------------------------------------------------------------------------
# property: execution order respects every discovered dependence edge
@settings(max_examples=15, deadline=None)
@given(ops=st.lists(_op, min_size=2, max_size=40))
def test_execution_respects_dependences(ops):
    rt = TaskRuntime(executor="staged")
    edges = []
    orig = rt.analyzer.analyze
    def wrapped(td):
        deps = orig(td)
        edges.extend((d, td) for d in deps)
        return deps
    rt.analyzer.analyze = wrapped
    _random_program(rt, ops)
    for d, t in edges:
        assert d.exec_order is not None and t.exec_order is not None
        assert d.exec_order < t.exec_order, (d, t)


# ---------------------------------------------------------------------------
# scheduling policies all produce correct results (new @task front-end)
@task(inout="c", in_=("x", "y"))
def _gemm_task(c, x, y):
    return c + x @ y


@pytest.mark.parametrize("policy", ["round_robin", "locality", "random"])
def test_policies(policy):
    rng = np.random.default_rng(1)
    a = rng.standard_normal((64, 64), dtype=np.float32)
    b = rng.standard_normal((64, 64), dtype=np.float32)

    with TaskRuntime(executor="host", n_workers=3, mpb_slots=2,
                     policy=policy) as rt:
        A = rt.from_array(a, (16, 16))
        B = rt.from_array(b, (16, 16))
        C = rt.zeros((64, 64), (16, 16))
        g = 4
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    _gemm_task(C[i, j], A[i, k], B[k, j])
        rt.barrier()
    np.testing.assert_allclose(np.asarray(C.gather()), a @ b,
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# placement
def test_placement_striped_balanced():
    from repro.core.placement import home_histogram
    rt = TaskRuntime(executor="sequential", placement="striped",
                     n_controllers=4)
    A = rt.zeros((32, 32), (4, 4))     # 64 blocks
    hist = home_histogram(A, 4)
    assert hist == [16, 16, 16, 16]


def test_placement_single_contended():
    from repro.core.placement import home_histogram
    rt = TaskRuntime(executor="sequential", placement="single")
    A = rt.zeros((32, 32), (4, 4))
    assert home_histogram(A, 4) == [64, 0, 0, 0]
