"""The OmpSs-style front-end: @task footprint binding, futures forcing
only their dependence cone, region-scoped waits vs concurrent writers,
and the InOut/WAR dependence edge cases the decorator leans on."""
import threading

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (In, InOut, RuntimeConfig, RuntimeStats,
                        TaskFuture, TaskRuntime, current_runtime, task)
from repro.core.executor import dependence_cone


@task(inout="x")
def _bump(x):
    return x + 1.0


@task(in_="a", out="b")
def _copy2x(a, b=None):
    return a * 2.0


@task(inout="c", in_=("a", "b"))
def _gemm(c, a, b):
    return c + a @ b


# ---------------------------------------------------------------------------
class TestTaskDecorator:
    def test_footprint_binding_order_and_modes(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((4, 4), (4, 4))
            C = rt.zeros((4, 4), (4, 4))
            f = _gemm(C[0, 0], A[0, 0], B[0, 0])
            td = f.descriptor
            # args in parameter order with the declared modes
            assert [type(m).__name__ for m in td.args] == \
                ["InOut", "In", "In"]
            assert td.args[0].region.array is C
            assert td.args[1].region.array is A
            assert td.args[2].region.array is B

    def test_kwargs_and_blockarray_whole(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.full((4, 4), (4, 4), 2.0)
            B = rt.zeros((4, 4), (4, 4))
            f = _copy2x(b=B, a=A)       # kwargs + whole-array regions
            np.testing.assert_allclose(np.asarray(f.result()), 4.0)

    def test_eager_outside_scope(self):
        assert current_runtime() is None
        out = _copy2x(jnp.ones((2, 2)))     # plain array -> runs eagerly
        assert float(out[0, 0]) == 2.0

    def test_region_args_without_scope_is_pointed_error(self):
        rt = TaskRuntime(executor="staged")    # no `with rt:` (old idiom)
        A = rt.zeros((4, 4), (4, 4))
        with pytest.raises(RuntimeError, match="no active runtime scope"):
            _bump(A[0, 0])

    def test_staged_release_does_not_leak_into_ready_queue(self):
        """A dependent that already executed in a later wave must not
        re-enter the ready queue at release (it would pin its descriptor
        and captured outputs forever)."""
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            for _ in range(50):                 # one 50-deep chain
                _bump(A[0, 0])
            rt.barrier()
            assert not rt.graph.ready, \
                f"{len(rt.graph.ready)} released descriptors leaked"
            np.testing.assert_allclose(
                np.asarray(A[0, 0].materialize()), 50.0)

    @pytest.mark.parametrize("kind", ["sequential", "host", "staged"])
    def test_task_bodies_run_eagerly_in_all_executors(self, kind):
        """A task body calling another @task function must not spawn
        recursively: worker threads see no ambient scope, and the
        master-thread executors (sequential/staged) mask it while the
        body runs — same program, same behavior, every executor."""
        seen = {}

        @task(inout="x")
        def outer(x):
            seen["inner"] = current_runtime()
            return _bump(x)          # nested call: must run eagerly

        with TaskRuntime(executor=kind, n_workers=2) as rt:
            A = rt.zeros((4, 4), (4, 4))
            out = outer(A[0, 0]).result()
        assert seen["inner"] is None
        np.testing.assert_allclose(np.asarray(out), 1.0)

    def test_declaration_errors(self):
        with pytest.raises(ValueError, match="more than one footprint"):
            task(in_="a", inout="a")(lambda a: a)
        with pytest.raises(ValueError, match="no parameter named"):
            task(inout="zz")(lambda a: a)
        with pytest.raises(ValueError, match="needs a footprint"):
            task(inout="a")(lambda a, b: a)
        with pytest.raises(ValueError, match="out/inout"):
            task(in_="a")(lambda a: a)
        with pytest.raises(ValueError, match="must come first"):
            task(inout="b")(lambda a=1, b=None: a)
        with pytest.raises(ValueError, match="must come first"):
            # out-only param ahead of an in_ param would mis-bind
            task(out="dst", in_="src")(lambda dst, src: src)
        with pytest.raises(ValueError, match="declare a default"):
            # out-only params receive no value -> need a default
            task(in_="a", out="b")(lambda a, b: a)
        with pytest.raises(TypeError, match="footprint declarations"):
            task(lambda a: a)

    def test_spawn_site_errors(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="already declares"):
                _bump(InOut(A[0, 0]))
            with pytest.raises(TypeError, match="expected a Region"):
                _bump(np.ones((4, 4)))

            @task(in_="a", out="b")
            def cap(a, b=None, _k=3):
                return a * _k
            with pytest.raises(TypeError, match="closure captures"):
                cap(A[0, 0], A[0, 0], 5)

    def test_imperative_spawn_is_gone(self):
        """The rt.spawn(fn, In(...), ...) wrapper-arg shim was removed
        after its deprecation window; @task spawns return futures through
        the same initiation path it used to wrap."""
        with TaskRuntime(executor="staged") as rt:
            assert not hasattr(rt, "spawn")
            A = rt.zeros((4, 4), (4, 4))
            assert isinstance(_bump(A[0, 0]), TaskFuture)


# ---------------------------------------------------------------------------
class TestFutures:
    def test_result_forces_only_dependence_cone(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((4, 4), (4, 4))
            f1 = _bump(A[0, 0])
            f2 = _bump(A[0, 0])          # depends on f1
            g1 = _bump(B[0, 0])          # unrelated
            assert not (f1.done() or f2.done() or g1.done())
            out = f2.result()
            assert f1.done() and f2.done()
            assert not g1.done(), "unrelated task was forced"
            np.testing.assert_allclose(np.asarray(out), 2.0)
            # cone of f2 (already complete) is empty now
            assert dependence_cone([f2.descriptor]) == set()
        assert g1.done()                 # scope-exit barrier drained it

    def test_result_values_multiple_outputs(self):
        @task(in_="a", out=("lo", "hi"))
        def split(a, lo=None, hi=None):
            return a - 1.0, a + 1.0

        with TaskRuntime(executor="staged") as rt:
            A = rt.full((4, 4), (4, 4), 5.0)
            L = rt.zeros((4, 4), (4, 4))
            H = rt.zeros((4, 4), (4, 4))
            lo, hi = split(A, L, H).result()
            np.testing.assert_allclose(np.asarray(lo), 4.0)
            np.testing.assert_allclose(np.asarray(hi), 6.0)

    @pytest.mark.parametrize("kind", ["sequential", "host", "staged"])
    def test_future_done_and_result_all_executors(self, kind):
        with TaskRuntime(executor=kind, n_workers=2) as rt:
            A = rt.zeros((4, 4), (4, 4))
            f = _bump(A[0, 0])
            np.testing.assert_allclose(np.asarray(f.result()), 1.0)
            assert f.done()

    @pytest.mark.parametrize("kind", ["sequential", "host", "staged"])
    def test_result_is_task_output_not_current_memory(self, kind):
        """result() returns the value the task itself produced — the
        serial-elision invariant holds even when a later writer has
        already overwritten the region."""
        with TaskRuntime(executor=kind, n_workers=2) as rt:
            A = rt.zeros((4, 4), (4, 4))
            f1 = _bump(A[0, 0])
            f2 = _bump(A[0, 0])
            rt.barrier()                 # both writers done; memory is 2.0
            np.testing.assert_allclose(np.asarray(f1.result()), 1.0)
            np.testing.assert_allclose(np.asarray(f2.result()), 2.0)
            np.testing.assert_allclose(
                np.asarray(A[0, 0].materialize()), 2.0)

    def test_sim_result_refuses_loudly(self):
        """The timing-only executor never computes values; result() must
        say so instead of returning stale memory."""
        with TaskRuntime(executor="sim") as rt:
            A = rt.full((4, 4), (4, 4), 5.0)
            f = _bump(A[0, 0])
            with pytest.raises(RuntimeError, match="timing-only"):
                f.result()
            assert f.done()              # wait() itself is fine

    def test_wait_all(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((8, 8), (4, 4))
            futs = [_bump(A[i, j]) for i in range(2) for j in range(2)]
            vals = rt.wait_all(futs)
            assert all(f.done() for f in futs)
            for v in vals:
                np.testing.assert_allclose(np.asarray(v), 1.0)


# ---------------------------------------------------------------------------
class TestWaitOn:
    def test_wait_on_region_vs_concurrent_writer(self):
        """wait_on(region) must return while an unrelated in-flight
        writer is still executing — deterministically arranged with an
        event-gated task body."""
        started = threading.Event()
        release = threading.Event()

        @task(inout="x")
        def gated(x):
            started.set()
            assert release.wait(timeout=30)
            return x + 1.0

        @task(inout="x")
        def double(x):
            return x * 2.0

        rt = TaskRuntime(executor="host", n_workers=2)
        try:
            with rt.scope():
                A = rt.zeros((4, 4), (4, 4))
                B = rt.full((4, 4), (4, 4), 3.0)
                f_gated = gated(A[0, 0])          # occupies worker 0
                assert started.wait(timeout=30)
                f_fast = double(B[0, 0])          # worker 1
                rt.wait_on(B[0, 0])
                # region-scoped: B's writer done, A's writer still running
                assert f_fast.done()
                assert not f_gated.done(), \
                    "wait_on(B) waited for an unrelated in-flight task"
                np.testing.assert_allclose(
                    np.asarray(B[0, 0].materialize()), 6.0)
                release.set()
                rt.barrier()
                assert f_gated.done()
        finally:
            release.set()
            rt.shutdown()

    def test_wait_on_modes(self):
        """mode="in" waits for writers only; mode="inout" also drains
        readers (the WAR ordering a new writer would need)."""
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((8, 8), (4, 4))
            w = _bump(A[0, 0])
            r = _copy2x(A[0, 0], B[0, 0])      # reader of A after w
            rt.wait_on(A[0, 0], mode="in")
            assert w.done()
            assert not r.done(), "mode='in' must not wait for readers"
            rt.wait_on(A[0, 0], mode="inout")
            assert r.done()

    def test_wait_on_forces_transitive_cone(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((4, 4), (4, 4))
            C = rt.zeros((8, 8), (4, 4))
            _bump(A[0, 0])                       # t1
            _copy2x(A[0, 0], B[0, 0])            # t2: RAW on t1
            unrelated = _bump(C[1, 1])
            rt.wait_on(B[0, 0])
            np.testing.assert_allclose(
                np.asarray(B[0, 0].materialize()), 2.0)
            assert not unrelated.done()

    def test_wait_on_type_errors_and_empty(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="regions"):
                rt.wait_on(In(A[0, 0]))
            with pytest.raises(ValueError, match="mode"):
                rt.wait_on(A[0, 0], mode="rw")
            rt.wait_on(A[0, 0])      # no live tasks: returns immediately
            assert rt.stats().region_waits == 1


# ---------------------------------------------------------------------------
class TestDependenceEdgeCases:
    def _edges(self, rt):
        edges = []
        orig = rt.analyzer.analyze

        def wrapped(td):
            deps = orig(td)
            edges.extend((d, td) for d in deps)
            return deps

        rt.analyzer.analyze = wrapped
        return edges

    def test_inout_no_self_dependency(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            f = _bump(A[0, 0])
            assert f.descriptor not in f.descriptor.preds
            g = _bump(A[0, 0])
            assert g.descriptor.preds == (f.descriptor,)

    def test_repeated_region_in_one_footprint(self):
        """The same region bound to an in_ param and an out param of one
        task == InOut: no self-dep, and later tasks order after it."""
        @task(in_="a", out="b")
        def through(a, b=None):
            return a + 5.0

        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            f = through(A[0, 0], A[0, 0])
            assert f.descriptor.preds == ()
            g = _bump(A[0, 0])
            assert g.descriptor.preds == (f.descriptor,)
            rt.barrier()
            np.testing.assert_allclose(
                np.asarray(A[0, 0].materialize()), 6.0)

    def test_war_readers_cleared_by_writer(self):
        """A write resets the reader set: the *second* writer must order
        after readers-since-the-last-write only, not ancient readers."""
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            B = rt.zeros((8, 8), (4, 4))
            edges = self._edges(rt)
            r1 = _copy2x(A[0, 0], B[0, 0])       # reader before w1
            w1 = _bump(A[0, 0])                  # WAR on r1
            r2 = _copy2x(A[0, 0], B[1, 1])       # reader after w1
            w2 = _bump(A[0, 0])                  # WAR on r2, WAW on w1
            pairs = {(d.tid, t.tid) for d, t in edges}
            assert (r1.tid, w1.tid) in pairs
            assert (w1.tid, w2.tid) in pairs
            assert (r2.tid, w2.tid) in pairs
            assert (r1.tid, w2.tid) not in pairs, \
                "stale reader survived a write"
            rt.barrier()

    def test_deps_released_tasks_do_not_order(self):
        """Completed+released tasks must not show up as dependences."""
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            f = _bump(A[0, 0])
            f.result()                            # executed + released
            g = _bump(A[0, 0])
            assert g.descriptor.preds == ()
            rt.barrier()
            np.testing.assert_allclose(
                np.asarray(A[0, 0].materialize()), 2.0)


# ---------------------------------------------------------------------------
@task(in_="x", out="y", firstprivate=("k", "b"))
def _affine(x, k, b=10.0, y=None):
    return x * k + b


class TestFirstprivate:
    def test_eager_call_outside_scope(self):
        out = _affine(jnp.ones((2, 2)), 3.0, 1.0)
        np.testing.assert_allclose(np.asarray(out), 4.0)
        out = _affine(jnp.ones((2, 2)), 3.0)       # default b=10
        np.testing.assert_allclose(np.asarray(out), 13.0)

    def test_values_in_descriptor_and_default(self):
        with TaskRuntime(executor="sequential") as rt:
            A = rt.full((4, 4), (4, 4), 1.0)
            Y = rt.zeros((4, 4), (4, 4))
            f = _affine(A[0, 0], 2.0, y=Y[0, 0])   # b omitted -> default
            assert f.descriptor.values == (2.0, 10.0)
            np.testing.assert_allclose(np.asarray(f.result()), 12.0)

    def test_kwarg_and_positional_binding(self):
        with TaskRuntime(executor="sequential") as rt:
            A = rt.full((4, 4), (4, 4), 1.0)
            Y = rt.zeros((4, 4), (4, 4))
            f = _affine(b=1.0, x=A[0, 0], y=Y[0, 0], k=5.0)
            assert f.descriptor.values == (5.0, 1.0)
            np.testing.assert_allclose(np.asarray(f.result()), 6.0)

    @pytest.mark.parametrize("kind", ["sequential", "host", "staged"])
    def test_numerics_match_serial_elision(self, kind):
        """Per-task values survive every executor, including the staged
        grouped vmap path, bit-identical to sequential."""
        def run(executor):
            rt = TaskRuntime(executor=executor, n_workers=2)
            try:
                with rt.scope():
                    A = rt.full((8, 8), (4, 4), 1.0)
                    Y = rt.zeros((8, 8), (4, 4))
                    for n, (i, j) in enumerate(
                            (i, j) for i in range(2) for j in range(2)):
                        _affine(A[i, j], float(n + 1), float(n), Y[i, j])
                    rt.barrier()
                return np.asarray(Y.gather())
            finally:
                rt.shutdown()
        np.testing.assert_array_equal(run("sequential"), run(kind))

    def test_grouped_dispatch_per_fn_and_wave(self):
        """Same fn + same shapes + different values = ONE vmap dispatch
        (the batching the paper measures; closures used to break this)."""
        with TaskRuntime(executor="staged", group_waves=True) as rt:
            A = rt.full((8, 8), (4, 4), 1.0)
            Y = rt.zeros((8, 8), (4, 4))
            for n, (i, j) in enumerate(
                    (i, j) for i in range(2) for j in range(2)):
                _affine(A[i, j], float(n), 0.0, Y[i, j])
            rt.barrier()
            s = rt.stats()
            assert s.waves == 1
            assert s.grouped_dispatches == 1, \
                "index-parameterized tasks split into multiple dispatches"

    def test_value_structure_splits_groups(self):
        """Values fold into the grouping signature by *structure* only:
        scalar-k tasks and vector-k tasks cannot share a vmap dispatch,
        but same-structure tasks still do."""
        with TaskRuntime(executor="staged", group_waves=True) as rt:
            A = rt.full((8, 8), (4, 4), 1.0)
            Y = rt.zeros((8, 8), (4, 4))
            _affine(A[0, 0], 2.0, 0.0, Y[0, 0])
            _affine(A[0, 1], 3.0, 0.0, Y[0, 1])
            _affine(A[1, 0], jnp.full((4, 4), 4.0), 0.0, Y[1, 0])
            _affine(A[1, 1], jnp.full((4, 4), 5.0), 0.0, Y[1, 1])
            rt.barrier()
            s = rt.stats()
            assert s.waves == 1
            assert s.grouped_dispatches == 2
            got = np.asarray(Y.gather())
            np.testing.assert_allclose(got[:4, :4], 2.0)
            np.testing.assert_allclose(got[:4, 4:], 3.0)
            np.testing.assert_allclose(got[4:, :4], 4.0)
            np.testing.assert_allclose(got[4:, 4:], 5.0)

    def test_missing_value_without_default_errors(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            Y = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="needs a value"):
                _affine(A[0, 0], y=Y[0, 0])

    def test_region_as_value_errors(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            Y = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="passed by value"):
                _affine(A[0, 0], A[0, 0], y=Y[0, 0])

    def test_scalar_provenance_shares_dispatch(self):
        """A Python float and an np.float32 stage to the same canonical
        dtype, so spawns differing only in scalar provenance must still
        share one grouped dispatch."""
        with TaskRuntime(executor="staged", group_waves=True) as rt:
            A = rt.full((8, 8), (4, 4), 1.0)
            Y = rt.zeros((8, 8), (4, 4))
            _affine(A[0, 0], 2.0, 0.0, Y[0, 0])
            _affine(A[0, 1], np.float32(3.0), np.float32(0.0), Y[0, 1])
            rt.barrier()
            s = rt.stats()
            assert s.grouped_dispatches == 1

    def test_overflowing_int_value_rejected_at_spawn(self):
        """An int that cannot stage to JAX's canonical integer dtype
        fails at the spawn site, not with an OverflowError at barrier."""
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            Y = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="overflows"):
                _affine(A[0, 0], 2 ** 40, 0.0, Y[0, 0])

    @pytest.mark.parametrize("kind", ["sequential", "staged"])
    def test_non_numeric_value_rejected_at_spawn(self, kind):
        """A string flag must fail at the spawn site on *every* executor
        with an error naming the parameter — not deep inside the staged
        executor's jit/vmap tracing at barrier time."""
        with TaskRuntime(executor=kind) as rt:
            A = rt.zeros((4, 4), (4, 4))
            Y = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError,
                               match="'k' must be a numeric"):
                _affine(A[0, 0], "add", 0.0, Y[0, 0])

    @pytest.mark.parametrize("kind", ["sequential", "staged"])
    def test_missing_return_is_clear_arity_error(self, kind):
        """A body that forgets its return statement raises the arity
        RuntimeError (0 values for 1 OUT/INOUT), not an obscure
        AttributeError from storing None.  (Master-thread executors only:
        the host executor surfaces body errors on its worker threads.)"""
        @task(inout="x")
        def forgot_return(x):
            x + 1.0

        rt = TaskRuntime(executor=kind)
        try:
            with rt.scope():                 # no exit barrier: the failed
                A = rt.zeros((4, 4), (4, 4))  # task stays pending
                with pytest.raises(RuntimeError,
                                   match="0 values for 1 OUT/INOUT"):
                    forgot_return(A[0, 0]).wait()
        finally:
            rt.shutdown()

    def test_declaration_errors(self):
        with pytest.raises(ValueError, match="both firstprivate"):
            task(inout="a", firstprivate="a")(lambda a: a)
        with pytest.raises(ValueError, match="declared twice"):
            task(inout="a", firstprivate=("k", "k"))(lambda a, k: a)
        with pytest.raises(ValueError, match="no parameter named"):
            task(inout="a", firstprivate="zz")(lambda a: a)
        with pytest.raises(ValueError, match="must come first"):
            # firstprivate param ahead of the in_/inout params mis-binds
            task(inout="a", firstprivate="k")(lambda k, a: a)
        with pytest.raises(ValueError, match="directly follow"):
            # out-only param between reads and firstprivate mis-binds
            task(in_="a", out="o", firstprivate="k")(
                lambda a, o=None, k=0: a)

    def test_closure_capture_still_rejected_at_spawn(self):
        @task(in_="a", out="o", firstprivate="k")
        def f(a, k, o=None, _cap=3):
            return a * k + _cap

        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            Y = rt.zeros((4, 4), (4, 4))
            with pytest.raises(TypeError, match="closure captures"):
                f(A[0, 0], 2.0, Y[0, 0], 5)


# ---------------------------------------------------------------------------
class TestRuntimeConfig:
    def test_config_object_and_overrides(self):
        cfg = RuntimeConfig(executor="staged", n_workers=7)
        rt = TaskRuntime(cfg)
        assert rt.config.n_workers == 7
        rt2 = TaskRuntime(cfg, n_workers=2, policy="locality")
        assert rt2.config.n_workers == 2
        assert rt2.config.policy == "locality"
        assert cfg.n_workers == 7           # frozen: overrides copy

    def test_kwargs_compat(self):
        rt = TaskRuntime(executor="sequential", pool_capacity=8)
        assert rt.config.executor == "sequential"
        assert rt.pool.capacity == 8

    def test_validation(self):
        with pytest.raises(ValueError, match="executor"):
            TaskRuntime(executor="gpu")
        with pytest.raises(ValueError, match="policy"):
            TaskRuntime(policy="fifo")
        with pytest.raises(ValueError, match="n_workers"):
            TaskRuntime(n_workers=0)

    def test_stats_typed(self):
        with TaskRuntime(executor="staged") as rt:
            A = rt.zeros((4, 4), (4, 4))
            _bump(A[0, 0]).result()
            s = rt.stats()
        assert isinstance(s, RuntimeStats)
        assert s.tasks_spawned == 1
        assert s.futures_resolved == 1
        # the dict-style access window is closed: attributes only
        with pytest.raises(TypeError):
            s["deps_found"]
        assert not hasattr(s, "get")
        assert "tasks_spawned" in s.as_dict()
        assert s.waves is not None           # staged executor section


# ---------------------------------------------------------------------------
class TestSimExecutor:
    def test_sim_predicts_without_executing(self):
        """executor="sim" shares the Executor protocol: same program,
        timing-only DES playback — outputs are NOT computed."""
        with TaskRuntime(executor="sim", n_workers=8) as rt:
            A = rt.full((16, 16), (4, 4), 1.0)
            for i in range(4):
                for j in range(4):
                    _bump(A[i, j])
            rt.barrier()
            s = rt.stats()
            assert s.predicted_total_s is not None
            assert s.predicted_total_s > 0
            res = rt._exec.last_result
            assert res.tasks == 16
            assert sum(res.worker_tasks) == 16
        # timing-only: data untouched
        np.testing.assert_allclose(np.asarray(A.gather()), 1.0)

    def test_sim_total_accumulates_across_syncs(self):
        """Mid-program syncs split the simulation into fragments; the
        reported makespan must cover the whole program, not the last
        fragment."""
        def run(syncs):
            with TaskRuntime(executor="sim") as rt:
                A = rt.full((16, 16), (4, 4), 1.0)
                for i in range(4):
                    for j in range(4):
                        _bump(A[i, j])
                    if syncs:
                        rt.barrier()
                rt.barrier()
                return rt.stats().predicted_total_s
        # fragmented prediction >= one-shot (syncs only serialize)
        assert run(True) >= 0.95 * run(False)

    def test_sim_speedup_shape(self):
        """More simulated workers -> shorter predicted makespan for an
        embarrassingly parallel batch."""
        def predict(workers):
            with TaskRuntime(executor="sim", n_workers=workers) as rt:
                A = rt.full((64, 64), (4, 4), 1.0)
                for i in range(16):
                    for j in range(16):
                        _bump(A[i, j])
                rt.barrier()
                return rt.stats().predicted_total_s
        assert predict(16) < predict(1)
