"""repro.serve: streaming sessions, admission control, tile checkpoints.

The serving acceptance bars: (a) the admission controller *provably*
bounds in-flight footprint bytes — pinned across a 10^3-request stream;
(b) the admission ledger closes (submitted == admitted + rejected once
the session drains); (c) checkpoint/restore of shared BlockArray state
is bit-identical across a simulated runtime restart; (d) every decision
surfaces through ``repro.obs`` events and the ``admission_*`` stats.
"""
import time

import numpy as np
import pytest

from repro import RuntimeConfig, task
from repro.obs.tracker import InMemoryTracker
from repro.serve import (AdmissionController, RequestRejected, ServeConfig,
                         Session, footprint_nbytes)
from repro.serve.admission import ADMIT, DEFER, REJECT

TILE = (4, 8)
TILE_BYTES = 4 * 8 * 4          # float32
ROW_BYTES = 8 * 4
REQ_BYTES = TILE_BYTES + ROW_BYTES


@task(in_="src", out="dest")
def _double(src, dest=None):
    return (src * 2.0)[:1]      # (4, 8) tile -> (1, 8) output row


@task(inout="x")
def _bump(x):
    return x + 1.0


def _session(budget_requests=4, **kw):
    kw.setdefault("on_saturation", "queue")
    serve = ServeConfig(budget_bytes=budget_requests * REQ_BYTES, **kw)
    return Session(RuntimeConfig(executor="staged"), serve)


def _arrays(s, n_tiles=8, n_slots=8):
    kv = s.from_array(
        np.arange(n_tiles * 4 * 8, dtype=np.float32).reshape(n_tiles * 4, 8),
        TILE, name="kv")
    out = s.zeros((n_slots, 8), (1, 8), name="out", state=False)
    return kv, out


def _req(s, kv, out, i, n_tiles=8, n_slots=8):
    src, dst = kv[i % n_tiles, 0], out[i % n_slots, 0]
    return s.submit(lambda: _double(src, dst), src, dst)


# ---------------------------------------------------------------------------
class TestFootprint:
    def test_counts_distinct_tiles_once(self):
        with Session(RuntimeConfig(executor="staged")) as s:
            kv, out = _arrays(s)
            assert footprint_nbytes([kv[0, 0]]) == TILE_BYTES
            assert footprint_nbytes([kv[0, 0], kv[0, 0]]) == TILE_BYTES
            assert footprint_nbytes([kv[0, 0], kv[1, 0]]) == 2 * TILE_BYTES
            assert footprint_nbytes([kv[0, 0], out[0, 0]]) == REQ_BYTES

    def test_whole_array_and_type_errors(self):
        with Session(RuntimeConfig(executor="staged")) as s:
            kv, _ = _arrays(s)
            assert footprint_nbytes([kv]) == 8 * TILE_BYTES
            with pytest.raises(TypeError, match="Region or BlockArray"):
                footprint_nbytes([np.zeros(3)])


# ---------------------------------------------------------------------------
class TestAdmissionController:
    def test_decisions_and_ledger(self):
        ac = AdmissionController(100, on_saturation="queue")
        assert ac.try_admit("a", 60) == ADMIT
        assert ac.try_admit("b", 60) == DEFER          # over budget
        assert ac.try_admit("big", 101) == REJECT      # oversize, always
        ac.release("a", 60)
        assert ac.has_room(60)
        ac.admit_deferred("b", 60)
        assert ac.submitted == 3
        assert ac.admitted == 2 and ac.rejected == 1 and ac.deferred == 1
        assert ac.peak_in_flight_bytes == 60

    def test_reject_policy_sheds_instead_of_queueing(self):
        ac = AdmissionController(100, on_saturation="reject")
        assert ac.try_admit("a", 80) == ADMIT
        assert ac.try_admit("b", 80) == REJECT
        assert ac.admitted + ac.rejected == ac.submitted == 2

    def test_depth_backpressure_defers_until_rings_drain(self):
        depths = {0: 5}
        ac = AdmissionController(1000, on_saturation="queue",
                                 max_home_depth=2,
                                 depths_fn=lambda: depths)
        assert ac.try_admit("a", 10) == DEFER
        assert not ac.has_room(10)
        depths.clear()
        assert ac.try_admit("b", 10) == ADMIT

    def test_validation(self):
        with pytest.raises(ValueError, match="budget_bytes"):
            AdmissionController(0)
        with pytest.raises(ValueError, match="on_saturation"):
            AdmissionController(1, on_saturation="panic")
        with pytest.raises(ValueError, match="max_home_depth"):
            AdmissionController(1, max_home_depth=-1)


# ---------------------------------------------------------------------------
class TestServeConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="budget_bytes"):
            ServeConfig(budget_bytes=0)
        with pytest.raises(ValueError, match="on_saturation"):
            ServeConfig(on_saturation="drop")
        with pytest.raises(ValueError, match="checkpoint_dir"):
            ServeConfig(checkpoint_every=5)

    def test_sim_executor_refused(self):
        with pytest.raises(ValueError, match="sim"):
            Session(RuntimeConfig(executor="sim"))

    def test_runtime_and_config_are_exclusive(self):
        from repro import TaskRuntime
        with TaskRuntime(executor="staged") as rt:
            with pytest.raises(ValueError, match="not both"):
                Session(RuntimeConfig(), runtime=rt)


# ---------------------------------------------------------------------------
class TestSessionStream:
    def test_budget_bounds_thousand_request_stream(self):
        """The tentpole bar: across a 10^3-request stream the in-flight
        footprint never exceeds the byte budget — checked both on the
        controller's peak and on every event the stream emitted."""
        trk = InMemoryTracker()
        budget = 4 * REQ_BYTES
        with Session(RuntimeConfig(executor="staged", tracker=trk),
                     ServeConfig(budget_bytes=budget)) as s:
            kv, out = _arrays(s)
            handles = [_req(s, kv, out, i) for i in range(1000)]
            s.drain()
            st = s.stats()
        assert st.admission_submitted == 1000
        assert st.admission_admitted + st.admission_rejected == 1000
        assert st.admission_rejected == 0          # queueing, not shedding
        assert 0 < st.admission_peak_bytes <= budget
        assert st.admission_budget_bytes == budget
        assert all(h.done() for h in handles)
        # every admit/release event agrees: never over budget
        highwater = [e.data["in_flight_bytes"]
                     for e in trk.events if e.kind.startswith("admission_")]
        assert highwater and max(highwater) <= budget

    def test_results_and_state_are_correct(self):
        with _session() as s:
            kv, out = _arrays(s)
            h = _req(s, kv, out, 2)
            h.wait()
            expect = np.asarray(kv.get_tile((2, 0)))[:1] * 2.0
            np.testing.assert_array_equal(
                np.asarray(out.get_tile((2, 0))), expect)
            assert h.latency_s is not None and h.latency_s >= 0

    def test_reject_policy_sheds_and_result_raises(self):
        with _session(budget_requests=2, on_saturation="reject") as s:
            kv, out = _arrays(s)
            handles = [_req(s, kv, out, i) for i in range(6)]
            states = [h.state for h in handles]
            assert states.count("admitted") == 2
            assert states.count("rejected") == 4
            with pytest.raises(RequestRejected):
                handles[-1].result()
            s.drain()
            st = s.stats()
        assert st.admission_admitted == 2 and st.admission_rejected == 4
        assert st.admission_peak_bytes == 2 * REQ_BYTES

    def test_oversize_request_always_rejected(self):
        with _session(budget_requests=1) as s:
            kv, out = _arrays(s)
            big = s.submit(lambda: _double(kv[0, 0], out[0, 0]),
                           kv[0, 0], kv[1, 0], kv[2, 0], out[0, 0])
            assert big.rejected()
            # the session is not wedged: a fitting request still admits
            ok = _req(s, kv, out, 3)
            assert ok.result() is not None

    def test_deferred_requests_admit_fifo(self):
        with _session(budget_requests=1) as s:
            kv, out = _arrays(s)
            handles = [_req(s, kv, out, i) for i in range(5)]
            assert [h.state for h in handles] == \
                ["admitted"] + ["queued"] * 4
            s.drain()
            done = sorted(handles, key=lambda h: h.done_ts)
        assert [h.name for h in done] == [h.name for h in handles]

    def test_wait_forces_only_the_requests_cone(self):
        with _session() as s:
            kv, out = _arrays(s)
            h1 = _req(s, kv, out, 0)
            h2 = _req(s, kv, out, 1)
            h2.wait()
            assert h2.done() and not h1.done()
            h1.wait()
            assert h1.done()

    def test_poll_retires_under_the_host_executor(self):
        with Session(RuntimeConfig(executor="host", n_workers=2),
                     ServeConfig(budget_bytes=8 * REQ_BYTES)) as s:
            kv, out = _arrays(s)
            handles = [_req(s, kv, out, i) for i in range(8)]
            deadline = time.time() + 30
            while not all(h.done() for h in handles) \
                    and time.time() < deadline:
                s.poll()
                time.sleep(0.001)
            assert all(h.done() for h in handles)

    def test_submit_errors(self):
        s = _session()
        kv, out = _arrays(s)
        with pytest.raises(ValueError, match="non-empty footprint"):
            s.submit(lambda: None)
        s.close()
        with pytest.raises(RuntimeError, match="closed"):
            _req(s, kv, out, 0)

    def test_state_arrays_need_names(self):
        with Session(RuntimeConfig(executor="staged")) as s:
            with pytest.raises(ValueError, match="explicit name"):
                s.zeros((4, 8), TILE)
            s.zeros((4, 8), TILE, name="a")
            with pytest.raises(ValueError, match="already registered"):
                s.zeros((4, 8), TILE, name="a")
            s.zeros((4, 8), TILE, state=False)     # scratch: no name needed

    def test_stats_fields_absent_without_a_session(self):
        from repro import TaskRuntime
        with TaskRuntime(executor="staged") as rt:
            st = rt.stats()
        assert st.admission_submitted is None
        assert st.admission_peak_bytes is None


# ---------------------------------------------------------------------------
class TestCheckpointRestore:
    def _run(self, s, kv, out, n):
        for i in range(n):
            s.submit(lambda: _bump(kv[i % 8, 0]), kv[i % 8, 0])
        s.drain()

    def _tiles(self, ba):
        return {idx: np.asarray(ba.get_tile(idx)).copy()
                for idx in ba.home}

    def test_restart_restores_bit_identical_state(self, tmp_path):
        ckpt = str(tmp_path / "ckpt")
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt)) as s:
            kv, out = _arrays(s)
            self._run(s, kv, out, 13)
            assert s.checkpoint(sync=True) == 1
            self._run(s, kv, out, 7)
            assert s.checkpoint(sync=True) == 2
            expect = self._tiles(kv)
        # close() committed one more (final) epoch of the same state

        # simulated restart: a fresh runtime, blank same-geometry state
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt)) as s2:
            kv2 = s2.zeros((8 * 4, 8), TILE, name="kv")
            assert s2.restore_latest() == 3
            got = self._tiles(kv2)
            assert set(got) == set(expect)
            for idx in expect:
                np.testing.assert_array_equal(got[idx], expect[idx])
                assert got[idx].dtype == expect[idx].dtype
            # serving continues, and the next epoch lands after 3
            self._run(s2, kv2, None, 3)
            assert s2.checkpoint(sync=True) == 4

    def test_async_checkpoint_commits_by_close(self, tmp_path):
        from repro.ckpt import latest_epoch
        ckpt = str(tmp_path / "ckpt")
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt)) as s:
            kv, out = _arrays(s)
            self._run(s, kv, out, 4)
            assert s.checkpoint() == 1          # async: returns at once
        # close() joined the writer and wrote the final epoch
        assert latest_epoch(ckpt) == 2

    def test_auto_checkpoint_every_n_requests(self, tmp_path):
        from repro.ckpt import latest_epoch
        ckpt = str(tmp_path / "ckpt")
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt, checkpoint_every=2,
                                 async_checkpoint=False)) as s:
            kv, out = _arrays(s)
            self._run(s, kv, out, 4)            # 4 completions -> 2 epochs
        assert latest_epoch(ckpt) >= 2

    def test_epoch_layout_on_disk(self, tmp_path):
        ckpt = tmp_path / "ckpt"
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=str(ckpt))) as s:
            _arrays(s)
            s.checkpoint(sync=True)
        epoch = ckpt / "epoch_00000001"
        assert (epoch / "manifest.json").is_file()
        assert (epoch / "_COMMITTED").is_file()
        assert list(epoch.glob("home_*.npz"))

    def test_restore_with_no_checkpoint_is_none(self, tmp_path):
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=str(tmp_path))) as s:
            _arrays(s)
            assert s.restore_latest() is None

    def test_restore_refuses_geometry_mismatch(self, tmp_path):
        ckpt = str(tmp_path / "ckpt")
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt)) as s:
            _arrays(s)
            s.checkpoint(sync=True)
        with Session(RuntimeConfig(executor="staged"),
                     ServeConfig(checkpoint_dir=ckpt)) as s2:
            s2.zeros((8 * 4, 8), (2, 8), name="kv")     # wrong block shape
            with pytest.raises(ValueError):
                s2.restore_latest()

    def test_checkpoint_requires_configuration(self):
        with Session(RuntimeConfig(executor="staged")) as s:
            _arrays(s)
            with pytest.raises(RuntimeError, match="checkpoint_dir"):
                s.checkpoint()
            with pytest.raises(RuntimeError, match="checkpoint_dir"):
                s.restore_latest()


# ---------------------------------------------------------------------------
class TestObservability:
    def test_admission_and_ckpt_events_emitted(self, tmp_path):
        trk = InMemoryTracker()
        with Session(RuntimeConfig(executor="staged", tracker=trk),
                     ServeConfig(budget_bytes=REQ_BYTES,
                                 checkpoint_dir=str(tmp_path))) as s:
            kv, out = _arrays(s)
            handles = [_req(s, kv, out, i) for i in range(3)]
            s.drain()
            s.checkpoint(sync=True)
            s.restore_latest()
        kinds = {e.kind for e in trk.events}
        assert {"admission_admit", "admission_defer", "admission_release",
                "ckpt_save", "ckpt_restore"} <= kinds
        admit = trk.events_of("admission_admit")[0]
        assert admit.data["bytes"] == REQ_BYTES
        save = trk.events_of("ckpt_save")[0]
        assert save.data["epoch"] == 1 and save.data["arrays"] == 1
        assert all(h.done() for h in handles)

    def test_reject_events_carry_the_reason(self):
        trk = InMemoryTracker()
        with Session(RuntimeConfig(executor="staged", tracker=trk),
                     ServeConfig(budget_bytes=REQ_BYTES,
                                 on_saturation="reject")) as s:
            kv, out = _arrays(s)
            _req(s, kv, out, 0)
            _req(s, kv, out, 1)
            s.drain()
        (rej,) = trk.events_of("admission_reject")
        assert rej.data["reason"] == "budget"
