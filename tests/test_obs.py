"""repro.obs (ISSUE 6): the wave-level observability subsystem.

Covers the tentpole's acceptance surface: (a) the event schema is
stable and every emitted event validates against it; (b) event counts
are deterministic per executor on a fixed gemm graph, through both the
in-memory and JSONL sinks; (c) the Chrome-trace exporter produces valid
trace JSON with monotonic timestamps; (d) feeding the tracker's live
queue depth into ``rebalance_owners`` is equivalent to the wave-local
path on unskewed waves, and on a forced-host 2-device mesh the
queue-depth-fed override preserves ``bytes_staged == 0`` and
bit-identical results; (e) a disabled tracker means *zero* emitted
events and no emit calls on the hot path (guarded by a spy, not a wall
clock).  Plus the satellites: host-worker pinned tile caches with
hit/miss counters, the ``RuntimeStats`` to/from-JSON round-trip, the
bench timings block validation, and the console/summary rendering.
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.core import RuntimeConfig, RuntimeStats, TaskRuntime, task
from repro.core.api import STATS_SCHEMA
from repro.core.placement import rebalance_owners
from repro.obs import (EVENT_FIELDS, EVENT_SCHEMA, ConsoleTracker, Event,
                       InMemoryTracker, JsonlTracker, NULL_TRACKER,
                       NullTracker, Tracker, chrome_trace,
                       export_chrome_trace, load_jsonl, make_tracker,
                       mode_latency, slowest_waves, summary_table,
                       trace_span, validate_event, validate_spec)


@task(inout="c", in_=("a", "b"))
def _gemm(c, a, b):
    return c + a @ b


def _gemm_run(executor, tracker, n=64, tile=32, **overrides):
    """The fixed gemm graph every determinism test uses: g=2, so 8 tasks
    in 2 wavefronts of 4 (one group each).  Returns (stats, result)."""
    g = n // tile
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    with TaskRuntime(executor=executor, tracker=tracker,
                     n_workers=2, **overrides) as rt:
        A = rt.from_array(a, (tile, tile))
        B = rt.from_array(b, (tile, tile))
        C = rt.zeros((n, n), (tile, tile))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    _gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        stats = rt.stats()
        out = np.asarray(C.gather())
    return stats, out


# ---------------------------------------------------------------------------
class TestEventSchema:
    def test_schema_version_pinned(self):
        assert EVENT_SCHEMA == "repro-obs/1"

    def test_event_kinds_pinned(self):
        # removing/renaming a kind or a required key is a schema bump:
        # update EVENT_SCHEMA and this pin together
        assert set(EVENT_FIELDS) == {
            "trace_header", "wave_open", "wave_close", "dispatch",
            "kernel_dispatch", "queue_depth", "owner_override",
            "tile_cache", "sim_predict", "dep_msg", "dep_batch",
            "pump_idle", "manager_admit",
            "stats", "admission_admit", "admission_defer",
            "admission_reject", "admission_release",
            "ckpt_save", "ckpt_restore"}
        assert EVENT_FIELDS["admission_admit"] == {
            "request", "bytes", "in_flight_bytes"}
        assert EVENT_FIELDS["admission_reject"] == {
            "request", "bytes", "in_flight_bytes", "reason"}
        assert EVENT_FIELDS["ckpt_save"] == {
            "epoch", "arrays", "tiles", "bytes"}
        assert EVENT_FIELDS["kernel_dispatch"] == {
            "wave", "executor", "fn", "tasks", "backend", "reason"}
        assert EVENT_FIELDS["dep_msg"] == {"manager", "msg", "count"}
        assert EVENT_FIELDS["dep_batch"] == {
            "manager", "direction", "descriptors", "lines"}
        assert EVENT_FIELDS["pump_idle"] == {"manager", "waits"}
        assert EVENT_FIELDS["manager_admit"] == {
            "manager", "task", "deps", "depth"}
        assert EVENT_FIELDS["wave_close"] == {
            "wave", "executor", "tasks", "wall_s", "dispatches",
            "tile_moves", "bytes_moved", "bytes_staged"}
        assert EVENT_FIELDS["dispatch"] == {
            "wave", "executor", "fn", "tasks", "mode", "wall_s"}
        assert EVENT_FIELDS["queue_depth"] == {"channel", "depth"}

    def test_record_round_trip(self):
        ev = Event("dispatch", 0.25, {"wave": 1, "executor": "staged",
                                      "fn": "gemm", "tasks": 4,
                                      "mode": "vmap", "wall_s": 0.01})
        rec = ev.to_record()
        assert rec["kind"] == "dispatch" and rec["ts"] == 0.25
        back = Event.from_record(json.loads(ev.to_json()))
        assert back == ev

    def test_validate_event(self):
        ok = Event("wave_open", 0.0, {"wave": 1, "executor": "staged",
                                      "tasks": 4, "groups": 1})
        assert validate_event(ok) == []
        assert validate_event(Event("nope", 0.0, {}))        # unknown kind
        assert validate_event(Event("wave_open", 0.0, {}))   # missing keys
        assert validate_event(Event("wave_open", -1.0, ok.data))  # neg ts

    def test_every_emitted_event_validates(self):
        trk = InMemoryTracker()
        _gemm_run("staged", trk)
        assert trk.events
        for ev in trk.events:
            assert validate_event(ev) == [], ev


# ---------------------------------------------------------------------------
class TestTrackerSinks:
    def test_specs_and_validate_spec(self):
        for spec in ("none", "off", "memory", "console", "jsonl",
                     "jsonl:some/trace.jsonl"):
            validate_spec(spec)
        with pytest.raises(ValueError, match="tracker spec"):
            validate_spec("bogus")

    def test_make_tracker_ownership(self):
        t, owned = make_tracker(None)
        assert t is NULL_TRACKER and not owned
        t, owned = make_tracker("memory")
        assert isinstance(t, InMemoryTracker) and owned
        mine = InMemoryTracker()
        t, owned = make_tracker(mine)
        assert t is mine and not owned          # caller keeps instances
        with pytest.raises(TypeError):
            make_tracker(42)

    def test_null_tracker_satisfies_protocol(self):
        assert isinstance(NULL_TRACKER, Tracker)
        assert isinstance(InMemoryTracker(), Tracker)
        assert not NULL_TRACKER.enabled
        NULL_TRACKER.emit("wave_open", wave=1)   # all no-ops
        NULL_TRACKER.queue(0, 5)
        assert NULL_TRACKER.queue_depths() == {}

    def test_jsonl_sink_round_trips(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        trk = JsonlTracker(str(path))
        _gemm_run("staged", trk)
        trk.close()
        events = load_jsonl(str(path))
        assert events[0].kind == "trace_header"
        assert events[0].data["schema"] == EVENT_SCHEMA
        assert trk.records_written == len(events)
        # identical timeline shape to the in-memory sink on the same graph
        mem = InMemoryTracker()
        _gemm_run("staged", mem)
        kinds = [e.kind for e in events if e.kind != "trace_header"]
        assert kinds == [e.kind for e in mem.events]

    def test_console_sink_summarizes(self):
        import io
        out = io.StringIO()
        trk = ConsoleTracker(out=out)
        _gemm_run("staged", trk)
        trk.close()
        text = out.getvalue()
        assert "[obs]" in text and "waves" in text
        assert "slowest" in text

    def test_caller_owned_tracker_stays_open(self):
        trk = InMemoryTracker()
        _gemm_run("staged", trk)
        assert not trk._closed            # runtime must not close it
        _gemm_run("staged", trk)          # reusable across runtimes
        assert len(trk.events_of("stats")) == 2

    def test_double_shutdown_emits_once(self):
        trk = InMemoryTracker()
        rt = TaskRuntime(executor="staged", tracker=trk)
        rt.shutdown()
        rt.shutdown()
        assert len(trk.events_of("stats")) == 1


# ---------------------------------------------------------------------------
class TestDeterministicCounts:
    """Fixed gemm graph (8 tasks, 2 waves of 4): event counts are exact."""

    def test_staged_timeline(self):
        trk = InMemoryTracker()
        stats, _ = _gemm_run("staged", trk)
        opens = trk.events_of("wave_open")
        closes = trk.events_of("wave_close")
        assert len(opens) == len(closes) == stats.waves == 2
        assert [e.data["tasks"] for e in opens] == [4, 4]
        assert all(e.data["executor"] == "staged" for e in opens + closes)
        dispatches = trk.events_of("dispatch")
        assert len(dispatches) == 2                 # one group per wave
        assert [e.data["mode"] for e in dispatches] == ["vmap", "vmap"]
        assert sum(e.data["dispatches"] for e in closes) == len(dispatches)
        assert all(e.data["wall_s"] >= 0 for e in closes + dispatches)
        # wave open/close pair up in order, with close after open
        for o, c in zip(opens, closes):
            assert o.data["wave"] == c.data["wave"]
            assert c.ts >= o.ts
        # queue accounting drains back to zero on channel 0
        assert trk.queue_depths() == {0: 0}

    def test_wave_traffic_sums_to_stats(self):
        trk = InMemoryTracker()
        stats, _ = _gemm_run("staged", trk)
        closes = trk.events_of("wave_close")
        assert sum(e.data["bytes_moved"] for e in closes) == \
            stats.bytes_moved
        assert sum(e.data["tile_moves"] for e in closes) == stats.tile_moves
        assert sum(e.data["bytes_staged"] for e in closes) == \
            stats.bytes_staged == 0

    def test_sharded_timeline_single_device(self):
        trk = InMemoryTracker()
        stats, out = _gemm_run("sharded", trk)
        closes = trk.events_of("wave_close")
        assert len(closes) == 2
        assert all(e.data["executor"] == "sharded" for e in closes)
        # per-home queue channels all drain to zero
        depths = trk.queue_depths()
        assert depths and all(d == 0 for d in depths.values())

    def test_host_queue_and_cache_events(self):
        trk = InMemoryTracker()
        stats, _ = _gemm_run("host", trk, worker_cache_tiles=8)
        # every scheduled task enqueues once and collects once
        qd = trk.events_of("queue_depth")
        assert len(qd) == 2 * stats.tasks_scheduled == 16
        assert all(d == 0 for d in trk.queue_depths().values())
        cache = trk.events_of("tile_cache")
        assert len(cache) == 2                      # one per worker
        hits = sum(e.data["hits"] for e in cache)
        misses = sum(e.data["misses"] for e in cache)
        assert hits == sum(stats.worker_cache_hits)
        assert misses == sum(stats.worker_cache_misses)
        # 8 tasks x 3 READS regions = 24 lookups in total
        assert hits + misses == 24
        assert hits > 0                              # A/B tiles repeat

    def test_sequential_emits_stats_only(self):
        trk = InMemoryTracker()
        _gemm_run("sequential", trk)
        assert {e.kind for e in trk.events} == {"stats"}

    def test_sim_predict_event(self):
        trk = InMemoryTracker()
        stats, _ = _gemm_run("sim", trk)
        (ev,) = trk.events_of("sim_predict")
        assert ev.data["tasks"] == 8
        assert ev.data["predicted_s"] == pytest.approx(
            stats.predicted_total_s)
        assert ev.data["predicted_s"] > 0
        assert ev.data["sequential_s"] > 0

    def test_stats_event_round_trips(self):
        trk = InMemoryTracker()
        stats, _ = _gemm_run("staged", trk)
        (ev,) = trk.events_of("stats")
        # the payload is the shutdown-time snapshot (taken after the exit
        # barrier, so wall-clock fields drift past the mid-run copy) in
        # the to_dict schema: it parses, and every deterministic counter
        # matches the stats() the program saw
        got = RuntimeStats.from_dict(ev.data["stats"])
        for f in ("tasks_spawned", "deps_found", "waves",
                  "grouped_dispatches", "tile_moves", "bytes_moved",
                  "bytes_staged", "region_waits", "futures_resolved"):
            assert getattr(got, f) == getattr(stats, f), f


# ---------------------------------------------------------------------------
class TestDisabledTrackerIsFree:
    def test_no_tracker_means_no_emit_calls(self):
        """The zero-overhead guarantee: with the default NULL_TRACKER the
        hot path never even calls emit/queue (every site is guarded by
        ``obs.enabled``) — proven by a spy, not a wall clock."""
        calls = []

        class Spy(NullTracker):            # enabled stays False
            def emit(self, kind, **data):
                calls.append(kind)

            def queue(self, channel, delta):
                calls.append("queue")

        spy = Spy()
        for executor in ("staged", "sharded", "host", "sim", "sequential"):
            _gemm_run(executor, spy)
        assert calls == []

    def test_default_config_has_no_tracker(self):
        assert RuntimeConfig().tracker is None
        rt = TaskRuntime(executor="staged")
        assert rt.obs is NULL_TRACKER
        rt.shutdown()

    def test_config_rejects_bad_tracker(self):
        with pytest.raises(ValueError, match="tracker spec"):
            RuntimeConfig(tracker="bogus").validate()
        with pytest.raises(ValueError, match="tracker"):
            RuntimeConfig(tracker=42).validate()
        with pytest.raises(ValueError, match="worker_cache_tiles"):
            RuntimeConfig(worker_cache_tiles=-1).validate()


# ---------------------------------------------------------------------------
class TestChromeTrace:
    def _events(self):
        trk = InMemoryTracker()
        _gemm_run("staged", trk)
        return trk.events

    def test_chrome_trace_is_valid(self, tmp_path):
        doc = chrome_trace(self._events())
        # valid trace JSON: object format with a traceEvents list
        parsed = json.loads(json.dumps(doc))
        evs = parsed["traceEvents"]
        assert evs
        for e in evs:
            assert e["ph"] in ("X", "C", "i", "M")
            if e["ph"] != "M":
                assert e["ts"] >= 0
        # wave spans and dispatch spans both present, with durations
        spans = [e for e in evs if e["ph"] == "X"]
        assert any(e["name"].startswith("wave ") for e in spans)
        assert any("[staged]" in e["name"] for e in spans)
        assert all(e["dur"] >= 0 for e in spans)
        counters = [e for e in evs if e["ph"] == "C"]
        assert counters and all("depth" in e["args"] for e in counters)

    def test_timestamps_monotonic(self):
        evs = chrome_trace(self._events())["traceEvents"]
        ts = [e["ts"] for e in evs if e["ph"] != "M"]
        assert ts == sorted(ts)

    def test_export_from_jsonl_path(self, tmp_path):
        trace = tmp_path / "t.jsonl"
        trk = JsonlTracker(str(trace))
        _gemm_run("staged", trk)
        trk.close()
        out = tmp_path / "t.json"
        doc = export_chrome_trace(str(trace), str(out))
        assert json.loads(out.read_text())["traceEvents"] == \
            doc["traceEvents"]

    def test_cli_summary_and_chrome(self, tmp_path):
        trace = tmp_path / "t.jsonl"
        trk = JsonlTracker(str(trace))
        _gemm_run("staged", trk)
        trk.close()
        repo = pathlib.Path(__file__).resolve().parent.parent
        env = {**os.environ, "PYTHONPATH": "src"}
        out = subprocess.run(
            [sys.executable, "-m", "repro.obs", "summary", str(trace),
             "--top", "3"],
            capture_output=True, text=True, cwd=repo, timeout=120,
            env=env)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "| wave |" in out.stdout
        chrome_out = tmp_path / "t.json"
        out = subprocess.run(
            [sys.executable, "-m", "repro.obs", "chrome", str(trace),
             "-o", str(chrome_out)],
            capture_output=True, text=True, cwd=repo, timeout=120,
            env=env)
        assert out.returncode == 0, out.stderr[-2000:]
        assert json.loads(chrome_out.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
class TestSummary:
    def test_slowest_waves_orders_by_wall(self):
        evs = [Event("wave_close", float(i),
                     {"wave": i, "executor": "staged", "tasks": 1,
                      "wall_s": w, "dispatches": 1, "tile_moves": 0,
                      "bytes_moved": 0, "bytes_staged": 0})
               for i, w in enumerate([0.1, 0.5, 0.2])]
        top = slowest_waves(evs, top=2)
        assert [e.data["wave"] for e in top] == [1, 2]

    def test_summary_table_shape(self):
        trk = InMemoryTracker()
        _gemm_run("staged", trk)
        table = summary_table(trk.events, top=5)
        assert "**trace**" in table
        assert "| wave | executor |" in table
        assert table.count("\n| ") >= 3       # header sep + 2 wave rows

    def _dispatch(self, mode, wall):
        return Event("dispatch", 0.0,
                     {"wave": 0, "executor": "staged", "fn": "f",
                      "tasks": 1, "mode": mode, "wall_s": wall})

    def test_mode_latency_percentiles(self):
        # 100 jit dispatches at 1..100ms: nearest-rank p50=50ms p99=99ms
        evs = [self._dispatch("jit", i / 1000) for i in range(1, 101)]
        evs.append(self._dispatch("vmap", 0.5))
        hist = mode_latency(evs)
        assert list(hist) == ["jit", "vmap"]      # sorted by mode
        assert hist["jit"]["count"] == 100
        assert hist["jit"]["p50_s"] == pytest.approx(0.050)
        assert hist["jit"]["p99_s"] == pytest.approx(0.099)
        assert hist["vmap"] == {"count": 1, "total_s": 0.5,
                                "p50_s": 0.5, "p99_s": 0.5}

    def test_mode_latency_in_summary_table(self):
        trk = InMemoryTracker()
        _gemm_run("staged", trk)
        table = summary_table(trk.events, top=5)
        assert "| mode | dispatches |" in table
        modes = mode_latency(trk.events)
        assert modes                              # staged run dispatched
        assert sum(h["count"] for h in modes.values()) \
            == len(trk.events_of("dispatch"))

    def test_mode_latency_empty_without_dispatches(self):
        assert mode_latency([]) == {}
        assert "| mode |" not in summary_table([])


# ---------------------------------------------------------------------------
class TestProfilerHook:
    def test_trace_span_disabled_is_nullcontext(self):
        with trace_span("x", False):
            pass

    def test_trace_span_enabled_runs(self):
        # TraceAnnotation works outside an active profiler session
        with trace_span("bddt/test/wave1", True):
            pass

    def test_profile_waves_config_plumbs(self):
        rt = TaskRuntime(executor="staged", profile_waves=True)
        assert rt._exec.profile is True
        rt.shutdown()


# ---------------------------------------------------------------------------
class TestStatsRoundTrip:
    def test_json_round_trip_exact(self):
        stats, _ = _gemm_run("staged", None)
        d = stats.to_dict()
        assert d["schema"] == STATS_SCHEMA
        assert RuntimeStats.from_json(stats.to_json()) == stats

    def test_round_trip_with_worker_fields(self):
        stats, _ = _gemm_run("host", None, worker_cache_tiles=4)
        assert stats.worker_cache_hits is not None
        assert RuntimeStats.from_json(stats.to_json()) == stats

    def test_from_dict_rejects_bad_schema_and_fields(self):
        stats, _ = _gemm_run("sequential", None)
        d = stats.to_dict()
        with pytest.raises(ValueError, match="schema"):
            RuntimeStats.from_dict({**d, "schema": "nope/9"})
        with pytest.raises(ValueError, match="unknown"):
            RuntimeStats.from_dict({**d, "mystery_field": 1})

    def test_report_table_accepts_dicts(self):
        from benchmarks.report import runtime_stats_table
        stats, _ = _gemm_run("staged", None)
        a = runtime_stats_table([("gemm", stats)])
        b = runtime_stats_table([("gemm", stats.to_dict())])
        c = runtime_stats_table([("gemm", stats.to_json())])
        assert a == b == c


# ---------------------------------------------------------------------------
class TestWorkerTileCache:
    def test_cache_disabled_by_default_in_executor(self):
        from repro.core.executor import _Worker
        from repro.core.mpb import MPBQueue
        w = _Worker(0, MPBQueue(0, 4))
        assert w.cache_tiles == 0

    def test_cache_off_means_no_counters(self):
        stats, _ = _gemm_run("host", None, worker_cache_tiles=0)
        assert stats.worker_cache_hits == [0, 0]
        assert stats.worker_cache_misses == [0, 0]

    def test_cache_correct_under_overwrites(self):
        """The gemm InOut region C[i,j] is re-read after every overwrite:
        the cache must miss on changed tiles (object identity) and still
        produce bit-identical results."""
        _, ref_out = _gemm_run("sequential", None)
        stats, out = _gemm_run("host", None, worker_cache_tiles=64)
        np.testing.assert_array_equal(out, ref_out)
        assert sum(stats.worker_cache_hits) > 0

    def test_lru_eviction_bounds_cache(self):
        from collections import OrderedDict
        from repro.core.executor import _Worker
        from repro.core.mpb import MPBQueue
        from repro.core.blocks import BlockArray
        w = _Worker(0, MPBQueue(0, 4), cache_tiles=2)
        ba = BlockArray.from_array(
            np.arange(64, dtype=np.float32).reshape(8, 8), (2, 2))
        regions = [ba[i, j] for i in range(2) for j in range(2)]
        for r in regions:
            w._materialize(r)
        assert len(w._cache) == 2                   # LRU evicted
        assert w.cache_misses == 4 and w.cache_hits == 0
        np.testing.assert_array_equal(
            np.asarray(w._materialize(regions[-1])),
            np.asarray(regions[-1].materialize()))
        assert w.cache_hits == 1


# ---------------------------------------------------------------------------
class TestQueueFedRebalance:
    def test_zero_base_equals_wave_local(self):
        """base_load=None and base_load=zeros are the same decision on
        every wave shape — the equivalence the sharded feedback hinges
        on (an unskewed tracker contributes a balanced base)."""
        waves = [[0, 1, 2, 3], [0, 0, 0, 0], [0, 0, 1, 2, 3, 3, 3, 3],
                 [2], []]
        for owners in waves:
            for thr in (0.0, 1.2, 1.5, 2.0):
                legacy = rebalance_owners(list(owners), 4, thr)
                fed = rebalance_owners(list(owners), 4, thr,
                                       base_load=[0.0] * 4)
                assert legacy == fed, (owners, thr)

    def test_balanced_base_no_extra_spill(self):
        # a uniformly-loaded background shifts every home equally: the
        # skew ratio only moves toward the mean, so an unskewed wave
        # stays unspilled
        owners = [0, 1, 2, 3, 0, 1, 2, 3]
        for base in ([0.0] * 4, [5.0] * 4):
            got, spilled = rebalance_owners(list(owners), 4, 1.5,
                                            base_load=base)
            assert got == owners and spilled == 0

    def test_background_hot_home_stops(self):
        # home 3 is hot purely on background load: nothing of this
        # group's to move, must terminate without spilling
        got, spilled = rebalance_owners([0, 0, 1, 2], 4, 1.1,
                                        base_load=[0, 0, 0, 100])
        assert spilled == 0 and got == [0, 0, 1, 2]

    def test_base_load_validation(self):
        with pytest.raises(ValueError, match="one entry per home"):
            rebalance_owners([0], 4, 1.5, base_load=[1.0, 2.0])
        with pytest.raises(ValueError, match=">= 0"):
            rebalance_owners([0], 4, 1.5, base_load=[1, -1, 0, 0])

    def test_sharded_with_tracker_matches_without(self):
        """Queue-depth-fed rebalance on unskewed waves: identical results
        and overrides with the tracker on or off."""
        s_off, out_off = _gemm_run("sharded", None,
                                   owner_skew_threshold=1.5)
        trk = InMemoryTracker()
        s_on, out_on = _gemm_run("sharded", trk, owner_skew_threshold=1.5)
        np.testing.assert_array_equal(out_off, out_on)
        assert s_on.owner_overrides == s_off.owner_overrides
        assert s_on.bytes_staged == s_off.bytes_staged == 0
        assert s_on.cross_home_bytes == s_off.cross_home_bytes


# ---------------------------------------------------------------------------
def _load_gate():
    import importlib.util
    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "bench_gate_obs", root / "tools" / "bench_gate.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestBenchTimings:
    def test_validate_timings(self):
        gate = _load_gate()
        timings_point = gate.timings_point
        validate_timings = gate.validate_timings
        assert validate_timings({}) == []           # block is optional
        good = {"timings": {"schema": "bddt-scc-timings/1",
                            "suite": "smoke", "suite_wall_s": 1.5,
                            "spawn_us_per_task": 40.0,
                            "staged_wall_s": {"matmul": 0.2}}}
        assert validate_timings(good) == []
        pt = timings_point({**good, "env": {"jax": "x"}})
        assert pt["staged_wall_s"] == {"matmul": 0.2}
        assert pt["env"] == {"jax": "x"}
        bad = json.loads(json.dumps(good))
        bad["timings"]["suite_wall_s"] = float("nan")
        assert validate_timings(bad)
        bad = json.loads(json.dumps(good))
        bad["timings"]["staged_wall_s"] = {}
        assert validate_timings(bad)
        bad = json.loads(json.dumps(good))
        bad["timings"]["schema"] = "nope"
        assert validate_timings(bad)

    def test_gate_appends_timings(self, tmp_path):
        gate_main = _load_gate().main
        doc = {"schema": "bddt-scc-bench/1", "suite": "smoke",
               "wall_s": 1.0, "env": {}, "calibration": {},
               "entries": [{"id": "x", "kind": "app", "info": {},
                            "metrics": {"tasks": 8}}],
               "timings": {"schema": "bddt-scc-timings/1",
                           "suite": "smoke", "suite_wall_s": 1.0,
                           "spawn_us_per_task": 10.0,
                           "staged_wall_s": {"matmul": 0.1}},
               "validation": {"checks": {}, "passed": 0, "total": 0}}
        art = tmp_path / "BENCH.json"
        art.write_text(json.dumps(doc))
        series = tmp_path / "series.jsonl"
        base = tmp_path / "base.json"
        # twice: series is append-only, one JSON line per run
        for _ in range(2):
            rc = gate_main([str(art), "--baseline", str(base),
                            "--append-timings", str(series)])
            assert rc == 0
        lines = series.read_text().strip().splitlines()
        assert len(lines) == 2
        assert json.loads(lines[0])["suite_wall_s"] == 1.0

    def test_run_builds_timings_block(self):
        # the emitter and the gate agree on the timings schema tag
        from benchmarks.run import TIMINGS_SCHEMA
        assert TIMINGS_SCHEMA == _load_gate().TIMINGS_SCHEMA \
            == "bddt-scc-timings/1"


# ---------------------------------------------------------------------------
def test_two_device_wave_timeline():
    """The ISSUE 6 acceptance run: on a forced-host 2-device mesh, one
    staged and one sharded gemm run each emit a complete wave timeline
    through in-memory and JSONL sinks — per-wave tile-move bytes sum to
    ``RuntimeStats.bytes_moved``, the Chrome export is valid, and the
    queue-depth-fed owner override keeps ``bytes_staged == 0`` with
    bit-identical results."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import json
import jax, numpy as np
from repro import dist
from repro.core import TaskRuntime, task
from repro.obs import (InMemoryTracker, JsonlTracker, chrome_trace,
                       load_jsonl, validate_event)

assert jax.device_count() == 2
mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2), ("data",))

@task(inout="c", in_=("a", "b"))
def gemm(c, a, b):
    return c + a @ b

rng = np.random.default_rng(0)
a = rng.standard_normal((128, 128), dtype=np.float32)
b = rng.standard_normal((128, 128), dtype=np.float32)

def prog(executor, tracker, **overrides):
    g = 4
    with TaskRuntime(executor=executor, tracker=tracker,
                     n_controllers=2, **overrides) as rt:
        A = rt.from_array(a, (32, 32)); B = rt.from_array(b, (32, 32))
        C = rt.zeros((128, 128), (32, 32))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        s = rt.stats()
        return np.asarray(C.gather()), s

def check_timeline(trk, stats, executor):
    closes = trk.events_of("wave_close")
    opens = trk.events_of("wave_open")
    assert len(opens) == len(closes) == 4, (executor, len(closes))
    assert all(e.data["executor"] == executor for e in closes)
    assert all(e.data["wall_s"] >= 0 for e in closes)
    assert trk.events_of("dispatch"), executor
    assert trk.events_of("queue_depth"), executor
    assert all(d == 0 for d in trk.queue_depths().values()), executor
    # per-wave measured movement sums exactly to the stats totals
    assert sum(e.data["bytes_moved"] for e in closes) == \
        stats.bytes_moved, executor
    assert sum(e.data["bytes_staged"] for e in closes) == 0, executor
    for ev in trk.events:
        assert validate_event(ev) == [], ev

ref, _ = prog("sequential", None)

trk = InMemoryTracker()
got, s = prog("staged", trk)
np.testing.assert_array_equal(ref, got)
check_timeline(trk, s, "staged")

with dist.use_mesh(mesh):
    trk = InMemoryTracker()
    got, s = prog("sharded", trk)
    np.testing.assert_array_equal(ref, got)
    check_timeline(trk, s, "sharded")
    assert s.bytes_moved > 0            # real cross-device movement
    assert s.bytes_staged == 0

    # JSONL sink on the same program, then the Chrome export of it
    jt = JsonlTracker("obs_trace_test.jsonl")
    got, s = prog("sharded", jt)
    jt.close()
    events = load_jsonl("obs_trace_test.jsonl")
    assert events[0].kind == "trace_header"
    assert sum(e.data["bytes_moved"] for e in events
               if e.kind == "wave_close") == s.bytes_moved
    doc = chrome_trace(events)
    ts = [e["ts"] for e in doc["traceEvents"] if e["ph"] != "M"]
    assert ts and ts == sorted(ts) and min(ts) >= 0
    os.unlink("obs_trace_test.jsonl")

    # queue-depth-fed owner override: unskewed gemm waves place the
    # same with and without the tracker feeding base load
    got_off, s_off = prog("sharded", None, owner_skew_threshold=1.5)
    got_on, s_on = prog("sharded", InMemoryTracker(),
                        owner_skew_threshold=1.5)
    np.testing.assert_array_equal(got_off, got_on)
    np.testing.assert_array_equal(ref, got_on)
    assert s_on.owner_overrides == s_off.owner_overrides
    assert s_on.bytes_staged == s_off.bytes_staged == 0
    assert s_on.bytes_moved == s_off.bytes_moved

print("OBS-2DEV-OK")
"""
    out = subprocess.run([sys.executable, "-c", code],
                         cwd=pathlib.Path(__file__).resolve().parent.parent,
                         capture_output=True, text=True, timeout=300)
    assert "OBS-2DEV-OK" in out.stdout, out.stderr[-3000:]
