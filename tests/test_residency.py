"""Device-resident tile storage (ISSUE 5): block homes are physical.

Covers the tentpole's acceptance surface: (a) after ``from_array`` on a
mesh, every tile is committed to the device ``placement.device_assignment``
maps its home to — on ``dist.single_device_mesh()`` in-process and on a
forced-host 2-device mesh in a subprocess; (b) the *measured* cross-device
bytes (``TileTraffic``, reported as ``RuntimeStats.bytes_moved``) equal the
footprint-predicted ``cross_home_bytes`` on striped gemm when homes and
devices coincide, with ``bytes_staged == 0`` — wave dispatches never stage
operands through a non-home device; (c) sharded-vs-sequential bit-equality
holds with tiles physically distributed.  Plus the memory-layer unit
surface: TileStore swapping, destination-aware ``materialize``/``gather``,
and the contention-aware owner override (``rebalance_owners`` +
``RuntimeConfig.owner_skew_threshold``).
"""
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax

from repro import dist
from repro.core import RuntimeConfig, TaskRuntime, task
from repro.core.blocks import (BlockArray, DeviceTileStore, HostTileStore,
                               TileTraffic, device_of)
from repro.core.placement import (assign_homes, device_assignment,
                                  rebalance_owners)


@task(inout="c", in_=("a", "b"))
def _gemm(c, a, b):
    return c + a @ b


def _gemm_program(rt, a, b, tile=32):
    """Run tiled gemm; returns (result, stats-before-gather)."""
    n = a.shape[0]
    g = n // tile
    with rt.scope():
        A = rt.from_array(a, (tile, tile), name="A")
        B = rt.from_array(b, (tile, tile), name="B")
        C = rt.zeros((n, n), (tile, tile), name="C")
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    _gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        s = rt.stats()
        return np.asarray(C.gather()), s


# ---------------------------------------------------------------------------
class TestTileStore:
    def test_default_store_is_host(self):
        ba = BlockArray.from_array(np.zeros((8, 8), np.float32), (4, 4))
        assert isinstance(ba.store, HostTileStore)
        assert ba.store.device_for((0, 0)) is None
        assert ba.tile_device((0, 0)) is None     # uncommitted host tile

    def test_device_store_places_tiles_on_homes(self):
        """from_array through a sharded runtime under a mesh: every tile
        committed to device_assignment[home] (acceptance item (a) on the
        single-device mesh)."""
        with dist.use_mesh(dist.single_device_mesh()) as ctx:
            with TaskRuntime(executor="sharded", placement="striped") as rt:
                A = rt.from_array(np.ones((16, 16), np.float32), (4, 4))
                devmap = device_assignment(rt.n_controllers, ctx)
                assert isinstance(A.store, DeviceTileStore)
                for idx in A.block_indices():
                    assert A.tile_device(idx) == \
                        devmap[A.home[idx] % len(devmap)]

    def test_use_store_migration_not_charged(self):
        """Homing tiles at registration is placement, not traffic."""
        with dist.use_mesh(dist.single_device_mesh()):
            with TaskRuntime(executor="sharded") as rt:
                rt.from_array(np.ones((16, 16), np.float32), (4, 4))
                assert rt.traffic.tile_moves == 0
                assert rt.traffic.bytes_moved == 0

    def test_no_mesh_keeps_host_store(self):
        with TaskRuntime(executor="sharded") as rt:
            A = rt.from_array(np.ones((8, 8), np.float32), (4, 4))
            assert isinstance(A.store, HostTileStore)

    def test_non_sharded_executors_keep_host_store(self):
        with dist.use_mesh(dist.single_device_mesh()):
            for ex in ("sequential", "staged"):
                with TaskRuntime(executor=ex) as rt:
                    A = rt.zeros((8, 8), (4, 4))
                    assert isinstance(A.store, HostTileStore)

    def test_set_tile_recommits_to_home(self):
        """A write re-commits to the home device regardless of where the
        value was produced."""
        with dist.use_mesh(dist.single_device_mesh()) as ctx:
            with TaskRuntime(executor="sharded") as rt:
                A = rt.zeros((8, 8), (4, 4))
                devmap = device_assignment(rt.n_controllers, ctx)
                A.set_tile((0, 0), jax.numpy.ones((4, 4)))
                assert A.tile_device((0, 0)) == devmap[A.home[(0, 0)] % len(devmap)]


class TestDestinationAwareAssembly:
    def test_materialize_accepts_destination(self):
        ba = BlockArray.from_array(np.arange(64, dtype=np.float32)
                                   .reshape(8, 8), (4, 4))
        dev = jax.devices()[0]
        out = ba[0:2, 0:2].materialize(device=dev)
        assert out.shape == (8, 8)
        np.testing.assert_array_equal(
            np.asarray(out), np.arange(64, dtype=np.float32).reshape(8, 8))

    def test_gather_accepts_destination(self):
        arr = np.arange(64, dtype=np.float32).reshape(8, 8)
        ba = BlockArray.from_array(arr, (4, 4))
        np.testing.assert_array_equal(
            np.asarray(ba.gather(device=jax.devices()[0])), arr)

    def test_single_device_assembly_charges_nothing(self):
        """Uncommitted host tiles never count as traffic."""
        ba = BlockArray.from_array(np.ones((8, 8), np.float32), (4, 4))
        ba.traffic = TileTraffic()
        ba.whole.materialize()
        ba.gather()
        assert ba.traffic.tile_moves == 0
        assert ba.traffic.bytes_staged == 0

    def test_committed_local_read_counts_local(self):
        with dist.use_mesh(dist.single_device_mesh()):
            with TaskRuntime(executor="sharded") as rt:
                A = rt.from_array(np.ones((8, 8), np.float32), (4, 4))
                A.whole.materialize(device=jax.devices()[0])
                assert rt.traffic.bytes_local > 0
                assert rt.traffic.tile_moves == 0


class TestOwnerOverride:
    def test_rebalance_disabled_returns_input(self):
        owners, spilled = rebalance_owners([0, 0, 0, 0], 4, 0.0)
        assert owners == [0, 0, 0, 0] and spilled == 0

    def test_rebalance_spills_hot_home(self):
        owners, spilled = rebalance_owners([0] * 8, 4, 1.5)
        assert spilled > 0
        load = [owners.count(h) for h in range(4)]
        assert max(load) <= 1.5 * (8 / 4)

    def test_rebalance_balanced_wave_untouched(self):
        owners, spilled = rebalance_owners([0, 1, 2, 3] * 4, 4, 1.5)
        assert spilled == 0
        assert owners == [0, 1, 2, 3] * 4

    def test_rebalance_deterministic(self):
        a = rebalance_owners([0, 0, 0, 1, 0, 0], 4, 1.2)
        b = rebalance_owners([0, 0, 0, 1, 0, 0], 4, 1.2)
        assert a == b

    def test_config_knob_validates(self):
        with pytest.raises(ValueError, match="owner_skew_threshold"):
            RuntimeConfig(owner_skew_threshold=-1.0).validate()

    def test_override_counted_and_numerics_hold(self):
        """Single-home placement with the override on: tasks spill, the
        stats say so, numerics stay bit-identical to sequential."""
        rng = np.random.default_rng(7)
        a = rng.standard_normal((64, 64), dtype=np.float32)
        b = rng.standard_normal((64, 64), dtype=np.float32)
        ref, _ = _gemm_program(TaskRuntime(executor="sequential"), a, b)
        with dist.use_mesh(dist.single_device_mesh()):
            rt = TaskRuntime(executor="sharded", placement="single",
                             owner_skew_threshold=1.5)
            got, s = _gemm_program(rt, a, b)
        np.testing.assert_array_equal(ref, got)
        assert s.owner_overrides and s.owner_overrides > 0
        # spilling away from the hot home makes some reads (and the
        # write-back) cross-home: the charge the override knowingly pays
        assert s.cross_home_bytes > 0

    def test_override_off_by_default(self):
        with dist.use_mesh(dist.single_device_mesh()):
            rt = TaskRuntime(executor="sharded", placement="single")
            rng = np.random.default_rng(8)
            a = rng.standard_normal((64, 64), dtype=np.float32)
            _gemm_program(rt, a, a)
        s = rt.stats()
        assert s.owner_overrides == 0
        assert s.cross_home_bytes == 0


class TestResidencyStats:
    def test_all_executors_report_residency_fields(self):
        """Same residency semantics everywhere: the counters exist (and
        are zero where nothing ever moves across devices)."""
        rng = np.random.default_rng(9)
        a = rng.standard_normal((64, 64), dtype=np.float32)
        for ex in ("sequential", "staged", "sharded"):
            rt = TaskRuntime(executor=ex)
            _gemm_program(rt, a, a)
            s = rt.stats()
            assert s.tile_moves == 0
            assert s.bytes_moved == 0
            assert s.bytes_staged == 0

    def test_sim_reports_predicted_tile_moves(self):
        sys.path.insert(0, ".")
        from benchmarks.apps import run_app
        s = run_app("matmul", "sim", app_kwargs={"n": 128, "tile": 32})
        # g^2 (g-1) cross-home A-reads under striped homes, g=4
        assert s.tile_moves and s.tile_moves > 0
        assert s.bytes_staged == 0

    def test_mesh_wave_dispatch_never_stages(self):
        """The acceptance criterion on the single-device mesh: grouped
        wave dispatches stage zero operand bytes through a non-home
        device."""
        rng = np.random.default_rng(10)
        a = rng.standard_normal((128, 128), dtype=np.float32)
        with dist.use_mesh(dist.single_device_mesh()):
            rt = TaskRuntime(executor="sharded", placement="striped")
            _, s = _gemm_program(rt, a, a)
        assert s.sharded_dispatches > 0
        assert s.bytes_staged == 0


# ---------------------------------------------------------------------------
def test_two_device_residency_and_accounting():
    """The real thing, in a forced-host 2-device subprocess: (a) tiles
    committed to device_assignment[home]; (b) measured bytes_moved ==
    footprint-predicted cross_home_bytes on striped gemm with homes ==
    devices, bytes_staged == 0 through every wave dispatch; (c) sharded
    results bit-identical to sequential."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro import dist
from repro.core import TaskRuntime, task
from repro.core.blocks import DeviceTileStore
from repro.core.placement import device_assignment

assert jax.device_count() == 2
mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2), ("data",))

@task(inout="c", in_=("a", "b"))
def gemm(c, a, b):
    return c + a @ b

rng = np.random.default_rng(0)
a = rng.standard_normal((128, 128), dtype=np.float32)
b = rng.standard_normal((128, 128), dtype=np.float32)

def prog(rt, tile=32):
    g = 128 // tile
    with rt.scope():
        A = rt.from_array(a, (tile, tile)); B = rt.from_array(b, (tile, tile))
        C = rt.zeros((128, 128), (tile, tile))
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
        s = rt.stats()      # dispatch accounting, before the gather
        return np.asarray(C.gather()), s, (A, B, C)

ref, _, _ = prog(TaskRuntime(executor="sequential"))
with dist.use_mesh(mesh) as ctx:
    rt = TaskRuntime(executor="sharded", placement="striped",
                     n_controllers=2)
    got, s, arrays = prog(rt)
    devmap = device_assignment(2, ctx)

# (c) bit-equality with tiles physically distributed over 2 devices
np.testing.assert_array_equal(ref, got)
# (a) every tile lives on its home's device
for ba in arrays:
    assert isinstance(ba.store, DeviceTileStore)
    for idx in ba.block_indices():
        assert ba.tile_device(idx) == devmap[ba.home[idx] % 2], \
            (ba.name, idx)
# every wave went through the shard_map hybrid
assert s.sharded_dispatches == 4, s.sharded_dispatches
# (b) zero staging; measured moves equal the footprint prediction
assert s.bytes_staged == 0, s.bytes_staged
assert s.bytes_moved == s.cross_home_bytes, (s.bytes_moved,
                                             s.cross_home_bytes)
# exact count: with 2 striped homes (g even) only the A[i,k] read
# crosses, and only when k and j differ in parity -> g^3/2 blocks
g, block_bytes = 4, 32 * 32 * 4
assert s.cross_home_bytes == g ** 3 // 2 * block_bytes, s.cross_home_bytes
assert s.tile_moves == g ** 3 // 2, s.tile_moves
# the gather read-back itself assembles on the destination: direct
# moves for the off-destination half of C's tiles, still zero staging
s2 = rt.stats()
assert s2.bytes_staged == 0, s2.bytes_staged
assert s2.bytes_moved == s.bytes_moved + g * g // 2 * block_bytes
print("RESIDENCY-2DEV-OK")
"""
    out = subprocess.run([sys.executable, "-c", code],
                         cwd=pathlib.Path(__file__).resolve().parent.parent,
                         capture_output=True, text=True, timeout=300)
    assert "RESIDENCY-2DEV-OK" in out.stdout, out.stderr[-2000:]
