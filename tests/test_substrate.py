"""Training substrate: optimizer, schedule, compression, data pipeline,
checkpointing (incl. elastic restore), sharding rules."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticTokens
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, cosine_schedule, decompress_int8)
from repro.optim.compress import compress_with_feedback, ef_init


# ---------------------------------------------------------------------------
class TestAdamW:
    def _params(self):
        return {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,)),
                "nested": {"x": jnp.full((2, 3), 2.0)}}

    def test_descends_quadratic(self):
        params = {"w": jnp.full((8,), 5.0)}
        state = adamw_init(params)
        for step in range(200):
            grads = {"w": 2 * params["w"]}          # d/dw w^2
            params, state = adamw_update(grads, state, params, lr=5e-2,
                                         weight_decay=0.0)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_state_structure_and_step(self):
        p = self._params()
        s = adamw_init(p)
        g = jax.tree_util.tree_map(jnp.ones_like, p)
        p2, s2 = adamw_update(g, s, p, lr=1e-3)
        assert int(s2.step) == 1
        assert jax.tree_util.tree_structure(p) == \
            jax.tree_util.tree_structure(p2)

    def test_clip(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, gnorm = clip_by_global_norm(g, 1.0)
        assert float(gnorm) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
        new_norm = float(jnp.linalg.norm(clipped["a"]))
        assert new_norm == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    lr0 = cosine_schedule(jnp.int32(0), peak_lr=1e-3, warmup_steps=10,
                          total_steps=100)
    lr_peak = cosine_schedule(jnp.int32(10), peak_lr=1e-3, warmup_steps=10,
                              total_steps=100)
    lr_end = cosine_schedule(jnp.int32(100), peak_lr=1e-3, warmup_steps=10,
                             total_steps=100)
    assert float(lr0) == 0.0
    assert float(lr_peak) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_end) == pytest.approx(1e-4, rel=1e-3)


# ---------------------------------------------------------------------------
class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(1e-3, 1e3))
    def test_roundtrip_error_bounded(self, scale):
        g = jnp.asarray(np.random.default_rng(0).standard_normal(256)
                        * scale, jnp.float32)
        q, s = compress_int8(g)
        back = decompress_int8(q, s)
        # quantization error bounded by half a step
        assert float(jnp.abs(back - g).max()) <= float(s) / 2 + 1e-6

    def test_error_feedback_unbiased(self):
        """Sum of dequantized transmissions + final residual == sum of
        true gradients (error feedback conserves mass)."""
        rng = np.random.default_rng(1)
        grads_seq = [
            {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
            for _ in range(20)]
        ef = ef_init(grads_seq[0])
        sent_total = jnp.zeros(64)
        for g in grads_seq:
            qtree, ef = compress_with_feedback(g, ef)
            q, s = qtree["w"]
            sent_total = sent_total + decompress_int8(q, s)
        true_total = sum(g["w"] for g in grads_seq)
        gap = sent_total + ef.residual["w"] - true_total
        np.testing.assert_allclose(np.asarray(gap), 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
class TestData:
    def test_deterministic_skip_ahead(self):
        d = SyntheticTokens(vocab_size=1000, seq_len=64, global_batch=8,
                            seed=3)
        b1 = d.batch_at(17)
        b2 = d.batch_at(17)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = d.batch_at(18)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_host_sharding_partitions(self):
        d = SyntheticTokens(vocab_size=1000, seq_len=32, global_batch=8)
        h0 = d.batch_at(0, host_index=0, host_count=2)
        h1 = d.batch_at(0, host_index=1, host_count=2)
        assert h0["tokens"].shape == (4, 32)
        assert not np.array_equal(np.asarray(h0["tokens"]),
                                  np.asarray(h1["tokens"]))

    def test_learnable_structure(self):
        d = SyntheticTokens(vocab_size=100, seq_len=64, global_batch=4)
        t = np.asarray(d.batch_at(0)["tokens"])
        assert t.min() >= 0 and t.max() < 100


# ---------------------------------------------------------------------------
class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                "step": jnp.int32(7)}
        save_checkpoint(str(tmp_path), 7, tree, meta={"arch": "t"})
        assert latest_step(str(tmp_path)) == 7
        like = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        restored, meta, step = restore_checkpoint(str(tmp_path), 7, like)
        assert meta == {"arch": "t"} and step == 7
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))

    def test_async_save(self, tmp_path):
        tree = {"w": jnp.ones((8, 8))}
        t = save_checkpoint(str(tmp_path), 3, tree, async_save=True)
        t.join(timeout=10)
        assert latest_step(str(tmp_path)) == 3

    def test_commit_marker_crash_safety(self, tmp_path):
        tree = {"w": jnp.ones((4,))}
        save_checkpoint(str(tmp_path), 5, tree)
        # a torn checkpoint without the marker must be ignored
        os.makedirs(tmp_path / "step_00000009")
        assert latest_step(str(tmp_path)) == 5

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1, {"other": jnp.ones((4,))})


# ---------------------------------------------------------------------------
class TestShardingRules:
    def _ctx(self):
        from repro.dist.context import MeshContext
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        return MeshContext(mesh)

    @pytest.mark.parametrize("arch", ["command-r-35b", "qwen2-vl-72b",
                                      "deepseek-v2-lite-16b", "zamba2-1.2b",
                                      "xlstm-1.3b", "whisper-tiny"])
    def test_specs_cover_all_params(self, arch):
        from repro.configs import get_config
        from repro.dist.sharding import param_shardings
        from repro.models import api
        cfg = get_config(arch)
        abs_params = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        ctx = self._ctx()
        sh = param_shardings(cfg, abs_params, ctx)
        n_leaves = len(jax.tree_util.tree_leaves(abs_params))
        n_specs = len(jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_leaves == n_specs

    def test_divisibility_guard(self):
        """Rules must never emit a spec whose axis does not divide."""
        from repro.configs import get_config
        from repro.dist.context import MeshContext
        from repro.dist.sharding import param_shardings
        from repro.models import api
        import numpy as np
        cfg = get_config("qwen1.5-4b")      # 20 heads: awkward divisors
        mesh = jax.sharding.AbstractMesh(
            (2, 16), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        ctx = MeshContext(mesh)
        abs_params = jax.eval_shape(
            lambda: api.init_params(jax.random.PRNGKey(0), cfg))
        sh = param_shardings(cfg, abs_params, ctx)

        def check(path, leaf):
            s = jax.tree_util.tree_leaves_with_path(sh)
        flat_p = jax.tree_util.tree_leaves(abs_params)
        flat_s = jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: hasattr(x, "spec"))
        for leaf, nsh in zip(flat_p, flat_s):
            for dim, axis in zip(leaf.shape, tuple(nsh.spec)):
                if axis is None:
                    continue
                size = int(np.prod([mesh.shape[a] for a in
                                    (axis if isinstance(axis, tuple)
                                     else (axis,))]))
                assert dim % size == 0, (leaf.shape, nsh.spec)
