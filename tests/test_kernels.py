"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes; hypothesis properties for the combiners."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.black_scholes import ops as bs_ops, ref as bs_ref
from repro.kernels.cholesky import ops as chol_ops, ref as chol_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops, ref as fd_ref
from repro.kernels.jacobi import ops as jac_ops, ref as jac_ref
from repro.kernels.matmul import ops as mm_ops, ref as mm_ref

_rng = np.random.default_rng(42)


def _randn(*shape, dtype=np.float32):
    return jnp.asarray(_rng.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------------------
class TestBlackScholes:
    @pytest.mark.parametrize("n", [512, 2048, 1000, 129])
    def test_vs_ref(self, n):
        spot = jnp.asarray(_rng.uniform(10, 200, n).astype(np.float32))
        strike = jnp.asarray(_rng.uniform(10, 200, n).astype(np.float32))
        t = jnp.asarray(_rng.uniform(0.1, 2.0, n).astype(np.float32))
        rate = jnp.full((n,), 0.03, jnp.float32)
        vol = jnp.asarray(_rng.uniform(0.1, 0.6, n).astype(np.float32))
        c_ref, p_ref = bs_ref.black_scholes(spot, strike, t, rate, vol)
        c, p = bs_ops.black_scholes(spot, strike, t, rate, vol,
                                    use_pallas=True, interpret=True,
                                    block_rows=4)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                                   rtol=1e-5, atol=1e-3)

    def test_put_call_parity(self):
        n = 256
        spot = jnp.asarray(_rng.uniform(50, 150, n).astype(np.float32))
        strike = jnp.full((n,), 100.0, jnp.float32)
        t = jnp.full((n,), 1.0, jnp.float32)
        rate = jnp.full((n,), 0.05, jnp.float32)
        vol = jnp.full((n,), 0.3, jnp.float32)
        c, p = bs_ops.black_scholes(spot, strike, t, rate, vol,
                                    use_pallas=True, interpret=True)
        parity = np.asarray(c - p - (spot - strike * jnp.exp(-rate * t)))
        np.testing.assert_allclose(parity, 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
class TestMatmul:
    @pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 384),
                                       (128, 256, 512)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_vs_ref(self, m, n, k, dtype):
        a, b, c = _randn(m, k), _randn(k, n), _randn(m, n)
        a, b, c = (x.astype(dtype) for x in (a, b, c))
        got = mm_ops.matmul(a, b, c, use_pallas=True, interpret=True)
        want = mm_ref.matmul(a, b, c)
        tol = 1e-4 if dtype == np.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("m,n,k,bk", [(128, 128, 256, 128),
                                          (64, 128, 128, 64)])
    def test_tile_update(self, m, n, k, bk):
        c, a, b = _randn(m, n), _randn(m, k), _randn(n, k)
        got = mm_ops.tile_update(c, a, b, use_pallas=True, interpret=True,
                                 bk=bk)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(mm_ref.tile_update(c, a, b)),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
class TestJacobi:
    @pytest.mark.parametrize("h,w,br", [(256, 128, 64), (128, 256, 128),
                                        (64, 128, 64), (512, 128, 128)])
    def test_vs_ref(self, h, w, br):
        x = _randn(h, w)
        got = jac_ops.jacobi_step(x, use_pallas=True, interpret=True,
                                  block_rows=br)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jac_ref.jacobi_step(x)),
                                   rtol=1e-6, atol=1e-6)

    def test_max_principle_and_diffusion(self):
        # Laplace max principle: interior stays within boundary extremes;
        # heat diffuses inward from the hot boundary row
        x = jnp.zeros((32, 128), jnp.float32).at[0, :].set(1.0)
        y = jac_ops.jacobi(x, iters=200)
        interior = np.asarray(y)[1:-1, 1:-1]
        assert interior.min() >= 0.0 and interior.max() <= 1.0
        assert interior.mean() > 0.01            # heat actually moved
        assert not np.isnan(np.asarray(y)).any()


# ---------------------------------------------------------------------------
class TestCholesky:
    @pytest.mark.parametrize("n,tile", [(256, 64), (384, 128)])
    def test_blocked_vs_lapack(self, n, tile):
        a = np.asarray(_randn(n, n), np.float64)
        spd = jnp.asarray(a @ a.T + n * np.eye(n), jnp.float32)
        got = chol_ref.cholesky_blocked(spd, tile)
        want = jnp.linalg.cholesky(spd)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_tile_ops(self):
        a = np.asarray(_randn(128, 128), np.float64)
        spd = jnp.asarray(a @ a.T + 128 * np.eye(128), jnp.float32)
        l = chol_ops.potrf(spd)
        np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(spd),
                                   rtol=1e-3, atol=1e-3)
        b = _randn(128, 128)
        x = chol_ops.trsm(l, b)
        np.testing.assert_allclose(np.asarray(x @ l.T), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)
        c = _randn(128, 128)
        got = chol_ops.update(c, b, b, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(chol_ref.update(c, b, b)),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_vs_ref(self, causal, hq, hkv, dtype):
        B, S, D = 2, 128, 64
        q = _randn(B, hq, S, D).astype(dtype)
        k = _randn(B, hkv, S, D).astype(dtype)
        v = _randn(B, hkv, S, D).astype(dtype)
        want = np.asarray(fa_ref.mha(q, k, v, causal=causal), np.float32)
        tol = 2e-5 if dtype == np.float32 else 2e-2
        for impl in ("chunked", "pallas"):
            got = np.asarray(fa_ops.attention(
                q, k, v, causal=causal, impl=impl, interpret=True,
                q_chunk=64, k_chunk=64), np.float32)
            np.testing.assert_allclose(got, want, rtol=tol, atol=tol,
                                       err_msg=impl)

    def test_prefill_continuation(self):
        # Sq < Skv: new chunk attends to full prefix causally
        B, H, D = 1, 2, 64
        q = _randn(B, H, 32, D)
        k = _randn(B, H, 128, D)
        v = _randn(B, H, 128, D)
        want = np.asarray(fa_ref.mha(q, k, v, causal=True))
        for impl in ("chunked", "pallas"):
            got = np.asarray(fa_ops.attention(q, k, v, causal=True,
                                              impl=impl, interpret=True,
                                              q_chunk=32, k_chunk=64))
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(sq=st.sampled_from([64, 128]), skv=st.sampled_from([128, 256]),
           d=st.sampled_from([32, 64, 128]))
    def test_chunked_property(self, sq, skv, d):
        q, k, v = _randn(1, 2, sq, d), _randn(1, 2, skv, d), _randn(1, 2, skv, d)
        want = np.asarray(fa_ref.mha(q, k, v, causal=True))
        got = np.asarray(fa_ops.attention(q, k, v, causal=True,
                                          impl="chunked", q_chunk=32,
                                          k_chunk=64))
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
class TestFlashDecode:
    @pytest.mark.parametrize("hq,hkv,s", [(8, 2, 512), (4, 4, 256),
                                          (16, 8, 1024)])
    def test_vs_ref(self, hq, hkv, s):
        B, D = 2, 64
        q = _randn(B, hq, D)
        k, v = _randn(B, hkv, s, D), _randn(B, hkv, s, D)
        want = np.asarray(fd_ref.decode_mha(q, k, v))
        got = np.asarray(fd_ops.decode_attention(
            q, k, v, use_pallas=True, interpret=True, bk=128))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(n_shards=st.sampled_from([1, 2, 4, 8]))
    def test_shard_combine_exact(self, n_shards):
        """Property: LSE-combining partials over any seq split == full
        attention (the correctness of SP decode)."""
        B, Hq, Hkv, S, D = 1, 4, 2, 256, 32
        q = _randn(B, Hq, D)
        k, v = _randn(B, Hkv, S, D), _randn(B, Hkv, S, D)
        want = np.asarray(fd_ref.decode_mha(q, k, v))
        chunk = S // n_shards
        outs, lses = [], []
        for i in range(n_shards):
            o, lse = fd_ops.decode_partial(q, k[:, :, i*chunk:(i+1)*chunk],
                                           v[:, :, i*chunk:(i+1)*chunk])
            outs.append(o)
            lses.append(lse)
        got = np.asarray(fd_ref.combine_partials(jnp.stack(outs),
                                                 jnp.stack(lses)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_masked_padding_shard(self):
        """A shard that is entirely padding must not perturb the combine."""
        B, Hq, Hkv, S, D = 1, 4, 2, 128, 32
        q = _randn(B, Hq, D)
        k, v = _randn(B, Hkv, S, D), _randn(B, Hkv, S, D)
        want = np.asarray(fd_ref.decode_mha(q, k, v))
        o1, l1 = fd_ops.decode_partial(q, k, v)
        mask = jnp.zeros((B, S), bool)
        o2, l2 = fd_ops.decode_partial(q, k, v, mask=mask)
        got = np.asarray(fd_ref.combine_partials(jnp.stack([o1, o2]),
                                                 jnp.stack([l1, l2])))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
