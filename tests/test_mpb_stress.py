"""Concurrency stress for the MPB transports under real threads.

The SPSC discipline both rings rely on (``MPBChannel`` lock-free under
the GIL, ``MPBQueue`` lock-per-line) is exactly what the threaded
dependence pump leans on: the master produces while a pump thread
consumes, with no synchronization beyond the ring protocol itself.
These tests run that discipline hard — 10^4 descriptors through real
producer/consumer threads with randomized sleeps on both sides — and
assert the protocol invariants the runtime depends on:

* no message/descriptor is ever lost or duplicated,
* FIFO order survives concurrent append/drain,
* backpressure refuses (never drops) when a ring fills,
* every ``MPBQueue`` slot walks EMPTY -> READY -> COMPLETED -> EMPTY.

Sleeps are seeded and sparse (a handful of sub-millisecond naps per
thousand operations) — enough to shake out interleavings without making
the suite slow.
"""
from __future__ import annotations

import random
import threading
import time

from repro.core.mpb import MPBChannel, MPBQueue, SlotState

N_MSGS = 10_000


def _napper(seed: int, every: int = 397):
    """A seeded occasional-sleep callable: naps a random sub-ms amount
    roughly once per ``every`` calls, forcing varied interleavings."""
    rng = random.Random(seed)
    calls = [0]

    def nap():
        calls[0] += 1
        if calls[0] % every == 0:
            time.sleep(rng.random() * 1e-3)

    return nap


class TestChannelStress:
    def test_spsc_no_loss_no_dup_fifo(self):
        ch = MPBChannel("stress", n_slots=8)
        got: list[int] = []
        done = threading.Event()
        errors: list[BaseException] = []

        def consumer():
            try:
                nap = _napper(1)
                while not (done.is_set() and not len(ch)):
                    got.extend(ch.recv_all())
                    nap()
            except BaseException as e:          # pragma: no cover
                errors.append(e)

        t = threading.Thread(target=consumer)
        t.start()
        nap = _napper(2)
        for i in range(N_MSGS):
            while not ch.try_send(i):           # backpressure: retry,
                time.sleep(0)                   # never drop
            nap()
        done.set()
        t.join(timeout=30)
        assert not t.is_alive()
        assert not errors
        # exactly the sent stream, in order: nothing lost, duplicated,
        # or reordered by the concurrent append/popleft
        assert got == list(range(N_MSGS))
        assert ch.sends == N_MSGS
        assert len(ch) == 0

    def test_echo_round_trip(self):
        """The depman wire pattern: master posts envelopes into an inbox
        ring, the pump thread consumes and answers each on a grant ring,
        the master drains grants — both directions under backpressure."""
        inbox = MPBChannel("inbox", n_slots=4)
        grants = MPBChannel("grants", n_slots=4)
        stop = threading.Event()
        errors: list[BaseException] = []

        def pump():
            try:
                nap = _napper(3)
                while not (stop.is_set() and not len(inbox)):
                    for msg in inbox.recv_all():
                        while not grants.try_send(msg * 2):
                            time.sleep(0)
                    nap()
            except BaseException as e:          # pragma: no cover
                errors.append(e)

        t = threading.Thread(target=pump)
        t.start()
        answers: list[int] = []
        nap = _napper(4)
        n = N_MSGS // 4
        for i in range(n):
            while not inbox.try_send(i):
                answers.extend(grants.recv_all())
                time.sleep(0)
            answers.extend(grants.recv_all())
            nap()
        stop.set()
        t.join(timeout=30)
        assert not t.is_alive()
        while len(grants):
            answers.extend(grants.recv_all())
        assert not errors
        assert answers == [2 * i for i in range(n)]


class _FakeTD:
    """Duck-typed stand-in for a TaskDescriptor: the queue only touches
    ``worker`` (set on accept) and identity (``mark_completed``)."""

    __slots__ = ("tid", "worker")

    def __init__(self, tid: int):
        self.tid = tid
        self.worker = None


class TestQueueStress:
    def test_master_worker_transitions(self):
        q = MPBQueue(worker_id=0, n_slots=8)
        done = threading.Event()
        errors: list[BaseException] = []
        ran: list[int] = []

        def worker():
            try:
                nap = _napper(5)
                while True:
                    td = q.next_ready(timeout=0.01)
                    if td is None:
                        if done.is_set():
                            return
                        continue
                    ran.append(td.tid)
                    nap()
                    q.mark_completed(td)
            except BaseException as e:          # pragma: no cover
                errors.append(e)

        t = threading.Thread(target=worker)
        t.start()
        collected: list[int] = []
        nap = _napper(6)
        for i in range(N_MSGS):
            td = _FakeTD(i)
            while True:
                accepted, back = q.try_put(td)
                if back is not None:
                    collected.append(back.tid)
                if accepted:
                    break
                # ring full: poll for completions, as the scheduler does
                collected.extend(d.tid for d in q.collect_completed())
                time.sleep(0)
            nap()
        # drain: every enqueued descriptor must come back completed
        deadline = time.time() + 30
        while len(collected) < N_MSGS and time.time() < deadline:
            collected.extend(d.tid for d in q.collect_completed())
            time.sleep(0)
        done.set()
        t.join(timeout=30)
        assert not t.is_alive()
        assert not errors
        # worker saw the master's FIFO order, exactly once each
        assert ran == list(range(N_MSGS))
        # master reclaimed every descriptor exactly once (EMPTY -> READY
        # -> COMPLETED -> EMPTY per slot; a stuck or skipped transition
        # would lose or duplicate a tid)
        assert sorted(collected) == list(range(N_MSGS))
        assert q.enq_count == N_MSGS
        assert q.occupancy() == 0
        assert all(s.state is SlotState.EMPTY for s in q._slots)
