"""Seeded random task-graph generator for differential executor testing.

One seed -> one deterministic task program: a plain-data step list
(:func:`generate`) replayed onto a fresh runtime by :func:`run_case`.
Programs mix everything the dependence analyzer and the dispatch layers
must agree on:

* ``in``/``out``/``inout`` footprints, single-tile and multi-tile,
* overlapping regions (a window task reads a 2x2 tile neighbourhood that
  other tasks write tile-by-tile),
* firstprivate index parameters (scalar offsets into a halo, scale
  factors) so grouped dispatch carries by-value operands,
* a second dtype (an int32 array) so some waves are mixed-dtype — under
  ``kernel_backend="pallas"`` those must take the XLA fallback and still
  match bit-for-bit,
* uneven waves: chains, fan-in and independent tasks of one seed layer
  into wavefronts of varying width with 1-task groups in the mix.

``tests/test_differential.py`` replays every pinned seed on sequential vs
staged vs sharded vs staged+pallas and asserts bit-identical outputs and
identical dependence counts.  The task functions are module-level on
purpose: all four paths (and all seeds) share one jit/vmap/pallas cache
per function, which is also what makes a 50-seed sweep affordable.

Failures replay exactly: ``python -m tests.fuzz_graphs <seed>`` prints the
generated program and runs the four-way comparison for one seed.
"""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RuntimeConfig, TaskRuntime, task

TILE = 8
GRID = 3                       # float32 arrays are GRIDxGRID tiles
SEEDS = tuple(range(60))       # pinned: >= 50 seeds, replayed verbatim

__all__ = ["SEEDS", "TILE", "GRID", "generate", "run_case"]


# -- the op vocabulary (module-level: one jit cache across all runs).
# Each task body routes through an inner jitted kernel so the sequential
# executor — which runs bodies eagerly — executes the *compiled*
# computation: XLA's CPU backend contracts `x + alpha*y` into an FMA
# under jit but not op-by-op, and the bit-identity contract across all
# four paths only holds when every path runs the compiled form (inner
# jit inlines transparently under the vmap/pallas traces).
@jax.jit
def _axpy_k(c, a, alpha):
    return c + alpha * a


@task(inout="c", in_="a", firstprivate="alpha")
def _axpy(c, a, alpha):
    return _axpy_k(c, a, alpha)


@jax.jit
def _scaled_copy_k(src, s):
    return s * src


@task(in_="src", out="dst", firstprivate="s")
def _scaled_copy(src, s, dst=None):
    return _scaled_copy_k(src, s)


@jax.jit
def _gemm_k(c, x, y):
    return c + jnp.dot(x, y, preferred_element_type=jnp.float32)


@task(inout="c", in_=("x", "y"))
def _gemm(c, x, y):
    return _gemm_k(c, x, y)


@jax.jit
def _window_k(src, r0, c0):
    return jax.lax.dynamic_slice(src, (r0, c0), (TILE, TILE)) * 0.5


@task(in_="src", out="dst", firstprivate=("r0", "c0"))
def _window(src, r0, c0, dst=None):
    return _window_k(src, r0, c0)


@jax.jit
def _blend_k(c, a, b):
    return 0.25 * c + 0.5 * a + 0.25 * b


@task(inout="c", in_=("a", "b"))
def _blend(c, a, b):
    return _blend_k(c, a, b)


@jax.jit
def _accum_int_k(c, m):
    return c + 0.125 * m.astype(jnp.float32)


@task(inout="c", in_="m")
def _accum_int(c, m):
    # mixed-dtype group: float32 tile accumulating an int32 tile — under
    # kernel_backend="pallas" this wave must take the XLA fallback
    return _accum_int_k(c, m)


_OPS = ("axpy", "scaled_copy", "gemm", "window", "blend", "accum_int")
_WEIGHTS = (4, 3, 3, 3, 3, 2)


def generate(seed: int) -> list[tuple]:
    """The seed's program: a list of plain-data steps, each
    ``(op, *tile indices / values)`` — no runtime objects, so a failing
    seed replays exactly from this description alone."""
    rng = random.Random(seed)
    steps: list[tuple] = []
    for _ in range(rng.randint(8, 18)):
        op = rng.choices(_OPS, weights=_WEIGHTS)[0]
        t = lambda: rng.randrange(GRID)
        if op == "axpy":
            steps.append((op, t(), t(), t(), t(),
                          round(rng.uniform(-2, 2), 3)))
        elif op == "scaled_copy":
            # 1x2 tile source/dest strips: multi-tile footprints that
            # overlap single-tile writers
            j = rng.randrange(GRID - 1)
            steps.append((op, t(), j, t(), rng.randrange(GRID - 1),
                          round(rng.uniform(0.5, 1.5), 3)))
        elif op == "gemm":
            steps.append((op, t(), t(), t(), t(), t(), t()))
        elif op == "window":
            # 2x2 halo read + firstprivate offset into it
            i, j = rng.randrange(GRID - 1), rng.randrange(GRID - 1)
            steps.append((op, i, j, rng.randrange(TILE),
                          rng.randrange(TILE), t(), t()))
        elif op == "blend":
            steps.append((op, t(), t(), t(), t(), t(), t()))
        else:                          # accum_int
            steps.append((op, t(), t(), t(), t()))
    return steps


def _spawn(steps: list[tuple], arrs: dict) -> None:
    A, B, C, M = arrs["A"], arrs["B"], arrs["C"], arrs["M"]
    for step in steps:
        op, rest = step[0], step[1:]
        if op == "axpy":
            ci, cj, ai, aj, alpha = rest
            _axpy(C[ci, cj], A[ai, aj], alpha)
        elif op == "scaled_copy":
            si, sj, di, dj, s = rest
            _scaled_copy(A[si, sj:sj + 2], s, B[di, dj:dj + 2])
        elif op == "gemm":
            ci, cj, xi, xj, yi, yj = rest
            _gemm(C[ci, cj], A[xi, xj], B[yi, yj])
        elif op == "window":
            si, sj, r0, c0, di, dj = rest
            _window(B[si:si + 2, sj:sj + 2], r0, c0, C[di, dj])
        elif op == "blend":
            ci, cj, ai, aj, bi, bj = rest
            _blend(C[ci, cj], A[ai, aj], B[bi, bj])
        else:                          # accum_int
            ci, cj, mi, mj = rest
            _accum_int(C[ci, cj], M[mi, mj])


def run_case(seed: int, **config_overrides):
    """Replay one seed's program on a fresh runtime.

    Returns ``(outputs, stats)``: the gathered arrays as numpy (compared
    bit-for-bit across executors) and the run's ``RuntimeStats`` (the
    dependence counts must not depend on the executor either)."""
    steps = generate(seed)
    rng = np.random.default_rng(seed)
    n = TILE * GRID
    cfg = RuntimeConfig(**{"executor": "staged", **config_overrides})
    rt = TaskRuntime(cfg)
    try:
        with rt.scope():
            arrs = {
                name: rt.from_array(
                    rng.standard_normal((n, n)).astype(np.float32),
                    (TILE, TILE), name=name)
                for name in ("A", "B", "C")
            }
            arrs["M"] = rt.from_array(
                rng.integers(-4, 5, size=(n, n)).astype(np.int32),
                (TILE, TILE), name="M")
            _spawn(steps, arrs)
            rt.barrier()
            outputs = {name: np.asarray(ba.gather())
                       for name, ba in arrs.items()}
        return outputs, rt.stats()
    finally:
        rt.shutdown()


_PATHS = {
    "sequential": {"executor": "sequential"},
    "staged": {"executor": "staged"},
    "sharded": {"executor": "sharded"},
    "staged+pallas": {"executor": "staged", "kernel_backend": "pallas"},
}


def compare_paths(seed: int) -> dict:
    """Run one seed on all four paths and assert equivalence; returns the
    per-path stats for further inspection."""
    ref_out, ref_stats = run_case(seed, **_PATHS["sequential"])
    stats = {"sequential": ref_stats}
    # dependence counts must agree among the *deferred* executors, which
    # all analyze the same pending graph; the sequential oracle runs each
    # task at spawn, so its analyzer sees only completed predecessors —
    # it anchors numerics, the staged path anchors the dependence counts
    dep_ref = None
    for path, cfg in _PATHS.items():
        if path == "sequential":
            continue
        out, st = run_case(seed, **cfg)
        stats[path] = st
        for name, want in ref_out.items():
            got = out[name]
            assert got.dtype == want.dtype, \
                f"seed {seed} {path} {name}: dtype {got.dtype}!={want.dtype}"
            assert np.array_equal(got, want), (
                f"seed {seed} {path} {name}: outputs differ "
                f"(max |d|={np.abs(got.astype(np.float64) - want.astype(np.float64)).max()})")
        assert st.tasks_spawned == ref_stats.tasks_spawned, \
            f"seed {seed} {path}: tasks_spawned differ"
        counts = (st.tasks_spawned, st.deps_found, st.blocks_walked)
        if dep_ref is None:
            dep_ref = counts
        else:
            assert counts == dep_ref, (
                f"seed {seed} {path}: dependence counts {counts} != "
                f"{dep_ref} (staged reference)")
    return stats


if __name__ == "__main__":
    import sys

    for s in [int(a) for a in sys.argv[1:]] or SEEDS:
        for step in generate(s):
            print(s, step)
        compare_paths(s)
        print(f"seed {s}: all paths agree")
