"""Home-sharded dependence management (``repro.core.depman``).

The sharded manager must be *protocol-compatible* with the central
analyzer (same dependence sets, same counters, same cleanup) while
admitting each home's footprint slice independently over MPB channels.
These tests pin that equivalence three ways: unit parity on constructed
streams (including the WAR-with-interleaved-completion orderings the
fused single-pass walk has to get right), the streaming leak bound on
both managers, and the determinism pin — central and sharded runtimes
produce bit-identical wave schedules on every paper app.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from benchmarks.apps import APPS, run_app
from benchmarks.spawn_throughput import build_array, run_matrix, run_stream
from repro.core import (In, InOut, Out, RuntimeConfig, TaskRuntime,
                        ShardedDependenceManager, task)
from repro.core.depman import DepMessage, HomeManager
from repro.core.deps import DependenceAnalyzer
from repro.core.executor import StagedExecutor
from repro.core.graph import DescriptorPool, TaskGraph
from repro.core.mpb import MPBChannel
from repro.core.placement import assign_homes


def _noop(*_a, **_k):
    return None


def _sharded(ba, n=4):
    mgr = ShardedDependenceManager(n_managers=n)
    mgr.register_array(ba)
    return mgr


class _Stream:
    """A tiny driver running one footprint script through one analyzer:
    ``spawn`` analyzes + inserts, ``done`` completes + forgets (the same
    lifecycle the runtime drives), recording each task's dep tids."""

    def __init__(self, analyzer):
        self.analyzer = analyzer
        self.pool = DescriptorPool(capacity=256)
        self.graph = TaskGraph()
        self.tds: dict[str, object] = {}
        self.deps: dict[str, list[int]] = {}

    def spawn(self, name, *args):
        td = self.pool.acquire(_noop, tuple(args))
        td.spawn_order = len(self.tds)
        found = self.analyzer.analyze(td)
        self.graph.insert(td, found)
        self.tds[name] = td
        self.deps[name] = sorted(d.tid for d in found)
        return td

    def done(self, name):
        td = self.tds[name]
        self.graph.mark_executed(td)
        self.graph.release(td)
        self.analyzer.forget_completed(td)


def _both(ba_central, ba_sharded, script, n=4):
    """Run ``script`` through central and sharded; the recorded dep tids
    must match task for task (same pool => same tids)."""
    runs = []
    for analyzer, ba in ((DependenceAnalyzer(), ba_central),
                         (_sharded(ba_sharded, n), ba_sharded)):
        s = _Stream(analyzer)
        script(s, ba)
        runs.append(s)
    central, sharded = runs
    assert central.deps == sharded.deps
    return central, sharded


def _grid(homes=4):
    ba = build_array(8, homes, seg=4)       # 8x4 blocks, row-banded
    return ba


# ---------------------------------------------------------------------------
class TestMPBChannel:
    def test_fifo_and_len(self):
        ch = MPBChannel("t", n_slots=4)
        for i in range(3):
            assert ch.try_send(i)
        assert len(ch) == 3
        assert ch.recv_all() == [0, 1, 2]
        assert len(ch) == 0

    def test_backpressure_counts_stalls(self):
        ch = MPBChannel("t", n_slots=2)
        assert ch.try_send("a") and ch.try_send("b")
        assert not ch.try_send("c")            # ring full
        assert ch.full_stalls == 1
        assert ch.sends == 2
        assert ch.recv_all() == ["a", "b"]
        assert ch.try_send("c")                # space again

    def test_recv_all_drains_once(self):
        ch = MPBChannel("t")
        ch.try_send(1)
        assert ch.recv_all() == [1]
        assert ch.recv_all() == []


# ---------------------------------------------------------------------------
# unit parity: the sharded protocol finds the central analyzer's deps
class TestShardedParity:
    def test_raw_waw_war_chain(self):
        def script(s, ba):
            s.spawn("w1", InOut(ba[0, 0:4]))
            s.spawn("r1", In(ba[0, 0:4]), InOut(ba[1, 0:4]))
            s.spawn("w2", InOut(ba[0, 0:4]))     # RAW->w1? no: WAW + WAR
            assert s.deps["r1"] == [s.tds["w1"].tid]
            assert sorted(s.deps["w2"]) == sorted(
                [s.tds["w1"].tid, s.tds["r1"].tid])

        _both(_grid(), _grid(), script)

    def test_war_with_interleaved_reader_completion(self):
        """A reader that completed (and was forgotten) before the writer
        arrives contributes no WAR edge; a reader that completed but is
        not yet forgotten is filtered by liveness — both orderings must
        match central exactly."""
        def script(s, ba):
            s.spawn("r1", In(ba[0, 0:4]), InOut(ba[1, 0:4]))
            s.spawn("r2", In(ba[0, 0:4]), InOut(ba[2, 0:4]))
            s.done("r1")                         # completed + forgotten
            s.graph.mark_executed(s.tds["r2"])   # completed, NOT forgotten
            s.spawn("w", InOut(ba[0, 0:4]))
            assert s.deps["w"] == []             # both readers are done

        _both(_grid(), _grid(), script)

    def test_war_orders_live_readers(self):
        def script(s, ba):
            s.spawn("r1", In(ba[0, 0:4]), InOut(ba[1, 0:4]))
            s.spawn("r2", In(ba[0, 0:4]), InOut(ba[2, 0:4]))
            s.done("r1")
            s.spawn("w", InOut(ba[0, 0:4]))
            assert s.deps["w"] == [s.tds["r2"].tid]   # only the live one

        _both(_grid(), _grid(), script)

    def test_same_block_two_modes_no_self_dep(self):
        """(Out, In) on one block within one task: the fused walk must
        not order the task after itself, and downstream tasks see it as
        the writer — like central's two-pass walk."""
        def script(s, ba):
            s.spawn("t", Out(ba[0, 0:4]), In(ba[0, 0:4]))
            assert s.deps["t"] == []
            s.spawn("r", In(ba[0, 0:4]), InOut(ba[1, 0:4]))
            assert s.deps["r"] == [s.tds["t"].tid]

        _both(_grid(), _grid(), script)

    def test_cross_home_predecessor_counts_once(self):
        """A predecessor spanning two homes is granted by both managers
        but is one dependence — deps_found must match central."""
        def script(s, ba):
            s.spawn("w", Out(ba[0:2, 0]))        # rows 0+1: homes 0 and 1
            s.spawn("r", In(ba[0:2, 0]), Out(ba[2, 0]))
            assert s.deps["r"] == [s.tds["w"].tid]

        central, sharded = _both(_grid(), _grid(), script)
        assert central.analyzer.deps_found == sharded.analyzer.deps_found \
            == 1

    def test_blocks_walked_matches_central(self):
        def script(s, ba):
            s.spawn("a", InOut(ba[0, 0:4]), In(ba[1, 0:4]))
            s.spawn("b", In(ba[0, 0:4]), Out(ba[3, 0:4]))
            s.done("a")

        central, sharded = _both(_grid(), _grid(), script)
        assert central.analyzer.blocks_walked \
            == sharded.analyzer.blocks_walked == 16

    def test_tasks_touching_modes(self):
        for n in (1, 4):
            ba = _grid(n)
            mgr = _sharded(ba, n)
            s = _Stream(mgr)
            w = s.spawn("w", InOut(ba[0, 0:4]))
            r = s.spawn("r", In(ba[1, 0:4]), Out(ba[2, 0:4]))
            blocks = list(ba[0:2, 0:4].block_ids)
            assert mgr.tasks_touching(blocks, "in") == {w}
            assert mgr.tasks_touching(blocks, "out") == {w, r}
            assert mgr.tasks_touching(blocks, "inout") == {w, r}
            s.done("w")
            assert mgr.tasks_touching(blocks, "in") == set()
            with pytest.raises(ValueError):
                mgr.tasks_touching(blocks, "rw")

    def test_route_cache_invalidated_on_register(self):
        ba = _grid(4)
        mgr = _sharded(ba, 4)
        s = _Stream(mgr)
        td = s.spawn("w", Out(ba[1, 0:4]))
        assert mgr.owner_of(td) == 1             # row-banded: row 1 home 1
        assign_homes(ba, "single", 4)            # re-place: all home 0
        mgr.register_array(ba)                   # clears the route cache
        td2 = s.spawn("w2", Out(ba[1, 0:4]))
        assert mgr.owner_of(td2) == 0

    def test_grant_ring_overflow_raises(self):
        ba = _grid(2)
        mgr = _sharded(ba, 2)
        td = DescriptorPool(capacity=4).acquire(_noop, (Out(ba[0, 0:4]),))
        td.spawn_order = 0
        # violate the drain-before-post invariant by hand: a stuffed grant
        # ring must fail loudly, never drop a dependence set.  Inject the
        # query envelope directly — _flush_home would absorb the stuffed
        # ring first, which is exactly the invariant under test.
        while mgr.grants[0].try_send(DepMessage("dep_grant", 0, td, [])):
            pass
        env = DepMessage("dep_batch", 0, None,
                         [("dep_query", td,
                           [(False, True, list(ba[0, 0:4].block_ids))])])
        assert mgr.inbox[0].try_send(env)
        with pytest.raises(RuntimeError, match="overflow"):
            mgr._service(0)


# ---------------------------------------------------------------------------
# the monotonic-growth regression (ISSUE 7 satellite): block metadata for
# fully retired tasks must be dropped on both managers
class TestForgetReclaims:
    def test_streaming_live_blocks_return_to_zero(self):
        ba = build_array(16, 4, seg=4)
        for analyzer in (DependenceAnalyzer(), _sharded(ba, 4)):
            r = run_stream(2000, analyzer, ba, window=64)
            assert r["live_blocks"] == 0         # every entry reclaimed
        assert len(analyzer._live_parts) == 0    # sharded: slices freed

    def test_central_meta_stays_bounded(self):
        ba = build_array(16, 1, seg=4)
        analyzer = DependenceAnalyzer()
        run_stream(1000, analyzer, ba, window=32)
        assert len(analyzer._meta) == 0


# ---------------------------------------------------------------------------
# runtime integration
class TestRuntimeIntegration:
    def test_config_validates_dep_manager(self):
        with pytest.raises(ValueError, match="dep_manager"):
            RuntimeConfig(dep_manager="bogus").validate()

    def test_sharded_stats_carry_manager_counters(self):
        @task(inout="x")
        def bump(x):
            return x + 1.0

        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded")) as rt:
            A = rt.zeros((8, 8), (4, 4))
            for _ in range(3):
                bump(A[0, 0])
                bump(A[1, 1])
            rt.barrier()
            s = rt.stats()
        assert s.dep_messages > 0
        assert sum(s.manager_admissions) == s.tasks_spawned == 6
        np.testing.assert_allclose(np.asarray(A.gather())[:4, :4], 3.0)

    def test_central_stats_leave_manager_fields_none(self):
        @task(inout="x")
        def bump(x):
            return x + 1.0

        with TaskRuntime(RuntimeConfig(executor="staged")) as rt:
            A = rt.zeros((4, 4), (4, 4))
            bump(A[0, 0])
            rt.barrier()
            s = rt.stats()
        assert s.dep_messages is None
        assert s.manager_admissions is None

    def test_manager_events_emitted_when_tracked(self):
        from repro.obs import InMemoryTracker

        @task(inout="x")
        def bump(x):
            return x + 1.0

        trk = InMemoryTracker()
        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded",
                                       tracker=trk)) as rt:
            A = rt.zeros((8, 8), (4, 4))
            bump(A[0, 0])
            bump(A[1, 1])
            rt.barrier()
        admits = trk.events_of("manager_admit")
        msgs = trk.events_of("dep_msg")
        assert len(admits) == 2
        assert {e.data["msg"] for e in msgs} >= {"dep_query", "dep_grant",
                                                 "release"}

    @pytest.mark.parametrize("execu", ["sequential", "host", "staged",
                                       "sharded"])
    def test_gather_matches_central(self, execu):
        @task(inout="c", in_=("a", "b"))
        def gemm(c, a, b):
            return c + a @ b

        outs = []
        for dm in ("central", "sharded"):
            with TaskRuntime(RuntimeConfig(executor=execu, n_workers=2,
                                           dep_manager=dm)) as rt:
                A = rt.full((8, 8), (4, 4), 2.0)
                B = rt.full((8, 8), (4, 4), 3.0)
                C = rt.zeros((8, 8), (4, 4))
                for i in range(2):
                    for j in range(2):
                        for k in range(2):
                            gemm(C[i, j], A[i, k], B[k, j])
                rt.barrier()
                outs.append(np.asarray(C.gather()))
        np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# the determinism pin: central and sharded managers schedule identical
# waves (same tids, same order) on every paper app — the acceptance bar
# for swapping dependence management out from under the executors
SIZES = {
    "black_scholes": {"n_options": 2048, "task_options": 256},
    "matmul": {"n": 128, "tile": 32},
    "fft": {"n": 64, "row_block": 16, "tile": 16},
    "jacobi": {"n": 128, "tile": 32, "iters": 2},
    "cholesky": {"n": 128, "tile": 32},
}


@pytest.mark.parametrize("app", sorted(APPS))
def test_identical_wave_schedule_on_apps(app, monkeypatch):
    orig = StagedExecutor._wavefronts
    schedules = {}
    for dm in ("central", "sharded"):
        log: list = []

        def spy(self, tasks, _log=log):
            waves = orig(self, tasks)
            _log.append([tuple(t.tid for t in w) for w in waves])
            return waves

        monkeypatch.setattr(StagedExecutor, "_wavefronts", spy)
        run_app(app, "staged", app_kwargs=SIZES[app], dep_manager=dm)
        schedules[dm] = log
    assert schedules["central"] == schedules["sharded"]
    assert any(schedules["central"])             # the spy saw real waves


# ---------------------------------------------------------------------------
# descriptor-line batching + the concurrent pump (ISSUE 10): wire counts
# are deterministic functions of the logical stream and the config —
# identical across pump modes — and the sim-side replay reconciles
class TestBatchingAndPumps:
    def _run(self, pump, batch_lines, n=2000, homes=4, **kw):
        ba = build_array(16, homes, seg=4)
        mgr = ShardedDependenceManager(n_managers=homes,
                                       batch_lines=batch_lines, pump=pump,
                                       pump_threads=2, **kw)
        mgr.register_array(ba)
        r = run_stream(n, mgr, ba, window=64)
        mgr.shutdown()
        return mgr, r

    def test_batching_packs_envelopes(self):
        mgr1, _ = self._run("sync", 1)
        mgr4, _ = self._run("sync", 4)
        # logical traffic is batching-invariant; wire traffic is not
        assert mgr4.dep_messages == mgr1.dep_messages
        assert mgr1.dep_batches == mgr1.dep_messages   # one desc/envelope
        assert mgr4.dep_batches < mgr4.dep_messages
        assert mgr4.dep_lines < mgr1.dep_lines

    @pytest.mark.parametrize("batch_lines", [1, 4])
    def test_wire_counts_pump_invariant(self, batch_lines):
        sync_mgr, sync_r = self._run("sync", batch_lines)
        thr_mgr, thr_r = self._run("threaded", batch_lines)
        assert thr_r["dep_checksum"] == sync_r["dep_checksum"]
        assert thr_r["deps_found"] == sync_r["deps_found"]
        assert thr_mgr.dep_messages == sync_mgr.dep_messages
        assert thr_mgr.dep_batches == sync_mgr.dep_batches
        assert thr_mgr.dep_lines == sync_mgr.dep_lines

    @pytest.mark.parametrize("pump", ["sync", "threaded"])
    def test_traffic_reconciles_with_sim(self, pump):
        from repro.core.sim import predict_dep_traffic
        mgr, _ = self._run(pump, 4, record_traffic=True)
        pred = predict_dep_traffic(mgr.traffic_log, 4, mgr.traffic_deps)
        assert pred["dep_batches"] == mgr.dep_batches
        assert pred["dep_lines"] == mgr.dep_lines

    @pytest.mark.parametrize("pump", ["sync", "threaded"])
    def test_tiny_rings_backpressure(self, pump):
        """channel_slots=2 forces constant ring pressure on every post;
        the stream must still complete with the same dependence stream
        and wire counts as the roomy default."""
        ref_mgr, ref = self._run("sync", 4)
        mgr, r = self._run(pump, 4, channel_slots=2)
        assert r["dep_checksum"] == ref["dep_checksum"]
        assert mgr.dep_messages == ref_mgr.dep_messages
        assert mgr.dep_batches == ref_mgr.dep_batches

    def test_quiesce_with_admissions_outstanding_raises(self):
        ba = _grid(2)
        mgr = _sharded(ba, 2)
        td = DescriptorPool(capacity=4).acquire(
            _noop, (Out(ba[0, 0:4]),))
        td.spawn_order = 0
        mgr.analyze_begin(td)
        with pytest.raises(RuntimeError, match="outstanding"):
            mgr.quiesce()
        assert len(mgr.admit_finish()) == 1      # drain cleanly
        mgr.quiesce()                            # now fine

    def test_threaded_pump_wall_accumulates(self):
        mgr, _ = self._run("threaded", 4)
        assert mgr.pump_wall_s > 0.0
        # each stencil task queries one home per footprint row it touches
        assert sum(mgr.admissions) >= 2000

    def test_split_phase_matches_blocking(self):
        """analyze() == analyze_begin() + admit_finish() per task: the
        windowed split-phase admission finds the same dependences."""
        ba = _grid(4)
        blocking = _Stream(_sharded(ba, 4))
        split_mgr = _sharded(ba, 4)
        pool = DescriptorPool(capacity=256)
        split_deps = []
        tds = []
        for t in range(12):
            args = (InOut(ba[t % 8, 0:4]), In(ba[(t + 1) % 8, 0:4]))
            blocking.spawn(f"t{t}", *args)
            td = pool.acquire(_noop, args)
            td.spawn_order = t
            split_mgr.analyze_begin(td)
            tds.append(td)
        pairs = split_mgr.admit_finish()
        assert [td for td, _ in pairs] == tds    # spawn order
        split_deps = [sorted(d.tid for d in deps) for _, deps in pairs]
        assert split_deps == [blocking.deps[f"t{t}"] for t in range(12)]


class TestPumpRuntimeIntegration:
    def test_dep_pump_auto_resolves_from_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_DEPMAN_THREADS", "2")
        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded")) as rt:
            assert rt.dep_pump == "threaded"
        monkeypatch.delenv("REPRO_DEPMAN_THREADS")
        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded")) as rt:
            assert rt.dep_pump == "sync"

    def test_stats_carry_wire_counters(self):
        @task(inout="x")
        def bump(x):
            return x + 1.0

        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded",
                                       dep_pump="threaded",
                                       dep_batch_lines=4)) as rt:
            A = rt.zeros((8, 8), (4, 4))
            for _ in range(4):
                bump(A[0, 0])
                bump(A[1, 1])
            rt.barrier()
            s = rt.stats()
        assert s.dep_batches is not None and s.dep_batches > 0
        assert s.dep_lines is not None and s.dep_lines > 0
        assert s.dep_batches <= s.dep_messages
        assert s.pump_wall_s is not None and s.pump_wall_s >= 0.0

    def test_central_stats_leave_wire_fields_none(self):
        @task(inout="x")
        def bump(x):
            return x + 1.0

        with TaskRuntime(RuntimeConfig(executor="staged")) as rt:
            A = rt.zeros((4, 4), (4, 4))
            bump(A[0, 0])
            rt.barrier()
            s = rt.stats()
        assert s.dep_batches is None
        assert s.dep_lines is None
        assert s.pump_wall_s is None

    def test_dep_batch_events_emitted(self):
        from repro.obs import InMemoryTracker

        @task(inout="x")
        def bump(x):
            return x + 1.0

        trk = InMemoryTracker()
        with TaskRuntime(RuntimeConfig(executor="staged",
                                       dep_manager="sharded",
                                       dep_batch_lines=4,
                                       tracker=trk)) as rt:
            A = rt.zeros((8, 8), (4, 4))
            bump(A[0, 0])
            bump(A[1, 1])
            rt.barrier()
        batches = trk.events_of("dep_batch")
        assert batches
        assert {e.data["direction"] for e in batches} == {"post", "grant"}
        assert all(e.data["lines"] >= 1 for e in batches)
        assert all(e.data["descriptors"] >= 1 for e in batches)


@pytest.mark.parametrize("app", sorted(APPS))
def test_identical_wave_schedule_across_pumps(app, monkeypatch):
    """The tentpole determinism pin: the threaded pump schedules the
    exact same waves as the synchronous one on every paper app."""
    orig = StagedExecutor._wavefronts
    schedules = {}
    for pump in ("sync", "threaded"):
        log: list = []

        def spy(self, tasks, _log=log):
            waves = orig(self, tasks)
            _log.append([tuple(t.tid for t in w) for w in waves])
            return waves

        monkeypatch.setattr(StagedExecutor, "_wavefronts", spy)
        run_app(app, "staged", app_kwargs=SIZES[app],
                dep_manager="sharded", dep_pump=pump, dep_batch_lines=4)
        schedules[pump] = log
    assert schedules["sync"] == schedules["threaded"]
    assert any(schedules["sync"])


# ---------------------------------------------------------------------------
# spawn-throughput benchmark plumbing (the bench artifact entry)
class TestSpawnThroughputBench:
    def test_run_matrix_checksums_agree(self):
        res = run_matrix(400, [1, 2, 4], grid=16, seg=4, reps=1)
        c = res["central"]
        assert c["deps_found"] > 0
        for h, r in res["sharded"].items():
            assert r["dep_checksum"] == c["dep_checksum"]
            assert r["deps_found"] == c["deps_found"]
            assert r["blocks_walked"] == c["blocks_walked"]
            assert sum(r["admissions"]) >= 400

    def test_entry_shape_is_bench_compatible(self, monkeypatch):
        import importlib.util
        import pathlib

        import benchmarks.spawn_throughput as st

        spec = importlib.util.spec_from_file_location(
            "bench_gate",
            pathlib.Path(__file__).parent.parent / "tools" / "bench_gate.py")
        gate = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(gate)

        col = {"tasks_per_s": 10.0, "dep_messages": 3.0,
               "dep_batches": 2.0, "dep_lines": 2.0, "pump_wall_s": 0.1}
        monkeypatch.setattr(
            st, "run_matrix",
            lambda n, homes, grid=64, seg=8, reps=3: {
                "tasks": n, "grid": grid, "seg": seg,
                "central": {"tasks": n, "deps_found": 1.0,
                            "blocks_walked": 2.0, "tasks_per_s": 10.0},
                "sharded": {h: dict(col) for h in homes},
                "threaded": {h: dict(col) for h in homes},
            })
        monkeypatch.setattr(
            st, "reconcile_traffic",
            lambda **kw: {"reconciled": True, "pumps_agree": True})
        e = st.entry("smoke")
        assert e["id"] == "spawn-throughput-smoke"
        doc = {"schema": gate.SCHEMA, "suite": "smoke",
               "calibration": {},
               "validation": {"checks": {}, "passed": 0, "total": 0},
               "entries": [e]}
        assert gate.validate_schema(doc) == []
