"""PP-as-task-graph: the 1F1B schedule derived by dependence analysis and
its SPMD execution."""
import subprocess
import sys

import pytest

from repro.core.pipeline import PipeTask, derive_pipeline_schedule


class TestScheduleDerivation:
    def test_optimal_clock_count(self):
        """Greedy backward-first scheduling of the BDDT DAG reaches the
        textbook 1F1B bound: 2*M + 2*(S-1) clocks."""
        for s, m in ((2, 4), (4, 8), (8, 8)):
            table = derive_pipeline_schedule(s, m)
            assert len(table) == 2 * m + 2 * (s - 1), (s, m)

    def test_dependencies_respected(self):
        table = derive_pipeline_schedule(4, 6)
        seen = set()
        for row in table:
            fired = [t for t in row if t]
            for t in fired:
                if t.kind == "F" and t.stage > 0:
                    assert PipeTask("F", t.stage - 1, t.micro) in seen
                if t.kind == "B":
                    assert PipeTask("F", t.stage, t.micro) in seen
                    if t.stage < 3:
                        assert PipeTask("B", t.stage + 1, t.micro) in seen
            seen.update(fired)
        # every task fired exactly once
        assert len(seen) == 2 * 4 * 6

    def test_weight_grad_serialized_per_stage(self):
        """INOUT dW[s] must serialize each stage's backwards (at most one
        B per stage per clock, in microbatch order)."""
        table = derive_pipeline_schedule(3, 5)
        last_micro = {s: -1 for s in range(3)}
        for row in table:
            for t in row:
                if t and t.kind == "B":
                    assert t.micro == last_micro[t.stage] + 1
                    last_micro[t.stage] = t.micro

    def test_steady_state_is_1f1b(self):
        """In the steady state the last stage alternates F,B strictly."""
        table = derive_pipeline_schedule(4, 8)
        last = [row[3] for row in table if row[3] is not None]
        kinds = "".join(t.kind for t in last)
        assert "FB" * 8 == kinds  # last stage: perfect alternation


@pytest.mark.slow
def test_pipeline_execution_matches_sequential():
    """Numerical check on 4 host devices (subprocess sets XLA_FLAGS)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core.pipeline import pipeline_step

S, M, B, D = 4, 8, 2, 16
mesh = jax.make_mesh((S,), ("stage",),
                     axis_types=(jax.sharding.AxisType.Auto,))
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (S, D, D)) * (D ** -0.5)
xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

def fwd(w, x):
    return jnp.tanh(x @ w)

def bwd(w, x, g):
    # vjp of fwd wrt (x, w)
    y, vjp = jax.vjp(lambda xx, ww: jnp.tanh(xx @ ww), x, w)
    gx, gw = vjp(g)
    return gx, gw

dw = pipeline_step(fwd, bwd, ws, xs, mesh=mesh, stage_axis="stage",
                   n_stages=S)

# sequential reference: loss = sum(stageS-1(...stage0(x))) per microbatch
def full(ws_, x):
    h = x
    for s in range(S):
        h = jnp.tanh(h @ ws_[s])
    return h.sum()

ref = sum(jax.grad(full)(ws, xs[m]) for m in range(M))
np.testing.assert_allclose(np.asarray(dw), np.asarray(ref),
                           rtol=2e-4, atol=2e-4)
print("PIPELINE-OK")
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=300)
    assert "PIPELINE-OK" in out.stdout, out.stderr[-2000:]
