"""The paper's five applications executed for real on the task runtime
(both executors), numerics asserted inside each app; plus the EP MoE on a
real multi-device mesh (subprocess)."""
import subprocess
import sys

import pytest

import sys as _sys
_sys.path.insert(0, ".")
from benchmarks.apps import APPS  # noqa: E402

from repro.core import TaskRuntime


@pytest.mark.parametrize("name", sorted(APPS))
@pytest.mark.parametrize("executor", ["staged", "host"])
def test_app_correct(name, executor):
    rt = TaskRuntime(executor=executor, n_workers=3, mpb_slots=4,
                     policy="locality")
    try:
        APPS[name](rt)          # asserts numerics internally
    finally:
        rt.shutdown()


@pytest.mark.slow
def test_moe_ep_multidevice():
    """EP all-to-all dispatch on 4 real host devices == dense reference."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import dataclasses, jax, jax.numpy as jnp, numpy as np

@dataclasses.dataclass(frozen=True)
class Cfg:
    d_model: int = 64
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 32
    n_shared_experts: int = 2
    moe_renorm: bool = True
    moe_capacity_factor: float = 8.0
    moe_impl: str = "ep"

from repro.models import moe
from repro import dist
cfg = Cfg()
p = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
ref = moe.moe_ffn_ref(p, x, cfg)
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
with dist.use_mesh(mesh):
    got = moe.moe_ffn_ep(p, x, cfg)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
# gradients flow through the all_to_all
def loss(pp):
    with dist.use_mesh(mesh):
        return (moe.moe_ffn_ep(pp, x, cfg) ** 2).sum()
g = jax.grad(loss)(p)
total = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
assert total > 0
print("MOE-EP-OK")
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=300)
    assert "MOE-EP-OK" in out.stdout, out.stderr[-2000:]
