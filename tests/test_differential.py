"""Differential task-graph fuzzing: four executors, one answer.

Every pinned seed in ``fuzz_graphs.SEEDS`` generates a random task
program (mixed footprints, overlapping regions, firstprivate indices,
mixed dtypes, uneven waves) and replays it on

* ``sequential``           — the eager oracle,
* ``staged``               — wavefront vmap batching,
* ``sharded``              — home-aware dispatch (single-device fallback
  in this suite; the mesh path is pinned in ``test_sharded.py``),
* ``staged`` + ``kernel_backend="pallas"`` — the fused wave-kernel
  backend, including its automatic XLA fallbacks (mixed-dtype and
  single-task groups occur naturally in the generated programs).

Outputs must be bit-identical across all four, and the dependence
counters (``tasks_spawned``/``deps_found``/``blocks_walked``) identical
across the three deferred executors — the discipline of validating the
optimized path against a reference oracle on *generated* programs, not
just hand-picked pins (Myrmics' reference-vs-optimized methodology).

A failing seed replays exactly: ``python -m tests.fuzz_graphs <seed>``.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import fuzz_graphs
from fuzz_graphs import SEEDS, compare_paths, generate, run_case


def test_seed_corpus_is_pinned():
    # the acceptance bar: at least 50 seeds, committed, stable
    assert len(SEEDS) >= 50
    assert len(set(SEEDS)) == len(SEEDS)


def test_generator_is_deterministic():
    for seed in SEEDS[:10]:
        assert generate(seed) == generate(seed)


def test_generator_covers_the_op_mix():
    """The corpus actually exercises what it claims: multi-tile regions,
    firstprivate indices, the mixed-dtype op, and task counts that vary
    (uneven waves)."""
    ops = set()
    sizes = set()
    for seed in SEEDS:
        steps = generate(seed)
        sizes.add(len(steps))
        ops.update(s[0] for s in steps)
    assert ops == set(fuzz_graphs._OPS)
    assert len(sizes) > 3


@pytest.mark.parametrize("seed", SEEDS)
def test_all_paths_agree(seed):
    stats = compare_paths(seed)
    # the pallas path must actually engage the wave-kernel layer: every
    # group either fused or took a *named* fallback
    pallas = stats["staged+pallas"]
    assert pallas.kernel_dispatches is not None
    assert pallas.kernel_dispatches + pallas.kernel_fallbacks > 0


def test_pallas_path_fuses_somewhere_in_corpus():
    """Across the corpus the fused path is really taken (not 100%
    fallback) — guards against an eligibility regression that silently
    turns the backend into a no-op while numerics still pass."""
    fused = 0
    for seed in SEEDS[:12]:
        _, stats = run_case(seed, executor="staged",
                            kernel_backend="pallas")
        fused += stats.kernel_dispatches
    assert fused > 0


@pytest.mark.parametrize("seed", SEEDS)
def test_threaded_pump_matches_sync(seed):
    """The concurrent home-manager pump is invisible: every pinned seed
    run under ``dep_pump="threaded"`` produces bit-identical outputs and
    identical dependence *and wire* counts to the synchronous pump — the
    flush policy depends only on the logical descriptor stream, never on
    pump-thread timing."""
    out_s, st_s = run_case(seed, executor="staged", dep_manager="sharded",
                           dep_pump="sync")
    out_t, st_t = run_case(seed, executor="staged", dep_manager="sharded",
                           dep_pump="threaded")
    for name, want in out_s.items():
        assert out_t[name].dtype == want.dtype, f"seed {seed}: {name}"
        assert np.array_equal(out_t[name], want), f"seed {seed}: {name}"
    for fld in ("tasks_spawned", "deps_found", "blocks_walked",
                "dep_messages", "dep_batches", "dep_lines"):
        assert getattr(st_t, fld) == getattr(st_s, fld), \
            f"seed {seed}: {fld} differs across pump modes"


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=1000, max_value=10_000_000))
def test_property_unpinned_seeds(seed):
    """Property form of the same contract on seeds *outside* the pinned
    corpus — runs under real hypothesis when installed (CI) and under the
    deterministic stub in hermetic containers (same assertion surface
    either way; ``conftest.py`` guarantees the stub never shadows the
    real package)."""
    ref_out, _ = run_case(seed, executor="sequential")
    out, stats = run_case(seed, executor="staged", kernel_backend="pallas")
    for name, want in ref_out.items():
        assert np.array_equal(out[name], want), f"seed {seed}: {name}"
    assert stats.kernel_dispatches + stats.kernel_fallbacks > 0
