import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# hypothesis: the real package whenever it is installed (CI installs it),
# the deterministic stub only in hermetic containers.  Decide from
# find_spec, not try/except import — an already-registered stub module in
# sys.modules would make a bare import succeed and silently shadow a real
# installation.
HYPOTHESIS_IS_STUB = importlib.util.find_spec("hypothesis") is None
if HYPOTHESIS_IS_STUB:
    import _hypothesis_stub
    _hypothesis_stub.install()

import hypothesis  # noqa: E402

assert getattr(hypothesis, "IS_REPRO_STUB", False) == HYPOTHESIS_IS_STUB, (
    "the hypothesis stub is shadowing the real hypothesis package "
    f"(stub active: {getattr(hypothesis, 'IS_REPRO_STUB', False)}, "
    f"real package installed: {not HYPOTHESIS_IS_STUB})")
