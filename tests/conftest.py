import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

try:
    import hypothesis  # noqa: F401
except ImportError:                      # hermetic container: use the stub
    import _hypothesis_stub
    _hypothesis_stub.install()
