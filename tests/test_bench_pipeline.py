"""The machine-readable benchmark pipeline: BENCH JSON schema validation,
the regression gate's direction rules, and the committed baseline staying
a valid, gate-consumable artifact."""
import copy
import importlib.util
import json
import pathlib

import pytest

import sys
sys.path.insert(0, ".")
from benchmarks.run import SCHEMA, SUITES  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", ROOT / "tools" / "bench_gate.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def gate():
    return _load_gate()


def _minimal_doc():
    return {
        "schema": SCHEMA,
        "suite": "smoke",
        "wall_s": 1.0,
        "env": {"python": "3.11.0", "jax": "0.4.37"},
        "calibration": {"dram_base_cycles": 256.0},
        "entries": [
            {"id": "app/matmul", "kind": "app", "info": {"wall_s": 0.5},
             "metrics": {"tasks": 64, "sim_predicted_s": 0.016,
                         "cross_home_bytes": 196608,
                         "grouped_dispatches": 4}},
            {"id": "scalability/matmul", "kind": "scalability",
             "checkpoints": [{"workers": 1, "speedup": 1.0}],
             "info": {}, "metrics": {"speedup_w43": 29.0}},
        ],
        "validation": {"checks": {"ok": True}, "passed": 1, "total": 1},
    }


class TestSchema:
    def test_minimal_doc_is_valid(self, gate):
        assert gate.validate_schema(_minimal_doc()) == []

    @pytest.mark.parametrize("mutate, expect", [
        (lambda d: d.update(schema="nope"), "schema"),
        (lambda d: d.pop("suite"), "suite"),
        (lambda d: d.pop("calibration"), "calibration"),
        (lambda d: d.pop("validation"), "validation"),
        (lambda d: d.update(entries=[]), "entries"),
        (lambda d: d["entries"][0].pop("id"), "id"),
        (lambda d: d["entries"][0].pop("metrics"), "metrics"),
        (lambda d: d["entries"][0]["metrics"].update(bad=True),
         "not a finite"),
        (lambda d: d["entries"][0]["metrics"].update(bad=float("nan")),
         "not a finite"),
        (lambda d: d["entries"][1].update(id="app/matmul"), "duplicate"),
    ])
    def test_broken_docs_are_flagged(self, gate, mutate, expect):
        doc = _minimal_doc()
        mutate(doc)
        problems = gate.validate_schema(doc)
        assert problems and any(expect in p for p in problems), problems


class TestKernelBackendBlock:
    """The kernel-backend sweep entries: info-only wall clocks, gated
    deterministic dispatch/fallback counts."""

    def _doc_with_sweep(self):
        doc = _minimal_doc()
        doc["entries"].append({
            "id": "kernel_backend/matmul", "kind": "kernel_backend",
            "info": {"wall_s_xla": 0.4, "wall_s_pallas": 0.6},
            "metrics": {"kernel_dispatches": 4, "kernel_fallbacks": 0,
                        "waves": 4, "grouped_dispatches": 4}})
        return doc

    def test_valid_sweep_block_passes(self, gate):
        doc = self._doc_with_sweep()
        assert gate.validate_kernel_backend(doc) == []
        assert gate.validate_schema(doc) == []

    def test_doc_without_sweep_entries_is_valid(self, gate):
        assert gate.validate_kernel_backend(_minimal_doc()) == []

    @pytest.mark.parametrize("mutate, expect", [
        (lambda e: e["metrics"].pop("kernel_dispatches"),
         "kernel_dispatches"),
        (lambda e: e["metrics"].update(kernel_fallbacks=-1),
         "kernel_fallbacks"),
        (lambda e: e["metrics"].update(kernel_dispatches=3.5),
         "kernel_dispatches"),
        (lambda e: e["metrics"].update(kernel_fallbacks=True),
         "kernel_fallbacks"),
        (lambda e: e["info"].pop("wall_s_pallas"), "wall_s_pallas"),
        (lambda e: e["info"].update(wall_s_xla=float("inf")),
         "wall_s_xla"),
        (lambda e: e["info"].update(wall_s_xla=-0.1), "wall_s_xla"),
    ])
    def test_broken_sweep_blocks_are_flagged(self, gate, mutate, expect):
        doc = self._doc_with_sweep()
        mutate(doc["entries"][-1])
        problems = gate.validate_kernel_backend(doc)
        assert problems and any(expect in p for p in problems), problems

    def test_fallback_count_drift_is_two_sided(self, gate):
        """A fallback appearing where the baseline fused (or vice versa)
        trips the determinism gate in either direction — eligibility
        regressions can't hide as 'fewer dispatches, still passes'."""
        assert gate._rule("kernel_fallbacks") == "two_sided"
        assert gate._rule("kernel_dispatches") == "two_sided"
        doc = self._doc_with_sweep()
        new = copy.deepcopy(doc)
        new["entries"][-1]["metrics"]["kernel_fallbacks"] = 2
        new["entries"][-1]["metrics"]["kernel_dispatches"] = 2
        problems = gate.compare(doc, new)
        assert {p["metric"] for p in problems} == {
            "kernel_dispatches", "kernel_fallbacks"}

    def test_wall_clock_drift_is_never_gated(self, gate):
        doc = self._doc_with_sweep()
        new = copy.deepcopy(doc)
        new["entries"][-1]["info"]["wall_s_pallas"] = 60.0
        assert gate.compare(doc, new) == []


class TestServingBlock:
    """The streaming-serving entry: deterministic admission counters
    gated two-sided, open-loop latency info-only, and the ledger
    invariants (admitted + rejected == submitted, peak <= budget)
    enforced structurally on every artifact."""

    def _doc_with_serving(self):
        doc = _minimal_doc()
        doc["entries"].append({
            "id": "serving-smoke", "kind": "serving",
            "info": {"suite": "smoke", "capacity": 4,
                     "rates": {"200": {"p50_ms": 3.0, "p99_ms": 9.0,
                                       "throughput_rps": 190.0}}},
            "metrics": {"submitted": 96.0, "admitted": 48.0,
                        "rejected": 48.0,
                        "peak_in_flight_bytes": 17408.0,
                        "budget_bytes": 17408.0}})
        return doc

    def test_valid_serving_block_passes(self, gate):
        doc = self._doc_with_serving()
        assert gate.validate_serving(doc) == []
        assert gate.validate_schema(doc) == []

    def test_doc_without_serving_entries_is_valid(self, gate):
        assert gate.validate_serving(_minimal_doc()) == []

    @pytest.mark.parametrize("mutate, expect", [
        (lambda e: e["metrics"].pop("submitted"), "submitted"),
        (lambda e: e["metrics"].update(admitted=-1), "admitted"),
        (lambda e: e["metrics"].update(rejected=3.5), "rejected"),
        (lambda e: e["metrics"].update(admitted=49.0), "ledger leaks"),
        (lambda e: e["metrics"].update(peak_in_flight_bytes=17409.0),
         "exceeds the budget"),
        (lambda e: e["info"].pop("rates"), "rates"),
        (lambda e: e["info"]["rates"]["200"].update(
            p99_ms=float("inf")), "p99_ms"),
        (lambda e: e["info"]["rates"]["200"].update(
            throughput_rps=-1.0), "throughput_rps"),
    ])
    def test_broken_serving_blocks_are_flagged(self, gate, mutate, expect):
        doc = self._doc_with_serving()
        mutate(doc["entries"][-1])
        problems = gate.validate_serving(doc)
        assert problems and any(expect in p for p in problems), problems

    def test_admission_count_drift_is_two_sided(self, gate):
        """An admission split changing under the same budget means the
        controller (or the workload) changed — fails in both directions,
        it can't hide as 'fewer rejections, still passes'."""
        assert gate._rule("submitted") == "two_sided"
        assert gate._rule("admitted") == "two_sided"
        assert gate._rule("peak_in_flight_bytes") == "higher_is_worse"
        doc = self._doc_with_serving()
        new = copy.deepcopy(doc)
        new["entries"][-1]["metrics"]["admitted"] = 96.0
        new["entries"][-1]["metrics"]["rejected"] = 0.0
        problems = gate.compare(doc, new)
        assert {p["metric"] for p in problems} == {"admitted", "rejected"}

    def test_latency_drift_is_never_gated(self, gate):
        doc = self._doc_with_serving()
        new = copy.deepcopy(doc)
        new["entries"][-1]["info"]["rates"]["200"]["p99_ms"] = 500.0
        assert gate.compare(doc, new) == []

    def test_serving_profiles_cover_every_suite(self):
        from benchmarks.serving import PROFILES
        assert set(PROFILES) == set(SUITES)


class TestDirectionRules:
    def test_rules(self, gate):
        assert gate._rule("speedup_w43") == "lower_is_worse"
        assert gate._rule("peak_speedup") == "lower_is_worse"
        assert gate._rule("sim_predicted_s") == "higher_is_worse"
        assert gate._rule("cross_home_bytes") == "higher_is_worse"
        assert gate._rule("idle_frac") == "higher_is_worse"
        assert gate._rule("busy_cv") == "higher_is_worse"
        assert gate._rule("tasks") == "two_sided"
        assert gate._rule("fig4_32_vs_1") == "two_sided"
        # single-MC pathology metrics are determinism checks: drift in
        # either direction means the contention model changed
        assert gate._rule("speedup_single_mc") == "two_sided"
        assert gate._rule("sim_predicted_single_mc_s") == "two_sided"

    def test_weakened_contention_model_trips_the_gate(self, gate):
        """A model change that erodes the single-MC pathology (single-MC
        speedup *rising*) must fail, not pass as an 'improvement'."""
        doc = _minimal_doc()
        doc["entries"][1]["metrics"]["speedup_single_mc"] = 1.7
        new = copy.deepcopy(doc)
        new["entries"][1]["metrics"]["speedup_single_mc"] = 4.0
        (p,) = gate.compare(doc, new)
        assert p["metric"] == "speedup_single_mc"
        assert p["rule"] == "two_sided"


class TestCompare:
    def test_identical_docs_pass(self, gate):
        doc = _minimal_doc()
        assert gate.compare(doc, copy.deepcopy(doc)) == []

    def test_within_threshold_passes(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["entries"][0]["metrics"]["sim_predicted_s"] *= 1.15
        new["entries"][1]["metrics"]["speedup_w43"] *= 0.85
        assert gate.compare(doc, new) == []

    def test_slower_prediction_regresses(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["entries"][0]["metrics"]["sim_predicted_s"] *= 1.5
        (p,) = gate.compare(doc, new)
        assert p["metric"] == "sim_predicted_s"
        assert p["rule"] == "higher_is_worse"

    def test_faster_prediction_is_fine(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["entries"][0]["metrics"]["sim_predicted_s"] *= 0.5
        assert gate.compare(doc, new) == []

    def test_speedup_drop_regresses_rise_does_not(self, gate):
        doc = _minimal_doc()
        worse, better = copy.deepcopy(doc), copy.deepcopy(doc)
        worse["entries"][1]["metrics"]["speedup_w43"] *= 0.5
        better["entries"][1]["metrics"]["speedup_w43"] *= 1.5
        assert gate.compare(doc, worse)
        assert gate.compare(doc, better) == []

    def test_count_drift_is_two_sided(self, gate):
        doc = _minimal_doc()
        for factor in (0.5, 2.0):
            new = copy.deepcopy(doc)
            new["entries"][0]["metrics"]["tasks"] = int(64 * factor)
            (p,) = gate.compare(doc, new)
            assert p["rule"] == "two_sided"

    def test_zero_baseline_flags_any_nonzero(self, gate):
        doc = _minimal_doc()
        doc["entries"][0]["metrics"]["cross_home_bytes"] = 0
        new = copy.deepcopy(doc)
        new["entries"][0]["metrics"]["cross_home_bytes"] = 1024
        assert gate.compare(doc, new)

    def test_disappearing_entry_and_metric_regress(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        del new["entries"][1]
        assert any(p["rule"] == "entry disappeared"
                   for p in gate.compare(doc, new))
        new = copy.deepcopy(doc)
        del new["entries"][0]["metrics"]["tasks"]
        assert any(p["rule"] == "metric disappeared"
                   for p in gate.compare(doc, new))

    def test_new_entries_pass_until_blessed(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["entries"].append({"id": "app/extra", "kind": "app",
                               "info": {}, "metrics": {"tasks": 1}})
        assert gate.compare(doc, new) == []

    def test_suite_mismatch_refuses(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["suite"] = "paper"
        (p,) = gate.compare(doc, new)
        assert p["metric"] == "suite"

    def test_threshold_is_tunable(self, gate):
        doc = _minimal_doc()
        new = copy.deepcopy(doc)
        new["entries"][0]["metrics"]["sim_predicted_s"] *= 1.15
        assert gate.compare(doc, new, threshold=0.10)
        assert gate.compare(doc, new, threshold=0.20) == []


class TestCommittedBaseline:
    """The committed baseline must stay a valid artifact the CI gate can
    consume, and must describe the suite the CI bench job actually runs."""

    BASELINE = ROOT / "benchmarks" / "BASELINE_BENCH.json"

    def test_baseline_exists_and_is_schema_valid(self, gate):
        assert self.BASELINE.is_file(), \
            "benchmarks/BASELINE_BENCH.json missing — run " \
            "`python -m benchmarks.run --suite smoke --emit BENCH_4.json`" \
            " then `python tools/bench_gate.py BENCH_4.json --update`"
        doc = json.loads(self.BASELINE.read_text())
        assert gate.validate_schema(doc) == []
        assert doc["suite"] == "smoke"

    def test_baseline_covers_all_apps_and_sweeps(self, gate):
        doc = json.loads(self.BASELINE.read_text())
        ids = {e["id"] for e in doc["entries"]}
        for app in ("black_scholes", "matmul", "fft", "jacobi",
                    "cholesky"):
            assert f"app/{app}" in ids
            assert f"scalability/{app}" in ids
        assert "granularity" in ids and "microbench" in ids

    def test_baseline_validation_was_green(self):
        doc = json.loads(self.BASELINE.read_text())
        assert doc["validation"]["passed"] == doc["validation"]["total"]


class TestSuiteProfiles:
    def test_profiles_declare_every_knob(self):
        for name, cfg in SUITES.items():
            assert {"worker_counts", "workload_sizes", "granularity",
                    "app_sizes", "app_workers", "paper_ranges",
                    "owner_skew"} <= set(cfg), name

    def test_owner_override_on_in_paper_profile_only(self):
        """The paper suite reports striped vs striped+override; the CI
        smoke profile keeps the override off so its baseline stays
        minimal."""
        assert SUITES["smoke"]["owner_skew"] == 0.0
        assert SUITES["paper"]["owner_skew"] > 1.0

    def test_smoke_is_smaller_than_paper(self):
        smoke = SUITES["smoke"]
        assert smoke["workload_sizes"]["matmul"]["n"] < 1024
        assert smoke["app_sizes"]["matmul"]["n"] < 256
        assert not smoke["paper_ranges"]
        assert SUITES["paper"]["paper_ranges"]
