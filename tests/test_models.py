"""Model-component unit tests: chunked-vs-recurrent equivalence for SSM
blocks, MoE routing paths, MLA absorbed decode, RoPE properties."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro import dist


@dataclasses.dataclass(frozen=True)
class _MambaCfg:
    d_model: int = 64
    ssm_d_inner: int = 128
    ssm_state: int = 16
    ssm_heads: int = 4
    ssm_d_conv: int = 4
    ssm_chunk: int = 8


@dataclasses.dataclass(frozen=True)
class _XlstmCfg:
    d_model: int = 64
    n_heads: int = 4
    xlstm_d_inner: int = 128
    xlstm_d_conv: int = 4
    xlstm_chunk: int = 8


class TestMamba:
    def test_chunked_equals_recurrent(self):
        from repro.models import mamba
        cfg = _MambaCfg()
        p = mamba.init_mamba(jax.random.PRNGKey(0), cfg)
        u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        ref = mamba.mamba_recurrent_ref(p, u, cfg)
        got = mamba.mamba_chunked(p, u, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=8, deadline=None)
    @given(split=st.integers(8, 24))
    def test_streaming_state_handoff(self, split):
        from repro.models import mamba
        cfg = _MambaCfg()
        p = mamba.init_mamba(jax.random.PRNGKey(0), cfg)
        u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
        full = mamba.mamba_chunked(p, u, cfg)
        o1, state, cs = mamba.mamba_chunked(p, u[:, :split], cfg,
                                            return_state=True)
        o2 = mamba.mamba_chunked(p, u[:, split:], cfg, state=state,
                                 conv_state=cs)
        got = jnp.concatenate([o1, o2], 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=3e-4, atol=3e-4)


class TestXlstm:
    def test_mlstm_chunked_equals_recurrent(self):
        from repro.models import xlstm
        cfg = _XlstmCfg()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg)
        u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        ref = xlstm.mlstm_recurrent_ref(p, u, cfg)
        got = xlstm.mlstm_chunked(p, u, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_slstm_streaming(self):
        from repro.models import xlstm
        cfg = _XlstmCfg()
        p = xlstm.init_slstm(jax.random.PRNGKey(2), cfg)
        u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        full = xlstm.slstm_scan(p, u, cfg)
        o1, state = xlstm.slstm_scan(p, u[:, :16], cfg, return_state=True)
        o2, _ = xlstm.slstm_decode(p, u[:, 16:], cfg, state)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(full),
            rtol=1e-5, atol=1e-5)

    def test_mlstm_stability_long_context(self):
        """Gates saturated near 1 must not overflow over long sequences
        (the stabilizer's job)."""
        from repro.models import xlstm
        cfg = _XlstmCfg()
        p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg)
        u = 3.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 256, 64))
        out = xlstm.mlstm_chunked(p, u, cfg)
        assert bool(jnp.isfinite(out).all())


@dataclasses.dataclass(frozen=True)
class _MoeCfg:
    d_model: int = 64
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 32
    n_shared_experts: int = 0
    moe_renorm: bool = True
    moe_capacity_factor: float = 8.0
    moe_impl: str = "ep"


class TestMoE:
    def test_local_equals_ref_dropfree(self):
        from repro.models import moe
        cfg = _MoeCfg()
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        ref = moe.moe_ffn_ref(p, x, cfg)
        got = moe.moe_ffn_ep(p, x, cfg)      # no mesh -> local path
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_shared_experts(self):
        from repro.models import moe
        cfg = dataclasses.replace(_MoeCfg(), n_shared_experts=2)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
        ref = moe.moe_ffn_ref(p, x, cfg)
        got = moe.moe_ffn_ep(p, x, cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_capacity_drops_tokens(self):
        """With a tiny capacity factor, outputs differ from the drop-free
        reference for some tokens (drops happen) but stay finite."""
        from repro.models import moe
        cfg = dataclasses.replace(_MoeCfg(), moe_capacity_factor=0.3)
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
        got = moe.moe_ffn_ep(p, x, cfg)
        ref = moe.moe_ffn_ref(p, x, cfg)
        assert bool(jnp.isfinite(got).all())
        assert float(jnp.abs(got - ref).max()) > 1e-3

    def test_load_balance_loss_uniform_is_one(self):
        from repro.models import moe
        cfg = _MoeCfg()
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        # router weights ~0 -> uniform gates -> loss ~ E * E * (1/E * 1/E)
        p = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64))
        ll = moe.load_balance_loss(p, x, cfg)
        assert 0.9 < float(ll) < 1.1


class TestMLA:
    def _cfg(self):
        from repro.configs import get_config
        return get_config("deepseek-v2-lite-16b").reduced()

    def test_absorbed_decode_matches_materialized(self):
        """The latent-space decode must equal materializing K/V."""
        from repro.models import mla
        cfg = self._cfg()
        p = mla.init_mla(jax.random.PRNGKey(0), cfg)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(1),
                                    (2, 9, cfg.d_model))
        positions = jnp.arange(9)[None, :].repeat(2, 0)
        full = mla.mla_train(p, x, cfg, positions)
        _, cache = mla.mla_prefill(p, x[:, :8], cfg, positions[:, :8])
        # pad cache to length 9 and decode token 8
        cache = {k: jnp.pad(v, ((0, 0), (0, 1), (0, 0)))
                 for k, v in cache.items()}
        out, _ = mla.mla_decode(p, x[:, 8:9], cfg, cache, jnp.int32(8))
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(full[:, 8]),
                                   rtol=2e-3, atol=2e-3)


class TestRoPE:
    def test_rope_preserves_norm(self):
        from repro.models.rope import apply_rope
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 8, 64))
        pos = jnp.arange(8)[None]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(y, axis=-1)),
            np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)

    def test_rope_relative_shift_invariance(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        from repro.models.rope import apply_rope
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
        def dot_at(i, j):
            qi = apply_rope(q, jnp.array([[i]]))
            kj = apply_rope(k, jnp.array([[j]]))
            return float(jnp.sum(qi * kj))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)

    def test_mrope_sections_match_rope_when_equal_positions(self):
        from repro.models.rope import apply_mrope, apply_rope
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 8, 64))
        pos = jnp.arange(8)[None]
        pos3 = jnp.broadcast_to(pos[None], (3, 1, 8))
        y1 = apply_rope(x, pos)
        y2 = apply_mrope(x, pos3, (8, 12, 12))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
