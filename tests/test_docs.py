"""The documentation layer stays alive.

Runs the same intra-repo link check as CI's docs job
(tools/check_links.py), and pins the README's executor table to the
runtime's actual executor registry so a new executor cannot ship
undocumented.
"""
import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_links", ROOT / "tools" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_required_docs_exist():
    assert (ROOT / "README.md").is_file()
    assert (ROOT / "docs" / "ARCHITECTURE.md").is_file()


def test_no_broken_intra_repo_links():
    checker = _load_checker()
    bad = checker.check(ROOT)
    assert not bad, "broken documentation links:\n" + "\n".join(
        f"  {f}: {target}" for f, target in bad)


def test_file_line_anchors_are_checked(tmp_path):
    """check_links validates `file.py:line` anchors: missing files and
    out-of-range line numbers fail, valid anchors (full path or bare
    basename) pass."""
    checker = _load_checker()
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text(
        "bare basename anchor: `x.py:3`", encoding="utf-8")
    (tmp_path / "README.md").write_text(
        "good `tools/x.py:2`, missing `gone.py:5`, stale `x.py:99`, "
        "and fenced ones never count:\n```\n`fenced.py:1`\n```\n",
        encoding="utf-8")
    (tmp_path / "tools").mkdir()
    (tmp_path / "tools" / "x.py").write_text("a\nb\nc\n", encoding="utf-8")

    msgs = [t for _, t in checker.check_anchors(tmp_path)]
    assert any("`gone.py:5`" in m and "no such file" in m for m in msgs)
    assert any("`x.py:99`" in m and "out of range" in m for m in msgs)
    assert len(msgs) == 2, msgs        # the valid + fenced anchors pass


def test_readme_documents_every_executor():
    """Every executor the runtime registers must appear in the README's
    executor table (and nothing in the table may be stale)."""
    from repro import EXECUTORS

    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    for name in EXECUTORS:
        assert f'`"{name}"`' in readme, \
            f'executor "{name}" is not documented in README.md'


def test_architecture_names_every_core_module():
    """The paper-to-code map must reference each runtime module."""
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text(encoding="utf-8")
    for mod in ("api", "blocks", "deps", "graph", "mpb", "scheduler",
                "executor", "sharded", "placement", "costmodel", "sim"):
        assert f"{mod}.py" in arch, \
            f"docs/ARCHITECTURE.md does not mention core module {mod}.py"
