"""Per-architecture smoke tests: reduced configs of each family run one
forward/train step (and a prefill+decode consistency check) on CPU,
asserting output shapes and finiteness."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import api

SEQ = 32
BATCH = 2


def _reduced(arch_id):
    cfg = get_config(arch_id).reduced()
    return cfg


def _batch(cfg, seq=SEQ, batch=BATCH, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                        cfg.vocab_size)}
    if cfg.vision_seq:
        out["vision_embeds"] = jax.random.normal(
            ks[1], (batch, cfg.vision_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
    if cfg.family == "audio":
        out["enc_frames"] = jax.random.normal(
            ks[2], (batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_loss(arch_id):
    cfg = _reduced(arch_id)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    n = api.count_params(params)
    assert n > 0
    batch = _batch(cfg)
    loss = api.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: loss not finite"
    # a plausible CE for random init: ~log(padded_vocab) +- slack
    assert 1.0 < float(loss) < 3 * np.log(cfg.padded_vocab)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads(arch_id):
    cfg = _reduced(arch_id)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_consistency(arch_id):
    """Teacher-forcing: decode step at position S must reproduce the
    full-forward logits for the same next token."""
    cfg = _reduced(arch_id)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    tokens = batch["tokens"]

    # full forward over S+1 tokens: logits at position S-1 predict token S
    nxt = jax.random.randint(jax.random.PRNGKey(9), (BATCH, 1), 0,
                             cfg.vocab_size)
    full_batch = dict(batch, tokens=jnp.concatenate([tokens, nxt], 1))
    logits_full = api.forward_logits(params, cfg, full_batch)

    # prefill on S tokens, then decode token S
    _, caches = api.prefill_step(params, cfg, batch)
    caches = api.pad_caches(caches, SEQ + 8)
    logits_dec, _ = api.decode_step(params, cfg, nxt, caches,
                                    jnp.int32(SEQ))
    want = np.asarray(logits_full[:, SEQ], np.float32)
    got = np.asarray(logits_dec[:, 0], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3,
                               err_msg=arch_id)


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a
        # exact published dims spot-checks
    assert get_config("qwen2-vl-72b").d_model == 8192
    assert get_config("command-r-35b").vocab_size == 256000
    assert get_config("deepseek-v2-lite-16b").kv_lora_rank == 512
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("nemotron-4-15b").act == "relu2"


def test_input_specs_cells():
    from repro.configs import applicable_shapes, input_specs
    total = 0
    for a in ARCH_IDS:
        cfg = get_config(a)
        shapes = applicable_shapes(cfg)
        if cfg.sub_quadratic:
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        for s in shapes:
            specs = input_specs(cfg, s)
            assert specs
            total += 1
    assert total == 32  # 10 archs x 3 + 2 long_500k
