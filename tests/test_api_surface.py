"""The canonical public API surface, pinned.

Three guarantees: (a) the re-export surfaces of ``repro``,
``repro.core`` and ``repro.serve`` are exact snapshots — a name
appearing or vanishing is a deliberate, reviewed change to this file;
(b) the typed choice enums are the single source for every stringly
config field, equivalent to (and normalized alongside) plain strings;
(c) footprint access modes coerce uniformly everywhere a mode is
accepted (``@task`` kwargs, ``wait_on``, dependence queries) with one
shared error message.
"""
import numpy as np
import pytest

import repro
import repro.core
import repro.serve
from repro import (AccessMode, DEP_MANAGERS, DEP_PUMPS, EXECUTORS,
                   ExecutorKind, In, InOut, KERNEL_BACKENDS, KernelBackend,
                   Out, PLACEMENTS, PlacementKind, RuntimeConfig,
                   RuntimeStats, SCHEDULING_POLICIES, SchedulingPolicy,
                   TaskRuntime, task, wait_on)
from repro.core.api import DepManagerKind, DepPumpKind, _ChoiceEnum
from repro.core.blocks import coerce_mode

REPRO_ALL = [
    "TaskRuntime", "task", "wait_on", "current_runtime",
    "BlockArray", "Region", "AccessMode", "In", "Out", "InOut",
    "RuntimeConfig", "RuntimeStats", "STATS_SCHEMA", "TaskFuture",
    "ExecutorKind", "DepManagerKind", "DepPumpKind", "SchedulingPolicy",
    "PlacementKind", "KernelBackend", "EXECUTORS", "DEP_MANAGERS",
    "DEP_PUMPS", "SCHEDULING_POLICIES", "PLACEMENTS", "KERNEL_BACKENDS",
    "Executor",
    "__version__",
]

CORE_ALL = REPRO_ALL[:-1] + ["coerce_mode", "ShardedDependenceManager"]

SERVE_ALL = ["Session", "ServeConfig", "RequestHandle",
             "AdmissionController", "RequestRejected", "footprint_nbytes"]


class TestSurfaceSnapshots:
    def test_repro_all_is_pinned(self):
        assert sorted(repro.__all__) == sorted(REPRO_ALL)

    def test_core_all_is_pinned(self):
        assert sorted(repro.core.__all__) == sorted(CORE_ALL)

    def test_serve_all_is_pinned(self):
        assert sorted(repro.serve.__all__) == sorted(SERVE_ALL)

    @pytest.mark.parametrize("mod", [repro, repro.core, repro.serve])
    def test_every_exported_name_resolves(self, mod):
        for name in mod.__all__:
            assert getattr(mod, name, None) is not None, \
                f"{mod.__name__}.{name} is exported but missing"

    def test_top_level_reexports_core_objects(self):
        for name in REPRO_ALL:
            if name == "__version__":
                continue
            assert getattr(repro, name) is getattr(repro.core, name), name


class TestTypedChoices:
    REGISTRY = {
        "executor": (ExecutorKind, EXECUTORS),
        "dep_manager": (DepManagerKind, DEP_MANAGERS),
        "dep_pump": (DepPumpKind, DEP_PUMPS),
        "policy": (SchedulingPolicy, SCHEDULING_POLICIES),
        "placement": (PlacementKind, PLACEMENTS),
        "kernel_backend": (KernelBackend, KERNEL_BACKENDS),
    }

    def test_choices_cover_every_stringly_field(self):
        assert set(RuntimeConfig.CHOICES) == set(self.REGISTRY)
        for fld, (enum_cls, values) in self.REGISTRY.items():
            cfg_cls, cfg_values = RuntimeConfig.CHOICES[fld]
            assert cfg_cls is enum_cls and cfg_values == values

    def test_enum_values_match_runtime_registries(self):
        from repro.core.placement import PLACEMENTS as placement_fns
        from repro.core.scheduler import POLICIES as policy_fns
        assert set(SCHEDULING_POLICIES) == set(policy_fns)
        assert set(PLACEMENTS) == set(placement_fns)
        assert set(EXECUTORS) == {"sequential", "host", "staged", "sim",
                                  "sharded"}
        assert set(DEP_MANAGERS) == {"central", "sharded"}
        assert set(DEP_PUMPS) == {"auto", "sync", "threaded"}
        assert set(KERNEL_BACKENDS) == {"xla", "pallas"}

    @pytest.mark.parametrize("enum_cls, values", [
        (ExecutorKind, EXECUTORS), (DepManagerKind, DEP_MANAGERS),
        (DepPumpKind, DEP_PUMPS),
        (SchedulingPolicy, SCHEDULING_POLICIES),
        (PlacementKind, PLACEMENTS), (KernelBackend, KERNEL_BACKENDS),
    ])
    def test_members_are_their_string_values(self, enum_cls, values):
        assert tuple(m.value for m in enum_cls) == values
        for m in enum_cls:
            assert isinstance(m, str) and m == m.value
            assert str(m) == m.value              # not 'Kind.MEMBER'
            assert isinstance(m, _ChoiceEnum)

    def test_enum_and_string_configs_are_equivalent(self):
        a = RuntimeConfig(executor=ExecutorKind.STAGED,
                          policy=SchedulingPolicy.LOCALITY).validate()
        b = RuntimeConfig(executor="staged", policy="locality").validate()
        assert a.executor == b.executor == "staged"
        assert a.policy == b.policy == "locality"
        # validate() normalizes members to plain strings
        assert not isinstance(a.executor, ExecutorKind)
        assert type(a.executor) is str

    def test_enum_config_runs(self):
        with TaskRuntime(executor=ExecutorKind.SEQUENTIAL) as rt:
            A = rt.zeros((4, 4), (2, 2))
            assert rt.executor_kind == "sequential"
            assert A is not None

    @pytest.mark.parametrize("field, bad", [
        ("executor", "quantum"), ("dep_manager", "none"),
        ("dep_pump", "fibers"), ("policy", "lifo"),
        ("placement", "everywhere"), ("kernel_backend", "cuda"),
    ])
    def test_invalid_choice_names_the_alternatives(self, field, bad):
        with pytest.raises(ValueError) as e:
            RuntimeConfig(**{field: bad}).validate()
        msg = str(e.value)
        assert field in msg and bad in msg
        for alternative in dict(self.REGISTRY)[field][1]:
            assert alternative in msg


class TestModeCoercion:
    @pytest.mark.parametrize("spec, want", [
        ("in", "in"), ("out", "out"), ("inout", "inout"),
        (In, "in"), (Out, "out"), (InOut, "inout"),
        (AccessMode.IN, "in"), (AccessMode.OUT, "out"),
        (AccessMode.INOUT, "inout"),
    ])
    def test_coerce_mode(self, spec, want):
        assert coerce_mode(spec) == want

    @pytest.mark.parametrize("bad", ["rw", "IN", 3, None])
    def test_coerce_mode_rejects_with_one_message(self, bad):
        with pytest.raises(ValueError, match="mode must be one of"):
            coerce_mode(bad)

    def test_task_footprint_kwarg_matches_classic_kwargs(self):
        @task(in_="a", inout="b")
        def classic(a, b):
            return a + b

        @task(footprint={"a": AccessMode.IN, "b": InOut})
        def typed(a, b):
            return a + b

        results = []
        for fn in (classic, typed):
            with TaskRuntime(executor="sequential") as rt:
                A = rt.from_array(np.ones((2, 2), np.float32), (2, 2))
                B = rt.from_array(np.ones((2, 2), np.float32), (2, 2))
                fn(A[0, 0], B[0, 0])
                rt.barrier()
                results.append(np.asarray(B.get_tile((0, 0))))
        np.testing.assert_array_equal(results[0], results[1])

    def test_task_footprint_kwarg_rejects_bad_modes(self):
        with pytest.raises(ValueError, match="mode must be one of"):
            @task(footprint={"x": "readwrite"})
            def nope(x):
                return x

    def test_wait_on_accepts_typed_modes(self):
        with TaskRuntime(executor="sequential") as rt:
            A = rt.zeros((4, 4), (2, 2))
            rt.wait_on(A[0, 0], mode=AccessMode.IN)
            rt.wait_on(A[0, 0], mode=In)
            with pytest.raises(ValueError, match="mode must be one of"):
                rt.wait_on(A[0, 0], mode="peek")

    def test_module_level_wait_on_needs_a_scope(self):
        with pytest.raises(RuntimeError, match="scope"):
            wait_on(None)

    def test_module_level_wait_on_resolves_current_runtime(self):
        with TaskRuntime(executor="sequential") as rt:
            A = rt.zeros((4, 4), (2, 2))
            with rt.scope():
                wait_on(A[0, 0], mode="in")


class TestStatsSurface:
    def test_admission_fields_default_to_none(self):
        s = RuntimeStats()
        for f in ("admission_submitted", "admission_admitted",
                  "admission_rejected", "admission_deferred",
                  "admission_peak_bytes", "admission_budget_bytes"):
            assert getattr(s, f) is None

    def test_roundtrip_keeps_admission_fields(self):
        s = RuntimeStats(admission_submitted=9, admission_admitted=6,
                         admission_rejected=3)
        back = RuntimeStats.from_dict(s.to_dict())
        assert back.admission_submitted == 9
        assert back.admission_admitted == 6
        assert back.admission_rejected == 3
