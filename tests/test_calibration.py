"""The calibrated sim-executor cost path (ISSUE 4): flopcount-derived
default task costs, DES monotonicity in contention and hop distance, the
paper trends on real ``@task`` programs under ``executor="sim"``, and the
``SCCParams`` fit against the paper's microbenchmark anchors."""
import dataclasses

import numpy as np
import pytest

from repro.core import RuntimeConfig, TaskRuntime, task
from repro.core.calibrate import (CalibrationError, FIG3_LATENCY_CYCLES,
                                  FIG4_SLOWDOWN, calibrate, fit_params,
                                  granularity_sweep, validate_trends)
from repro.core.costmodel import (SCCParams, core_mc_hops,
                                  master_core_choice, worker_order)
from repro.core.sim import FlopcountCost, SimExecutor, SimTask, simulate

import sys
sys.path.insert(0, ".")
from benchmarks.apps import run_app  # noqa: E402


@task(out="c", in_=("a", "b"))
def _pure_gemm(a, b, c=None):
    return a @ b


@task(inout="x", firstprivate="r0")
def _sliced(x, r0):
    import jax
    return jax.lax.dynamic_update_slice(
        x, jax.lax.dynamic_slice(x, (r0, 0), (1, x.shape[1])) * 2.0,
        (r0, 0))


@task(inout="x")
def _untraceable(x):
    # concrete-value branch: jax.make_jaxpr cannot trace this body
    if float(np.asarray(x).sum()) > 0:
        return x + 1.0
    return x - 1.0


def _first_descriptor(spawn):
    """Spawn inside a sim runtime; return (descriptor, executor)."""
    rt = TaskRuntime(RuntimeConfig(executor="sim"))
    try:
        with rt.scope():
            spawn(rt)
            return rt._exec.pending[0], rt._exec
    finally:
        rt.shutdown()


class TestFlopcountCost:
    def test_gemm_tile_cost_is_2mnk(self):
        """The satellite check: flopcount-derived gemm cost is exactly
        the analytic 2*M*N*K (non-square to catch dimension mixups)."""
        M, K, N = 32, 16, 24

        def spawn(rt):
            A = rt.zeros((M, K), (M, K))
            B = rt.zeros((K, N), (K, N))
            C = rt.zeros((M, N), (M, N))
            _pure_gemm(A[0, 0], B[0, 0], C[0, 0])

        td, _ = _first_descriptor(spawn)
        flops, nbytes = FlopcountCost()(td)
        assert flops == 2.0 * M * N * K
        # DRAM traffic covers at least the footprint: two reads + a write
        assert nbytes >= 4 * (M * K + K * N + M * N)

    def test_default_cost_is_flopcount(self):
        """executor="sim" without sim_cost_fn uses FlopcountCost."""
        rt = TaskRuntime(RuntimeConfig(executor="sim"))
        try:
            assert isinstance(rt._exec.cost_fn, FlopcountCost)
        finally:
            rt.shutdown()

    def test_cost_traced_once_per_structure(self):
        fc = FlopcountCost()

        def spawn(rt):
            A = rt.zeros((8, 8), (4, 4))
            B = rt.zeros((8, 8), (4, 4))
            C = rt.zeros((8, 8), (4, 4))
            for i in range(2):
                for j in range(2):
                    _pure_gemm(A[i, 0], B[0, j], C[i, j])

        rt = TaskRuntime(RuntimeConfig(executor="sim"))
        try:
            with rt.scope():
                spawn(rt)
                costs = {fc(td) for td in rt._exec.pending}
                assert len(rt._exec.pending) == 4
                assert len(costs) == 1          # same structure, same cost
                assert len(fc._cache) == 1      # one trace covered all
        finally:
            rt.shutdown()

    def test_firstprivate_values_enter_the_trace(self):
        def spawn(rt):
            X = rt.zeros((8, 8), (8, 8))
            _sliced(X[0, 0], 3)

        td, _ = _first_descriptor(spawn)
        flops, nbytes = FlopcountCost()(td)
        assert flops > 0 and nbytes >= 8 * 8 * 4

    def test_untraceable_body_falls_back_to_footprint(self):
        def spawn(rt):
            X = rt.zeros((8, 8), (8, 8))
            _untraceable(X[0, 0])

        td, _ = _first_descriptor(spawn)
        fc = FlopcountCost()
        assert fc(td) == SimExecutor._footprint_cost(td)
        assert fc._cache[fc._key(td)] is None   # remembered as untraceable


class TestSimMonotone:
    """DES predictions move the right way with contention and distance."""

    def _stream(self, home=0, n=64):
        return [SimTask(tid=i, flops=1e3, mem_bytes=1e6, homes=(home,))
                for i in range(n)]

    def test_sim_time_monotone_in_contention(self):
        alphas = (0.1, 0.3, 0.55, 0.9)
        times = [simulate(self._stream(), 8,
                          dataclasses.replace(SCCParams(),
                                              contention_alpha=a)).total_s
                 for a in alphas]
        assert all(b > a for a, b in zip(times, times[1:]))

    def test_sim_time_monotone_in_hop_distance(self):
        w0 = worker_order(master_core_choice())[0]
        hops = [core_mc_hops(w0, m) for m in range(4)]
        near, far = int(np.argmin(hops)), int(np.argmax(hops))
        assert hops[near] < hops[far]
        p = SCCParams()
        t_near = simulate(self._stream(home=near, n=4), 1, p).total_s
        t_far = simulate(self._stream(home=far, n=4), 1, p).total_s
        assert t_far > t_near

    def test_sim_params_reach_the_executor(self):
        """RuntimeConfig.sim_params swaps the cost model under the DES."""
        slow = dataclasses.replace(SCCParams(), freq_hz=533e6 / 4)
        s_fast = run_app("matmul", "sim", n_workers=8,
                         app_kwargs={"n": 128, "tile": 32})
        s_slow = run_app("matmul", "sim", n_workers=8, sim_params=slow,
                         app_kwargs={"n": 128, "tile": 32})
        assert s_slow.predicted_total_s > 2.0 * s_fast.predicted_total_s


class TestSimAppTrends:
    """The acceptance criterion: executor="sim" with the default
    flopcount cost reproduces the paper's two trends on real programs."""

    def test_gemm_app_striped_beats_single(self):
        kw = {"app_kwargs": {"n": 256, "tile": 64}, "n_workers": 16}
        striped = run_app("matmul", "sim", placement="striped", **kw)
        single = run_app("matmul", "sim", placement="single", **kw)
        assert striped.predicted_total_s < single.predicted_total_s

    def test_granularity_sweep_has_interior_optimum(self):
        rows = granularity_sweep(fit_params().params)
        best = max(range(len(rows)), key=lambda i: rows[i]["speedup"])
        assert 0 < best < len(rows) - 1


class TestCalibrate:
    def test_fit_recovers_anchor_shape(self):
        r = fit_params()
        assert 10 < r.params.dram_hop_cycles < 25
        assert 200 < r.params.dram_base_cycles < 300
        assert 0.4 < r.params.contention_alpha < 0.7
        assert r.fig3_max_rel_err < 0.05
        assert r.fig4_max_rel_err < 0.05

    def test_fit_is_exact_on_synthetic_anchors(self):
        fig3 = {h: 300.0 + 20.0 * h for h in range(0, 9, 2)}
        fig4 = {c: 1.0 + 0.4 * (c - 1) for c in (1, 2, 4, 8, 16, 32)}
        r = fit_params(fig3=fig3, fig4=fig4)
        assert r.params.dram_base_cycles == pytest.approx(300.0)
        assert r.params.dram_hop_cycles == pytest.approx(20.0)
        assert r.params.contention_alpha == pytest.approx(0.4)
        assert r.fig3_max_rel_err < 1e-9
        assert r.fig4_max_rel_err < 1e-9

    def test_fit_preserves_unfitted_constants(self):
        base = dataclasses.replace(SCCParams(), flush_cycles=1234.0)
        assert fit_params(base).params.flush_cycles == 1234.0

    def test_calibrate_validates_trends(self):
        r = calibrate()
        assert r.ok
        assert set(r.checks) == {
            "fig3_latency_monotone_in_hops",
            "fig4_time_monotone_in_contention",
            "striped_beats_single",
            "granularity_interior_optimum",
        }
        d = r.as_dict()
        assert all(d["checks"].values())

    def test_calibrate_raises_when_a_finding_breaks(self):
        """A master-dominated model loses both placement sensitivity and
        the interior granularity optimum — calibrate must refuse it."""
        broken = dataclasses.replace(SCCParams(), spawn_base_cycles=5e6,
                                     schedule_cycles=5e5)
        with pytest.raises(CalibrationError, match="no longer reproduce"):
            calibrate(base=broken)

    def test_validate_trends_flags_disabled_contention(self):
        flat = dataclasses.replace(SCCParams(), contention_alpha=0.0)
        checks = validate_trends(flat)
        assert not checks["striped_beats_single"]
        assert not checks["fig4_time_monotone_in_contention"]

    def test_anchor_tables_are_well_formed(self):
        assert sorted(FIG3_LATENCY_CYCLES) == [0, 2, 4, 6, 8]
        assert FIG4_SLOWDOWN[1] == 1.0
        assert all(FIG4_SLOWDOWN[a] < FIG4_SLOWDOWN[b]
                   for a, b in zip(sorted(FIG4_SLOWDOWN),
                                   sorted(FIG4_SLOWDOWN)[1:]))
