"""Integration tests: end-to-end training, checkpoint/restart equivalence,
elastic resharding, serving round-trip, dry-run machinery on a small mesh."""
import dataclasses
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api


def _tiny_cfg():
    return get_config("qwen1.5-4b").reduced(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512)


class TestTraining:
    def test_loss_decreases(self):
        from repro.launch.train import train_loop
        cfg = _tiny_cfg()
        _, _, hist = train_loop(cfg, steps=30, seq_len=64, global_batch=4,
                                ckpt_dir=None, log_every=29, peak_lr=2e-3)
        assert hist[-1]["loss"] < hist[0]["loss"] - 0.3

    def test_checkpoint_restart_bitwise(self, tmp_path):
        """Stop at step 20, restart, continue to 30 == straight run to 30
        (deterministic pipeline + deterministic optimizer)."""
        from repro.launch.train import train_loop
        cfg = _tiny_cfg()
        kw = dict(seq_len=32, global_batch=4, log_every=1000,
                  peak_lr=1e-3)
        # straight run
        p_a, o_a, _ = train_loop(cfg, steps=12, ckpt_dir=None, **kw)
        # interrupted run
        ck = str(tmp_path / "ck")
        train_loop(cfg, steps=6, ckpt_dir=ck, ckpt_every=1000, **kw)
        p_b, o_b, _ = train_loop(cfg, steps=12, ckpt_dir=ck,
                                 ckpt_every=1000, resume=True, **kw)
        for a, b in zip(jax.tree_util.tree_leaves(p_a),
                        jax.tree_util.tree_leaves(p_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gradient_compression_converges(self):
        """Training with int8 error-feedback gradient compression reaches a
        similar loss — the cross-pod compression is usable."""
        from repro.data import SyntheticTokens
        from repro.optim import adamw_init, adamw_update
        from repro.optim.compress import (compress_with_feedback,
                                          decompress_int8, ef_init)
        cfg = _tiny_cfg()
        data = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        ef = None
        losses = []
        for step in range(25):
            batch = data.batch_at(step)
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(p, cfg, batch))(params)
            if ef is None:
                ef = ef_init(grads)
            q, ef = compress_with_feedback(grads, ef)
            grads = jax.tree_util.tree_map(
                lambda qs: decompress_int8(*qs), q,
                is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                and hasattr(x[0], "dtype"))
            params, opt = adamw_update(grads, opt, params, lr=2e-3)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.3


class TestElastic:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Checkpoint written under one sharding restores onto another
        mesh shape (elastic restart / failed-pod recovery)."""
        from repro.ckpt import restore_checkpoint, save_checkpoint
        from repro.dist.context import MeshContext
        from repro.dist.sharding import param_shardings
        from repro.launch.mesh import make_mesh
        cfg = _tiny_cfg()
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        save_checkpoint(str(tmp_path), 1, params)
        mesh = make_mesh((1, 1), ("data", "model"))
        ctx = MeshContext(mesh)
        sh = param_shardings(cfg, params, ctx, policy="tp")
        restored, _, _ = restore_checkpoint(str(tmp_path), 1, params,
                                            shardings=sh)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestServing:
    def test_generate_deterministic_greedy(self):
        from repro.launch.serve import generate
        cfg = _tiny_cfg()
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (2, 16), 0, cfg.vocab_size)}
        out1 = generate(cfg, params, batch, max_new_tokens=8, max_len=32)
        out2 = generate(cfg, params, batch, max_new_tokens=8, max_len=32)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert out1.shape == (2, 8)

    def test_generate_matches_teacher_forcing(self):
        """Greedy generation step t must equal argmax of the full forward
        over the prefix — the KV-cache path is consistent."""
        from repro.launch.serve import generate
        cfg = _tiny_cfg()
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                    cfg.vocab_size)
        out = generate(cfg, params, {"tokens": tokens}, max_new_tokens=3,
                       max_len=32)
        seq = tokens
        for t in range(3):
            logits = api.forward_logits(params, cfg, {"tokens": seq})
            nxt = int(jnp.argmax(logits[0, -1]))
            nxt = min(nxt, cfg.vocab_size - 1)
            assert nxt == int(out[0, t]), f"step {t}"
            seq = jnp.concatenate([seq, jnp.full((1, 1), nxt,
                                                 jnp.int32)], 1)


class TestDryrunMachinery:
    def test_flopcount_exact_on_known_graph(self):
        from repro.launch.flopcount import count_step

        def f(a, b):
            def body(c, w):
                return c @ w, 0.0
            c, _ = jax.lax.scan(body, a, b)
            return c.sum()

        a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
        out = count_step(f, a, b)
        want = 5 * 2 * 8 * 16 * 16          # scan length x dot flops
        assert abs(out["flops"] - want) / want < 0.01

    def test_collective_stats_trip_counts(self):
        from repro.launch.hlo_stats import collective_stats
        hlo = """
%body_comp (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
}
%cond_comp (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(7)
  %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main.1 (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%t), condition=%cond_comp, body=%body_comp
  %ag = f32[32]{0} all-gather(%a), channel_id=2, replica_groups=[1,8]<=[8], dimensions={0}
}
"""
        st = collective_stats(hlo)
        assert st.counts["all-reduce"] == 7      # inside the while x7
        assert st.counts["all-gather"] == 1
        # AG: result 32 f32 = 128B, g=8 -> operand 16B
        assert st.operand_bytes["all-gather"] == pytest.approx(16.0)

    def test_lower_cell_small(self):
        """The dry-run cell machinery works on the real (1-device) mesh."""
        import repro.launch.dryrun as dr
        from repro import dist
        from repro.dist.sharding import param_shardings
        # emulate lower_cell on a tiny config + tiny mesh
        from repro.launch.mesh import make_mesh
        cfg = _tiny_cfg()
        mesh = make_mesh((1, 1), ("data", "model"))
        with dist.use_mesh(mesh):
            params_abs = jax.eval_shape(
                lambda: api.init_params(jax.random.PRNGKey(0), cfg))
            batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
            from repro.launch.train import build_train_step
            from repro.optim.adamw import adamw_init
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            step = build_train_step(cfg)
            lowered = jax.jit(step).lower(
                params_abs, opt_abs, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        from repro.launch.hlo_stats import memory_stats
        ms = memory_stats(compiled)
        assert ms["per_device_total_bytes"] > 0
