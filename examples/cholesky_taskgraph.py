"""Task-parallel tiled Cholesky — the paper's hardest benchmark.

The right-looking factorization spawns potrf/trsm/update tile tasks whose
footprints overlap heavily; BDDT dependence analysis discovers the DAG
(RAW through the panel, WAW on diagonal updates) and the staged executor
runs it in wavefronts — on TPU the update tasks are the Pallas
``tile_update`` kernel.

    PYTHONPATH=src python examples/cholesky_taskgraph.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import In, InOut, TaskRuntime
from repro.kernels.cholesky import ops as chol


def main(n: int = 512, tile: int = 64):
    g = n // tile
    rng = np.random.default_rng(0)
    m = rng.standard_normal((n, n)).astype(np.float32)
    spd = m @ m.T + n * np.eye(n, dtype=np.float32)

    rt = TaskRuntime(executor="staged", placement="striped_diag")
    A = rt.from_array(spd, (tile, tile), name="A")

    def potrf(a):
        return chol.potrf(a)

    def trsm(l, a):
        return chol.trsm(l, a)

    def update(c, a, b):
        return chol.update(c, a, b)

    for k in range(g):
        rt.spawn(potrf, InOut(A[k, k]), name=f"potrf{k}")
        for i in range(k + 1, g):
            rt.spawn(trsm, In(A[k, k]), InOut(A[i, k]), name=f"trsm{i}{k}")
        for i in range(k + 1, g):
            for j in range(k + 1, i + 1):
                rt.spawn(update, InOut(A[i, j]), In(A[i, k]), In(A[j, k]),
                         name=f"upd{i}{j}{k}")
    rt.barrier()

    got = np.tril(np.asarray(A.gather()))
    want = np.asarray(jnp.linalg.cholesky(jnp.asarray(spd)))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    s = rt.stats()
    print(f"cholesky {n}x{n}/{tile}: {s['tasks_spawned']} tasks, "
          f"{s['deps_found']} deps, {s.get('waves', '?')} wavefronts "
          f"-> factor verified against jnp.linalg.cholesky")
    print("wavefront width = available parallelism per step; the paper's "
          "22-worker saturation is this DAG's critical path showing up")


if __name__ == "__main__":
    main()
