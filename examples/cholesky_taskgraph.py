"""Task-parallel tiled Cholesky — the paper's hardest benchmark.

The right-looking factorization's three kernels are declared once as
``@task`` functions; calling them inside the runtime scope spawns tile
tasks whose footprints overlap heavily.  BDDT dependence analysis
discovers the DAG (RAW through the panel, WAW on diagonal updates) and
the staged executor runs it in wavefronts — on TPU the update tasks are
the Pallas ``tile_update`` kernel.  ``wait_on(A[0, 0])`` demonstrates
region-scoped sync: the first diagonal tile is final long before the
trailing submatrix drains.

    PYTHONPATH=src python examples/cholesky_taskgraph.py
"""
import numpy as np
import jax.numpy as jnp

from repro import TaskRuntime, task
from repro.kernels.cholesky import ops as chol


@task(inout="a")
def potrf(a):
    return chol.potrf(a)


@task(in_="l", inout="a")
def trsm(l, a):
    return chol.trsm(l, a)


@task(inout="c", in_=("a", "b"))
def update(c, a, b):
    return chol.update(c, a, b)


def main(n: int = 512, tile: int = 64):
    g = n // tile
    rng = np.random.default_rng(0)
    m = rng.standard_normal((n, n)).astype(np.float32)
    spd = m @ m.T + n * np.eye(n, dtype=np.float32)

    with TaskRuntime(executor="staged", placement="striped_diag") as rt:
        A = rt.from_array(spd, (tile, tile), name="A")

        for k in range(g):
            potrf(A[k, k])
            for i in range(k + 1, g):
                trsm(A[k, k], A[i, k])
            for i in range(k + 1, g):
                for j in range(k + 1, i + 1):
                    update(A[i, j], A[i, k], A[j, k])

        # the top-left tile's cone is just potrf(A[0,0]): ready immediately
        rt.wait_on(A[0, 0])
        top = np.asarray(A[0, 0].materialize())
        np.testing.assert_allclose(
            np.tril(top), np.asarray(jnp.linalg.cholesky(
                jnp.asarray(spd[:tile, :tile]))), rtol=2e-2, atol=2e-2)

        rt.barrier()
        got = np.tril(np.asarray(A.gather()))
        want = np.asarray(jnp.linalg.cholesky(jnp.asarray(spd)))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
        s = rt.stats()
        print(f"cholesky {n}x{n}/{tile}: {s.tasks_spawned} tasks, "
              f"{s.deps_found} deps, {s.waves} wavefronts "
              f"-> factor verified against jnp.linalg.cholesky")
        print("wavefront width = available parallelism per step; the "
              "paper's 22-worker saturation is this DAG's critical path "
              "showing up")


if __name__ == "__main__":
    main()
