"""End-to-end training driver: a small LM on the synthetic pipeline with
checkpoint/restart.

Any of the ten architectures works via --arch (reduced to a CPU-sized
sibling with --reduced); scale d_model/layers up on real hardware.  The
loss must fall well below ln(vocab) — the pipeline injects learnable
bigram structure.

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses
import math

import jax

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        d_model=args.d_model, n_layers=args.layers,
        d_ff=args.d_model * 2, vocab_size=2048)
    n_params_est = args.layers * 12 * args.d_model ** 2
    print(f"[example] {cfg.name} reduced: ~{n_params_est/1e6:.1f}M "
          f"block params, seq {args.seq_len}, batch {args.global_batch}")

    params, opt, history = train_loop(
        cfg, steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 2, 10), peak_lr=1e-3)

    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[example] loss {first:.3f} -> {last:.3f} "
          f"(uniform = {math.log(cfg.padded_vocab):.3f})")
    assert last < first - 0.5, "loss did not decrease"
    print("[example] checkpoint saved; re-run to resume from it")


if __name__ == "__main__":
    main()
