"""Quickstart: the BDDT-SCC programming model in five minutes.

Declare each kernel's footprint once with ``@task`` (OmpSs's pragma as a
decorator), then call it naturally inside a runtime scope — every call
spawns a task, the runtime discovers dependencies block-by-block, and
synchronization is exactly as fine-grained as you ask for:

* ``future.result()``    — force one task's dependence cone;
* ``rt.wait_on(region)`` — taskwait scoped to a footprint;
* ``rt.barrier()``       — global drain (implied at scope exit).

Scalar parameters go in ``firstprivate``: they are bound at the call
site like everything else, but passed *by value* in the task descriptor
(OmpSs firstprivate) instead of synchronized on — and on the staged
executor, tasks that differ only in those values still share one batched
vmap dispatch.

Swap ``executor=`` between the paper-faithful dynamic host runtime, the
TPU-idiomatic staged wavefront executor, and the home-aware sharded
executor — results are identical (serial elision).  Outside a runtime
scope the decorated function runs eagerly, so ``gemm_tile(c, a, b)`` is
its own reference implementation.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import dist
from repro import RuntimeConfig, TaskRuntime, task


@task(inout="c", in_=("a", "b"))
def gemm_tile(c, a, b):
    """One tile task: C[i,j] += A[i,k] @ B[k,j]."""
    return c + a @ b


@task(in_="x", out="y", firstprivate="shift")
def roll_tile(x, shift, y=None):
    """An index-parameterized task: ``shift`` is firstprivate — a plain
    value riding in the task descriptor, different for every spawn."""
    return jnp.roll(x, shift, axis=0)


def main():
    n, tile = 512, 64
    g = n // tile
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)

    for executor in ("host", "staged"):
        cfg = RuntimeConfig(executor=executor, n_workers=4, mpb_slots=8,
                            policy="locality")
        with TaskRuntime(cfg) as rt:
            A = rt.from_array(a, (tile, tile), name="A")
            B = rt.from_array(b, (tile, tile), name="B")
            C = rt.zeros((n, n), (tile, tile), name="C")

            # OmpSs-style task loop: footprints give the runtime everything
            # it needs — no locks, no barriers between dependent tasks
            futures = {}
            for i in range(g):
                for j in range(g):
                    for k in range(g):
                        futures[i, j, k] = gemm_tile(C[i, j], A[i, k],
                                                     B[k, j])

            # force one output tile: runs only its g-task dependence chain
            tile00 = futures[0, 0, g - 1].result()
            np.testing.assert_allclose(np.asarray(tile00),
                                       a[:tile] @ b[:, :tile],
                                       rtol=2e-4, atol=2e-4)

            # region-scoped taskwait: top block row is done after this,
            # unrelated tiles may still be in flight
            rt.wait_on(C[0, 0:g])

            rt.barrier()
            got = np.asarray(C.gather())
            np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)

            s = rt.stats()
            print(f"[{executor:6s}] {s.tasks_spawned} tasks, "
                  f"{s.deps_found} dependencies, "
                  f"{s.spawn_us_per_task:.1f} us/spawn, "
                  f"{s.futures_resolved} futures, "
                  f"{s.region_waits} region waits -> result verified")

    # firstprivate values: one function, per-task shift amounts — the
    # staged executor batches all g tasks into a single vmap dispatch.
    # tracker="console" turns on the observability layer (repro.obs):
    # every wave emits open/close events with dispatch wall time and
    # measured tile movement, summarized on stdout at shutdown — swap in
    # "jsonl:trace.jsonl" to capture the full timeline instead (then
    # `python -m repro.obs chrome trace.jsonl -o trace.json` renders it
    # for chrome://tracing or https://ui.perfetto.dev)
    with TaskRuntime(executor="staged", tracker="console") as rt:
        X = rt.from_array(a, (tile, n), name="X")
        Y = rt.zeros((n, n), (tile, n), name="Y")
        for r in range(g):
            roll_tile(X[r, 0], r + 1, Y[r, 0])
        rt.barrier()
        got = np.asarray(Y.gather())
        for r in range(g):
            np.testing.assert_array_equal(
                got[r * tile:(r + 1) * tile],
                np.roll(a[r * tile:(r + 1) * tile], r + 1, axis=0))
        s = rt.stats()
        print(f"[staged] firstprivate: {s.tasks_spawned} index-"
              f"parameterized tasks -> {s.grouped_dispatches} batched "
              f"dispatch(es) across {s.waves} wave(s)")

    # home-aware mesh execution: blocks keep the homes the placement
    # policy assigned (the paper's controller striping), the sharded
    # executor runs each task on the home device of its output block
    # (owner-computes) and reports the cross-home read traffic that
    # placement decision implies — the quantity the paper's §4 findings
    # hinge on.  Here the mesh is the one-device fallback, so the same
    # code path CI runs is exactly what a real mesh would execute.
    mesh = dist.single_device_mesh()
    n_dev = int(np.asarray(mesh.devices).size)
    with dist.use_mesh(mesh):
        with TaskRuntime(executor="sharded", placement="striped") as rt:
            A = rt.from_array(a, (tile, tile), name="A")
            B = rt.from_array(b, (tile, tile), name="B")
            C = rt.zeros((n, n), (tile, tile), name="C")
            for i in range(g):
                for j in range(g):
                    for k in range(g):
                        gemm_tile(C[i, j], A[i, k], B[k, j])
            rt.barrier()
            np.testing.assert_allclose(np.asarray(C.gather()), a @ b,
                                       rtol=2e-4, atol=2e-4)
            s = rt.stats()
            total = s.cross_home_bytes + s.local_home_bytes
            print(f"[sharded] owner-computes on a {n_dev}-device mesh: "
                  f"{s.sharded_dispatches} shard_map/vmap dispatches, "
                  f"{s.cross_home_bytes / 2**20:.1f} MiB cross-home of "
                  f"{total / 2**20:.1f} MiB touched "
                  f"({100 * s.cross_home_bytes / total:.0f}% remote) "
                  f"-> result verified")


if __name__ == "__main__":
    main()
