"""Quickstart: the BDDT-SCC programming model in five minutes.

Spawn tasks with declared footprints (In/Out/InOut over block regions);
the runtime discovers dependencies block-by-block, schedules tasks over
workers through bounded MPB-style descriptor rings, and a barrier drains
everything.  Swap ``executor=`` between the paper-faithful dynamic host
runtime and the TPU-idiomatic staged wavefront executor — results are
identical (serial elision).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import In, InOut, TaskRuntime


def gemm_tile(c, a, b):
    """One tile task: C[i,j] += A[i,k] @ B[k,j]."""
    return c + a @ b


def main():
    n, tile = 512, 64
    g = n // tile
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)

    for executor in ("host", "staged"):
        rt = TaskRuntime(executor=executor, n_workers=4, mpb_slots=8,
                         policy="locality")
        A = rt.from_array(a, (tile, tile), name="A")
        B = rt.from_array(b, (tile, tile), name="B")
        C = rt.zeros((n, n), (tile, tile), name="C")

        # OmpSs-style task loop: footprints give the runtime everything it
        # needs — no locks, no barriers between dependent tasks
        for i in range(g):
            for j in range(g):
                for k in range(g):
                    rt.spawn(gemm_tile, InOut(C[i, j]), In(A[i, k]),
                             In(B[k, j]))
        rt.barrier()

        got = np.asarray(C.gather())
        np.testing.assert_allclose(got, a @ b, rtol=2e-4, atol=2e-4)
        s = rt.stats()
        print(f"[{executor:6s}] {s['tasks_spawned']} tasks, "
              f"{s['deps_found']} dependencies, "
              f"spawn {1e6 * s['spawn_time_s'] / s['tasks_spawned']:.1f} "
              f"us/task -> result verified")
        rt.shutdown()


if __name__ == "__main__":
    main()
