"""Streaming LM serving on ``repro.serve``: decode requests against a
shared KV arena.

The serving shape of the paper's runtime: the KV cache lives as
long-lived ``BlockArray`` state striped along the sequence axis (the
"memory controllers"), and every arriving query becomes a *small task
graph* — one ``flash_decode`` partial-attention task per KV tile in the
request's context window, plus one log-sum-exp combine task.  The
dependence analyzer isolates requests touching different windows, the
admission controller bounds the in-flight footprint bytes, and the
arena checkpoints per home through ``repro.ckpt`` so a restart resumes
bit-identically.

(The batch prefill+generate driver this file used to hold lives on as
``repro.launch.serve.generate``.)

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --requests 48 --budget 4
"""
import argparse
import tempfile
import time

import numpy as np

from repro import RuntimeConfig, task
from repro.kernels.flash_decode import ops as fd_ops, ref as fd_ref
from repro.serve import ServeConfig, Session, footprint_nbytes

S_TILE = 64         # KV rows per tile (one sequence shard = one task)
D = 64              # head dimension
N_TILES = 16        # arena length = N_TILES * S_TILE tokens
SHARDS = 4          # context window per request, in tiles


@task(in_=("k", "v"), out=("o", "lse"), firstprivate=("q",))
def _partial(k, v, q, o=None, lse=None):
    # one KV shard's partial attention for one query token
    out, l = fd_ops.decode_partial(q[None, None, :], k[None, None],
                                   v[None, None])
    return out[0], l[0][:, None]                # (1, D), (1, 1)


@task(in_=("outs", "lses"), out="dest")
def _combine(outs, lses, dest=None):
    # exact LSE merge of the shard partials -> the request's output row
    o = fd_ref.combine_partials(outs[:, None, None, :], lses[:, :, None])
    return o[0].astype(np.float32)              # (1, D)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--budget", type=int, default=3,
                    help="admission budget, in concurrent requests")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: a temp dir)")
    args = ap.parse_args()
    n_req = args.requests
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_lm_ckpt_")
    rng = np.random.default_rng(0)
    k_init = rng.standard_normal((N_TILES * S_TILE, D)).astype(np.float32)
    v_init = rng.standard_normal((N_TILES * S_TILE, D)).astype(np.float32)
    queries = rng.standard_normal((n_req, D)).astype(np.float32)
    windows = rng.integers(0, N_TILES - SHARDS + 1, n_req)

    # request footprint: SHARDS (K + V) tiles + SHARDS partial rows +
    # SHARDS lse rows + 1 output row; the budget admits args.budget such
    # requests concurrently and queues the rest (FIFO)
    req_bytes = (2 * SHARDS * S_TILE * D + SHARDS * (D + 1) + D) * 4
    serve = ServeConfig(budget_bytes=args.budget * req_bytes,
                        checkpoint_dir=ckpt_dir)

    with Session(RuntimeConfig(executor="host", n_workers=args.workers),
                 serve) as s:
        K = s.from_array(k_init, (S_TILE, D), name="K")
        V = s.from_array(v_init, (S_TILE, D), name="V")
        OP = s.zeros((n_req * SHARDS, D), (1, D), name="op", state=False)
        LSE = s.zeros((n_req * SHARDS, 1), (1, 1), name="lse", state=False)
        OUT = s.zeros((n_req, D), (1, D), name="out", state=False)

        def build(i):
            t0, q = int(windows[i]), queries[i]
            r0 = i * SHARDS

            def graph():
                futs = [_partial(K[t0 + j, 0], V[t0 + j, 0], q,
                                 OP[r0 + j, 0], LSE[r0 + j, 0])
                        for j in range(SHARDS)]
                futs.append(_combine(OP[r0:r0 + SHARDS, 0],
                                     LSE[r0:r0 + SHARDS, 0], OUT[i, 0]))
                return futs

            footprint = ([K[t0:t0 + SHARDS, 0], V[t0:t0 + SHARDS, 0],
                          OP[r0:r0 + SHARDS, 0], LSE[r0:r0 + SHARDS, 0],
                          OUT[i, 0]])
            assert footprint_nbytes(footprint) == req_bytes
            return s.submit(graph, *footprint, name=f"decode-{i}")

        t_start = time.perf_counter()
        handles = [build(i) for i in range(n_req)]
        while not all(h.done() for h in handles):
            s.poll()
            time.sleep(0.0005)
        wall = time.perf_counter() - t_start

        # verify every served row against the unsharded oracle
        for i, h in enumerate(handles):
            t0 = int(windows[i])
            kw = k_init[t0 * S_TILE:(t0 + SHARDS) * S_TILE]
            vw = v_init[t0 * S_TILE:(t0 + SHARDS) * S_TILE]
            want = fd_ref.decode_mha(queries[i][None, None, :],
                                     kw[None, None], vw[None, None])[0]
            got = np.asarray(OUT.get_tile((i, 0)))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

        lat = np.asarray([h.latency_s for h in handles]) * 1e3
        st = s.stats()
        epoch = s.checkpoint(sync=True)
        print(f"[serve_lm] {n_req} requests in {wall * 1e3:.0f}ms "
              f"({n_req / wall:.0f} req/s): "
              f"p50 {np.percentile(lat, 50):.1f}ms "
              f"p99 {np.percentile(lat, 99):.1f}ms")
        print(f"[serve_lm] admission: {st.admission_admitted} admitted / "
              f"{st.admission_submitted} submitted, peak "
              f"{st.admission_peak_bytes}B <= "
              f"budget {st.admission_budget_bytes}B")
        print(f"[serve_lm] checkpointed arena epoch {epoch} -> {ckpt_dir}")
        assert st.admission_peak_bytes <= st.admission_budget_bytes

    # simulated restart: a fresh runtime restores the arena bit-identically
    with Session(RuntimeConfig(executor="host", n_workers=args.workers),
                 ServeConfig(checkpoint_dir=ckpt_dir)) as s2:
        K2 = s2.zeros((N_TILES * S_TILE, D), (S_TILE, D), name="K")
        V2 = s2.zeros((N_TILES * S_TILE, D), (S_TILE, D), name="V")
        restored = s2.restore_latest()
        for idx in K2.home:
            np.testing.assert_array_equal(np.asarray(K2.get_tile(idx)),
                                          np.asarray(K.get_tile(idx)))
            np.testing.assert_array_equal(np.asarray(V2.get_tile(idx)),
                                          np.asarray(V.get_tile(idx)))
        print(f"[serve_lm] restart restored epoch {restored}: "
              f"KV arena bit-identical")


if __name__ == "__main__":
    main()
