"""Batched serving driver: prefill a batch of prompts, then decode with
the pre-allocated KV arena (the decode_32k dry-run shape, miniaturized).

    PYTHONPATH=src python examples/serve_lm.py --arch mistral-nemo-12b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {"tokens": jax.random.randint(
        ks[0], (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.vision_seq:
        batch["vision_embeds"] = 0.02 * jax.random.normal(
            ks[1], (args.batch, cfg.vision_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
    if cfg.family == "audio":
        batch["enc_frames"] = 0.02 * jax.random.normal(
            ks[2], (args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))

    t0 = time.perf_counter()
    out = generate(cfg, params, batch, max_new_tokens=args.new_tokens,
                   max_len=args.prompt_len + args.new_tokens + 8,
                   temperature=args.temperature)
    dt = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: generated {out.shape[0]}x{out.shape[1]} "
          f"tokens in {dt:.2f}s ({out.size / dt:.1f} tok/s incl. compile)")
    print(out[:, :12])
    assert out.shape == (args.batch, args.new_tokens)
    assert int(out.max()) < cfg.vocab_size


if __name__ == "__main__":
    main()
