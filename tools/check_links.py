"""Fail on broken intra-repo links in the documentation layer.

Scans README.md and every Markdown file under docs/ for relative links
(``[text](path)`` and ``[text](path#fragment)``), resolves each against
the linking file's directory, and exits non-zero when any target is
missing — the docs CI job runs this so the documentation cannot rot
silently.  External links (http/https/mailto) and pure-fragment anchors
are skipped; fenced code blocks are stripped first so example snippets
never count.  ``tests/test_docs.py`` runs the same check in tier-1.

Inline-code ``file.py:line`` anchors (the entry-point pointers in
docs/ARCHITECTURE.md's paper-to-code map) are validated too: the named
file must exist somewhere in the repo (anchors use basenames or short
suffix paths — every file whose path ends with the anchor is a
candidate) and the line number must be in range for at least one
candidate, so moving an entry point without refreshing its anchor fails
the docs job instead of rotting.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) / [text](target#fragment); targets with a scheme or a
# leading '#' are filtered below.  Images (![alt](src)) match too, which
# is what we want.
_LINK = re.compile(r"\[[^\]]*\]\(\s*<?([^)#\s>]+)(#[^)\s>]*)?>?\s*\)")
# `path/to/file.py:123` in inline code — the file:line entry-point anchors
_ANCHOR = re.compile(r"`([\w][\w./-]*\.[A-Za-z]\w*):(\d+)`")
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
_SKIP_DIRS = {".git", "__pycache__", ".venv", "node_modules"}

# the documentation layer that must exist at all (a missing file is a
# broken link from everywhere)
REQUIRED = ("README.md", "docs/ARCHITECTURE.md")


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md"]
    docs = root / "docs"
    if docs.is_dir():
        files.extend(sorted(docs.glob("**/*.md")))
    return files


def _file_index(root: pathlib.Path) -> dict[str, list[pathlib.Path]]:
    """basename -> repo files, from one walk (anchors resolve against it
    so per-anchor lookups never re-scan the tree)."""
    index: dict[str, list[pathlib.Path]] = {}
    for p in root.rglob("*"):
        if p.is_file() and not any(d in p.parts for d in _SKIP_DIRS):
            index.setdefault(p.name, []).append(p)
    return index


def _anchor_candidates(root: pathlib.Path, target: str,
                       index: dict) -> list[pathlib.Path]:
    """Repo files an anchor like ``sim.py`` / ``dist/__init__.py`` can
    name: exact path from the root, or any file whose path ends with the
    anchor (anchors use basenames for brevity)."""
    suffix = "/" + target.lstrip("/")
    return [p for p in index.get(target.rsplit("/", 1)[-1], [])
            if p == root / target or str(p).endswith(suffix)]


def check_anchors(root: pathlib.Path) -> list[tuple[pathlib.Path, str]]:
    """(file, problem) pairs for every ``file:line`` anchor naming a
    missing file or an out-of-range line number."""
    bad: list[tuple[pathlib.Path, str]] = []
    index = _file_index(root)
    n_lines: dict[pathlib.Path, int] = {}
    for f in doc_files(root):
        if not f.is_file():
            continue
        text = _FENCE.sub("", f.read_text(encoding="utf-8"))
        for m in _ANCHOR.finditer(text):
            target, line = m.group(1), int(m.group(2))
            cands = _anchor_candidates(root, target, index)
            if not cands:
                bad.append((f, f"anchor `{target}:{line}`: no such file"))
                continue
            for p in cands:
                if p not in n_lines:
                    n_lines[p] = len(
                        p.read_text(encoding="utf-8").splitlines())
            if line < 1 or not any(line <= n_lines[p] for p in cands):
                where = ", ".join(
                    f"{p.relative_to(root)} has {n_lines[p]} lines"
                    for p in cands)
                bad.append((f, f"anchor `{target}:{line}` out of range "
                               f"({where})"))
    return bad


def check(root: pathlib.Path) -> list[tuple[pathlib.Path, str]]:
    """Return (file, target) pairs for every broken link or anchor."""
    bad: list[tuple[pathlib.Path, str]] = []
    for rel in REQUIRED:
        if not (root / rel).is_file():
            bad.append((root / rel, "<required documentation file missing>"))
    for f in doc_files(root):
        if not f.is_file():
            continue
        text = _FENCE.sub("", f.read_text(encoding="utf-8"))
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_SCHEMES):
                continue
            if not (f.parent / target).resolve().exists():
                bad.append((f, target))
    bad.extend(check_anchors(root))
    return bad


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = check(root)
    for f, target in bad:
        print(f"{f.relative_to(root) if f.is_relative_to(root) else f}: "
              f"broken link -> {target}")
    n_files = len([f for f in doc_files(root) if f.is_file()])
    print(f"checked {n_files} markdown file(s): "
          f"{'FAIL, ' + str(len(bad)) + ' broken' if bad else 'all links ok'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
