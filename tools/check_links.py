"""Fail on broken intra-repo links in the documentation layer.

Scans README.md and every Markdown file under docs/ for relative links
(``[text](path)`` and ``[text](path#fragment)``), resolves each against
the linking file's directory, and exits non-zero when any target is
missing — the docs CI job runs this so the documentation cannot rot
silently.  External links (http/https/mailto) and pure-fragment anchors
are skipped; fenced code blocks are stripped first so example snippets
never count.  ``tests/test_docs.py`` runs the same check in tier-1.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) / [text](target#fragment); targets with a scheme or a
# leading '#' are filtered below.  Images (![alt](src)) match too, which
# is what we want.
_LINK = re.compile(r"\[[^\]]*\]\(\s*<?([^)#\s>]+)(#[^)\s>]*)?>?\s*\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")

# the documentation layer that must exist at all (a missing file is a
# broken link from everywhere)
REQUIRED = ("README.md", "docs/ARCHITECTURE.md")


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md"]
    docs = root / "docs"
    if docs.is_dir():
        files.extend(sorted(docs.glob("**/*.md")))
    return files


def check(root: pathlib.Path) -> list[tuple[pathlib.Path, str]]:
    """Return (file, target) pairs for every broken link."""
    bad: list[tuple[pathlib.Path, str]] = []
    for rel in REQUIRED:
        if not (root / rel).is_file():
            bad.append((root / rel, "<required documentation file missing>"))
    for f in doc_files(root):
        if not f.is_file():
            continue
        text = _FENCE.sub("", f.read_text(encoding="utf-8"))
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_SCHEMES):
                continue
            if not (f.parent / target).resolve().exists():
                bad.append((f, target))
    return bad


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent
    bad = check(root)
    for f, target in bad:
        print(f"{f.relative_to(root) if f.is_relative_to(root) else f}: "
              f"broken link -> {target}")
    n_files = len([f for f in doc_files(root) if f.is_file()])
    print(f"checked {n_files} markdown file(s): "
          f"{'FAIL, ' + str(len(bad)) + ' broken' if bad else 'all links ok'}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
