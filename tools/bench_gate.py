"""CI gate over BENCH JSON artifacts (schema ``bddt-scc-bench/1``).

``benchmarks.run --emit`` produces a machine-readable benchmark document
(specified in docs/BENCHMARKS.md); this tool validates its schema and
diffs every entry's ``metrics`` against the committed baseline:

* metrics whose name contains ``speedup`` regress when they *drop* more
  than the threshold;
* metrics ending in ``_s``/``_us`` or containing ``bytes``/``frac``/``cv``
  regress when they *grow* more than the threshold;
* everything else — task/dispatch counts, model shape ratios, and any
  ``single_mc`` pathology metric (whose job is to stay *bad*) — is a
  determinism check: any drift beyond the threshold in either direction
  fails, because it means the suite or model itself changed and the
  baseline must be regenerated deliberately (``--update``).

Only deterministic quantities live under ``metrics`` (DES predictions,
dependence/dispatch counts, home-traffic bytes); wall-clock measurements
ride in each entry's ``info`` block and are never gated, so the gate
cannot flake on runner noise.

    python tools/bench_gate.py BENCH_4.json
    python tools/bench_gate.py BENCH_4.json --update     # bless new numbers

On first run (no baseline committed yet) the artifact is copied to the
baseline path and the gate passes — commit the file to arm the gate.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

SCHEMA = "bddt-scc-bench/1"
TIMINGS_SCHEMA = "bddt-scc-timings/1"
DEFAULT_BASELINE = "benchmarks/BASELINE_BENCH.json"
DEFAULT_THRESHOLD = 0.20


def validate_timings(doc) -> list[str]:
    """Shape-check the optional ``timings`` block (empty = valid).

    Timings are *informational*: they must be well-formed finite numbers
    (so the nightly series stays parseable) but are never diffed against
    a baseline — wall clocks flake on shared runners, and the paper's
    deterministic claims are gated through entry ``metrics`` instead.
    An artifact without a timings block is also valid (older emitters).
    """
    t = doc.get("timings")
    if t is None:
        return []
    bad: list[str] = []
    if not isinstance(t, dict):
        return ["'timings' is not an object"]
    if t.get("schema") != TIMINGS_SCHEMA:
        bad.append(f"timings schema is {t.get('schema')!r}, "
                   f"expected {TIMINGS_SCHEMA!r}")
    for key in ("suite_wall_s", "spawn_us_per_task"):
        v = t.get(key)
        if isinstance(v, bool) or not isinstance(v, (int, float)) \
                or not math.isfinite(v) or v < 0:
            bad.append(f"timings.{key} is not a finite non-negative "
                       f"number ({v!r})")
    staged = t.get("staged_wall_s")
    if not isinstance(staged, dict) or not staged:
        bad.append("timings.staged_wall_s missing/empty")
    else:
        for app, v in staged.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(v) or v < 0:
                bad.append(f"timings.staged_wall_s[{app!r}] is not a "
                           f"finite non-negative number ({v!r})")
    return bad


def validate_kernel_backend(doc) -> list[str]:
    """Shape-check the kernel-backend sweep entries (empty = valid).

    Wall clocks ride in each entry's ``info`` block and are purely
    informational (CPU CI runs pallas in interpret mode — a correctness
    harness, not a perf claim); the gated quantities are the
    deterministic ``kernel_dispatches``/``kernel_fallbacks`` counts,
    which must be present non-negative integers so the two-sided
    determinism diff has something real to bite on.  An artifact with no
    ``kernel_backend`` entries is valid (older emitters).
    """
    bad: list[str] = []
    entries = doc.get("entries")
    if not isinstance(entries, list):
        return bad
    for e in entries:
        if not isinstance(e, dict) or e.get("kind") != "kernel_backend":
            continue
        eid = e.get("id", "<kernel_backend>")
        metrics = e.get("metrics") or {}
        for key in ("kernel_dispatches", "kernel_fallbacks"):
            v = metrics.get(key)
            if isinstance(v, bool) or not isinstance(v, int) or v < 0:
                bad.append(f"{eid}: metric {key!r} is not a "
                           f"non-negative integer ({v!r})")
        info = e.get("info") or {}
        for key in ("wall_s_xla", "wall_s_pallas"):
            v = info.get(key)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(v) or v < 0:
                bad.append(f"{eid}: info {key!r} is not a finite "
                           f"non-negative number ({v!r})")
    return bad


def validate_serving(doc) -> list[str]:
    """Shape-check the streaming-serving entries (empty = valid).

    The admission counters are a closed ledger: every submitted request
    resolves as exactly one of admitted or rejected, and the controller's
    peak in-flight footprint never exceeds the byte budget — structural
    invariants of the controller, so an artifact violating them is
    malformed regardless of any baseline.  The open-loop latency sweep
    rides in ``info`` (wall clocks, never gated) and must only be finite
    non-negative numbers.  No ``serving`` entries is valid (older
    emitters).
    """
    bad: list[str] = []
    entries = doc.get("entries")
    if not isinstance(entries, list):
        return bad
    for e in entries:
        if not isinstance(e, dict) or e.get("kind") != "serving":
            continue
        eid = e.get("id", "<serving>")
        metrics = e.get("metrics") or {}
        vals: dict[str, int] = {}
        for key in ("submitted", "admitted", "rejected",
                    "peak_in_flight_bytes", "budget_bytes"):
            v = metrics.get(key)
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(v) or v < 0 or v != int(v):
                bad.append(f"{eid}: metric {key!r} is not a "
                           f"non-negative integer ({v!r})")
            else:
                vals[key] = int(v)
        if len(vals) == 5:
            if vals["admitted"] + vals["rejected"] != vals["submitted"]:
                bad.append(
                    f"{eid}: admission ledger leaks — admitted "
                    f"({vals['admitted']}) + rejected ({vals['rejected']}) "
                    f"!= submitted ({vals['submitted']})")
            if vals["peak_in_flight_bytes"] > vals["budget_bytes"]:
                bad.append(
                    f"{eid}: peak in-flight {vals['peak_in_flight_bytes']}B "
                    f"exceeds the budget {vals['budget_bytes']}B")
        rates = (e.get("info") or {}).get("rates")
        if not isinstance(rates, dict) or not rates:
            bad.append(f"{eid}: info 'rates' missing/empty")
            continue
        for rate, r in rates.items():
            for key in ("p50_ms", "p99_ms", "throughput_rps"):
                v = (r if isinstance(r, dict) else {}).get(key)
                if isinstance(v, bool) or not isinstance(v, (int, float)) \
                        or not math.isfinite(v) or v < 0:
                    bad.append(f"{eid}: rates[{rate!r}].{key} is not a "
                               f"finite non-negative number ({v!r})")
    return bad


def timings_point(doc) -> dict | None:
    """One series point for the nightly append-only timing log: the
    timings block plus enough identity (suite, env) to plot it."""
    t = doc.get("timings")
    if t is None:
        return None
    return {**t, "env": doc.get("env", {})}


# ---------------------------------------------------------------------------
def validate_schema(doc) -> list[str]:
    """Return a list of schema problems (empty = valid)."""
    bad: list[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    if doc.get("schema") != SCHEMA:
        bad.append(f"schema is {doc.get('schema')!r}, expected {SCHEMA!r}")
    if not isinstance(doc.get("suite"), str):
        bad.append("missing/non-string 'suite'")
    if not isinstance(doc.get("calibration"), dict):
        bad.append("missing 'calibration' object")
    val = doc.get("validation")
    if not (isinstance(val, dict) and isinstance(val.get("checks"), dict)
            and isinstance(val.get("passed"), int)
            and isinstance(val.get("total"), int)):
        bad.append("missing/malformed 'validation' "
                   "{checks, passed, total}")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        return bad + ["missing/empty 'entries' list"]
    seen: set[str] = set()
    for i, e in enumerate(entries):
        where = f"entries[{i}]"
        if not isinstance(e, dict):
            bad.append(f"{where}: not an object")
            continue
        eid = e.get("id")
        if not isinstance(eid, str) or not eid:
            bad.append(f"{where}: missing string 'id'")
        elif eid in seen:
            bad.append(f"{where}: duplicate id {eid!r}")
        else:
            seen.add(eid)
        if not isinstance(e.get("kind"), str):
            bad.append(f"{where}: missing string 'kind'")
        metrics = e.get("metrics")
        if not isinstance(metrics, dict):
            bad.append(f"{where}: missing 'metrics' object")
            continue
        for k, v in metrics.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)) \
                    or not math.isfinite(v):
                bad.append(f"{where}: metric {k!r} is not a finite "
                           f"number ({v!r})")
    return bad


# ---------------------------------------------------------------------------
def _rule(metric: str) -> str:
    # single-MC pathology metrics measure how *bad* the contended
    # placement is — drift in either direction means the cost model
    # changed (e.g. weakened contention eroding the striped-beats-single
    # margin), so they are determinism checks, not perf directions
    if "single_mc" in metric:
        return "two_sided"
    if "speedup" in metric:
        return "lower_is_worse"
    if metric.endswith(("_s", "_us")) or "bytes" in metric \
            or "frac" in metric or "cv" in metric:
        return "higher_is_worse"
    return "two_sided"


def _regressed(rule: str, base: float, new: float, thr: float) -> bool:
    if base == 0:
        return abs(new) > 1e-12
    if rule == "lower_is_worse":
        return new < base * (1.0 - thr)
    if rule == "higher_is_worse":
        return new > base * (1.0 + thr)
    return abs(new - base) > thr * abs(base)


def compare(baseline: dict, new: dict,
            threshold: float = DEFAULT_THRESHOLD) -> list[dict]:
    """Every regression of ``new`` against ``baseline`` (empty = pass).

    A baseline entry or metric missing from ``new`` is itself a
    regression (the suite silently shrank); entries/metrics that are new
    in ``new`` pass — they will be gated once the baseline is updated.
    """
    problems: list[dict] = []
    if baseline.get("suite") != new.get("suite"):
        return [{"id": "<doc>", "metric": "suite",
                 "base": baseline.get("suite"), "new": new.get("suite"),
                 "rule": "suites must match"}]
    new_by_id = {e["id"]: e for e in new["entries"]}
    for be in baseline["entries"]:
        ne = new_by_id.get(be["id"])
        if ne is None:
            problems.append({"id": be["id"], "metric": "<entry>",
                             "base": "present", "new": "missing",
                             "rule": "entry disappeared"})
            continue
        for metric, base in be["metrics"].items():
            if metric not in ne["metrics"]:
                problems.append({"id": be["id"], "metric": metric,
                                 "base": base, "new": "missing",
                                 "rule": "metric disappeared"})
                continue
            val = ne["metrics"][metric]
            rule = _rule(metric)
            if _regressed(rule, float(base), float(val), threshold):
                problems.append({"id": be["id"], "metric": metric,
                                 "base": base, "new": val, "rule": rule})
    return problems


# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="gate a BENCH artifact against the committed baseline")
    ap.add_argument("artifact", help="BENCH JSON from benchmarks.run --emit")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline path (default {DEFAULT_BASELINE})")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression tolerance (default 0.20)")
    ap.add_argument("--update", action="store_true",
                    help="bless the artifact as the new baseline")
    ap.add_argument("--append-timings", metavar="SERIES",
                    help="append the artifact's timings block (one JSON "
                         "line) to this series file — informational, "
                         "never gated")
    args = ap.parse_args(argv)

    with open(args.artifact, encoding="utf-8") as f:
        doc = json.load(f)
    bad = (validate_schema(doc) + validate_timings(doc)
           + validate_kernel_backend(doc) + validate_serving(doc))
    if bad:
        for b in bad:
            print(f"SCHEMA: {b}")
        print(f"{args.artifact}: FAIL, invalid {SCHEMA} document")
        return 1

    if args.append_timings:
        point = timings_point(doc)
        if point is None:
            print(f"{args.artifact}: no timings block to append")
        else:
            series = pathlib.Path(args.append_timings)
            series.parent.mkdir(parents=True, exist_ok=True)
            with series.open("a", encoding="utf-8") as f:
                f.write(json.dumps(point, sort_keys=True) + "\n")
            print(f"{series}: appended timings point "
                  f"(suite={point.get('suite')})")

    base_path = pathlib.Path(args.baseline)
    if args.update or not base_path.exists():
        base_path.parent.mkdir(parents=True, exist_ok=True)
        base_path.write_text(json.dumps(doc, indent=1, sort_keys=True)
                             + "\n", encoding="utf-8")
        verb = "updated" if args.update else "created (first run)"
        print(f"{base_path}: baseline {verb} from {args.artifact} — "
              "commit it to arm the gate")
        return 0

    with open(base_path, encoding="utf-8") as f:
        baseline = json.load(f)
    bad = validate_schema(baseline)
    if bad:
        for b in bad:
            print(f"BASELINE SCHEMA: {b}")
        print(f"{base_path}: FAIL, invalid baseline — regenerate with "
              "--update")
        return 1

    problems = compare(baseline, doc, args.threshold)
    for p in problems:
        print(f"REGRESSION {p['id']} :: {p['metric']} "
              f"[{p['rule']}] baseline={p['base']} new={p['new']}")
    n_meta = sum(len(e["metrics"]) for e in baseline["entries"])
    verdict = f"FAIL, {len(problems)} regression(s)" if problems else "ok"
    print(f"compared {n_meta} metric(s) across "
          f"{len(baseline['entries'])} entries at ±{args.threshold:.0%}: "
          f"{verdict}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
