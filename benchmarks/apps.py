"""The paper's five applications as *real* task-graph programs on the
runtime (the DES in ``paper_suite`` simulates SCC timing; these execute
the same dataflow with actual JAX kernels and verify numerics).

Each app's kernels are declared once with ``@task`` footprints and called
naturally inside the runtime scope — the OmpSs front-end the paper
describes.  Index-parameterized kernels (fft's tile transpose, jacobi's
halo stencil) take their offsets as ``firstprivate`` value parameters, so
one shared function covers every tile and the staged executor batches a
whole wavefront into a single vmap dispatch.  Sizes are parameters —
tests use laptop-scale instances; the DES workloads carry the paper's
§4.2 sizes.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import TaskRuntime, task
from repro.kernels.black_scholes import ops as bs_ops
from repro.kernels.cholesky import ops as chol_ops
from repro.kernels.jacobi import ref as jac_ref
from repro.kernels.matmul import ops as mm_ops


# ---------------------------------------------------------------------------
@task(in_=("spot", "strike", "t", "rate", "vol"), out=("call", "put"))
def _price(spot, strike, t, rate, vol, call=None, put=None):
    return bs_ops.black_scholes(spot, strike, t, rate, vol)


def black_scholes_app(rt: TaskRuntime, n_options: int = 8192,
                      task_options: int = 512, verify: bool = True):
    """Independent pricing tasks — embarrassingly parallel (§4.2)."""
    rng = np.random.default_rng(0)
    cols = {
        "spot": rng.uniform(10, 200, n_options).astype(np.float32),
        "strike": rng.uniform(10, 200, n_options).astype(np.float32),
        "t": rng.uniform(0.1, 2.0, n_options).astype(np.float32),
        "rate": np.full(n_options, 0.03, np.float32),
        "vol": rng.uniform(0.1, 0.6, n_options).astype(np.float32),
    }
    with rt.scope():
        arrays = {k: rt.from_array(v, (task_options,), name=k)
                  for k, v in cols.items()}
        call = rt.zeros((n_options,), (task_options,), name="call")
        put = rt.zeros((n_options,), (task_options,), name="put")

        futures = [
            _price(arrays["spot"][i], arrays["strike"][i], arrays["t"][i],
                   arrays["rate"][i], arrays["vol"][i], call[i], put[i])
            for i in range(n_options // task_options)]
        if verify:
            # independent tasks: every future resolves without a barrier
            rt.wait_all(futures)
        else:
            # same synchronization surface without result() — the
            # timing-only sim executor never computes task values
            rt.wait_on(call, put)
    if not verify:
        return call, put
    want_c, want_p = bs_ops.black_scholes(
        *[jnp.asarray(cols[k])
          for k in ("spot", "strike", "t", "rate", "vol")])
    np.testing.assert_allclose(np.asarray(call.gather()),
                               np.asarray(want_c), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(put.gather()),
                               np.asarray(want_p), rtol=1e-5, atol=1e-3)
    return call, put


# ---------------------------------------------------------------------------
@task(inout="c", in_=("x", "y"))
def _gemm(c, x, y):
    return mm_ops.matmul(x, y, c)


def matmul_app(rt: TaskRuntime, n: int = 256, tile: int = 64,
               verify: bool = True):
    g = n // tile
    rng = np.random.default_rng(1)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    with rt.scope():
        A = rt.from_array(a, (tile, tile), name="A")
        B = rt.from_array(b, (tile, tile), name="B")
        C = rt.zeros((n, n), (tile, tile), name="C")

        for i in range(g):
            for j in range(g):
                for k in range(g):
                    _gemm(C[i, j], A[i, k], B[k, j])
        rt.barrier()
    if verify:
        np.testing.assert_allclose(np.asarray(C.gather()), a @ b,
                                   rtol=2e-4, atol=2e-4)
    return C


# ---------------------------------------------------------------------------
@task(in_=("re", "im"), out=("re_out", "im_out"))
def _row_fft(re, im, re_out=None, im_out=None):
    out = jnp.fft.fft(re + 1j * im, axis=1)
    return out.real.astype(jnp.float32), out.imag.astype(jnp.float32)


def fft2d_app(rt: TaskRuntime, n: int = 256, row_block: int = 32,
              tile: int = 32, verify: bool = True):
    """2-D FFT exactly as the paper structures it: row-FFT tasks on
    32-row blocks, 32x32 tiled transpose tasks, row-FFT tasks again.
    Complex data as separate re/im planes."""
    rng = np.random.default_rng(2)
    x = (rng.standard_normal((n, n)) +
         1j * rng.standard_normal((n, n))).astype(np.complex64)

    with rt.scope():
        Re = rt.from_array(x.real.astype(np.float32), (row_block, n),
                           name="Re")
        Im = rt.from_array(x.imag.astype(np.float32), (row_block, n),
                           name="Im")
        Re1 = rt.zeros((n, n), (row_block, n), name="Re1")
        Im1 = rt.zeros((n, n), (row_block, n), name="Im1")
        ReT = rt.zeros((n, n), (tile, tile), name="ReT")
        ImT = rt.zeros((n, n), (tile, tile), name="ImT")
        Re2 = rt.zeros((n, n), (row_block, n), name="Re2")
        Im2 = rt.zeros((n, n), (row_block, n), name="Im2")

        g = n // row_block
        for r in range(g):
            _row_fft(Re[r, 0], Im[r, 0], Re1[r, 0], Im1[r, 0])
        assert row_block == tile, \
            "paper's §4.2 uses 32-row blocks + 32x32 tiles"
        gt = n // tile

        # one shared TaskFn for every tile: the (row, col) offsets are
        # firstprivate values carried in the descriptor, so a wavefront
        # of transpose tasks shares one batched vmap dispatch on the
        # staged executor instead of jit-compiling per tile
        @task(in_=("re_block", "im_block"), out=("re_t", "im_t"),
              firstprivate=("r0", "c0"))
        def transpose_tile(re_block, im_block, r0, c0,
                           re_t=None, im_t=None):
            re = jax.lax.dynamic_slice(re_block, (r0, c0), (tile, tile))
            im = jax.lax.dynamic_slice(im_block, (r0, c0), (tile, tile))
            return re.T, im.T

        for i in range(gt):
            for j in range(gt):
                # source tile (i, j) lives in row-block i*tile//row_block
                rb = (i * tile) // row_block
                r0 = i * tile - rb * row_block
                transpose_tile(Re1[rb, 0], Im1[rb, 0], r0, j * tile,
                               ReT[j, i], ImT[j, i])
        for r in range(g):
            # row r of the transposed matrix spans tile-rows of ReT
            t0 = (r * row_block) // tile
            t1 = ((r + 1) * row_block - 1) // tile
            _row_fft(ReT[t0:t1 + 1, :], ImT[t0:t1 + 1, :],
                     Re2[r, 0], Im2[r, 0])
        rt.barrier()
    if verify:
        got = np.asarray(Re2.gather()) + 1j * np.asarray(Im2.gather())
        want = np.fft.fft2(x).T   # pipeline output stays transposed
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-1)
    return Re2, Im2


# ---------------------------------------------------------------------------
def jacobi_app(rt: TaskRuntime, n: int = 256, tile: int = 64,
               iters: int = 4, verify: bool = True):
    """Tiled 5-point Jacobi: each task reads its tile plus the available
    neighbour tiles (one footprint region) and writes its tile — the halo
    dependencies the paper's stencil workloads exhibit."""
    rng = np.random.default_rng(3)
    x0 = rng.standard_normal((n, n)).astype(np.float32)
    g = n // tile
    with rt.scope():
        bufs = [rt.from_array(x0, (tile, tile), name="J0"),
                rt.zeros((n, n), (tile, tile), name="J1")]

        # one shared TaskFn: the tile's offset inside its halo is a
        # firstprivate value, so tasks group by halo *shape* only
        # (corner/edge/interior) and each group batches into one vmap
        # dispatch on the staged executor
        @task(in_="halo", out="dest", firstprivate=("r0", "c0"))
        def stencil(halo, r0, c0, dest=None):
            full = jac_ref.jacobi_step(halo)
            return jax.lax.dynamic_slice(full, (r0, c0), (tile, tile))

        for it in range(iters):
            s, d = bufs[it % 2], bufs[(it + 1) % 2]
            for i in range(g):
                for j in range(g):
                    i0, i1 = max(i - 1, 0), min(i + 2, g)
                    j0, j1 = max(j - 1, 0), min(j + 2, g)
                    stencil(s[i0:i1, j0:j1], (i - i0) * tile,
                            (j - j0) * tile, d[i, j])
        rt.barrier()
    if verify:
        want = np.asarray(jac_ref.jacobi(jnp.asarray(x0), iters=iters))
        got = np.asarray(bufs[iters % 2].gather())
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    return bufs[iters % 2]


# ---------------------------------------------------------------------------
@task(inout="a")
def _potrf(a):
    return chol_ops.potrf(a)


@task(in_="l", inout="a")
def _trsm(l, a):
    return chol_ops.trsm(l, a)


@task(inout="c", in_=("x", "y"))
def _update(c, x, y):
    return chol_ops.update(c, x, y)


def cholesky_app(rt: TaskRuntime, n: int = 256, tile: int = 64,
                 verify: bool = True):
    g = n // tile
    rng = np.random.default_rng(4)
    m = rng.standard_normal((n, n)).astype(np.float32)
    spd = m @ m.T + n * np.eye(n, dtype=np.float32)
    with rt.scope():
        A = rt.from_array(spd, (tile, tile), name="Chol")

        for k in range(g):
            _potrf(A[k, k])
            for i in range(k + 1, g):
                _trsm(A[k, k], A[i, k])
            for i in range(k + 1, g):
                for j in range(k + 1, i + 1):
                    _update(A[i, j], A[i, k], A[j, k])
        rt.barrier()
    if verify:
        got = np.tril(np.asarray(A.gather()))
        want = np.asarray(jnp.linalg.cholesky(jnp.asarray(spd)))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    return A


APPS = {
    "black_scholes": black_scholes_app,
    "matmul": matmul_app,
    "fft": fft2d_app,
    "jacobi": jacobi_app,
    "cholesky": cholesky_app,
}


def run_app(name: str, executor: str = "staged", *,
            verify: bool | None = None, app_kwargs: dict | None = None,
            **config_overrides):
    """Run one paper app on a fresh runtime and return its RuntimeStats.

    Every app self-verifies its numerics against the reference kernel, so
    a returned stats object means the run was correct — this is what the
    report tables and the executor-comparison tests call.  For
    ``executor="sharded"`` install a mesh first (``repro.dist.use_mesh``)
    to exercise the shard_map dispatch; without one the executor falls
    back to single-device staged dispatch and still reports home traffic.

    ``verify=None`` means "verify unless the executor cannot": the
    timing-only ``"sim"`` executor never computes task values, so its runs
    skip the numeric check (and its stats carry ``predicted_total_s``).
    ``app_kwargs`` forwards problem sizes to the app (the benchmark
    suites shrink them for smoke runs).
    """
    from repro import RuntimeConfig

    if verify is None:
        verify = executor != "sim"
    config_overrides.setdefault("n_workers", 4)
    rt = TaskRuntime(RuntimeConfig(executor=executor, **config_overrides))
    try:
        APPS[name](rt, verify=verify, **(app_kwargs or {}))
        return rt.stats()
    finally:
        rt.shutdown()
