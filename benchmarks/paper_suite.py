"""Figures 5-7: the five applications on the simulated SCC runtime.

Per application: execution time + speedup vs worker count (Fig 5),
cumulative idle/app/flush breakdowns (Fig 6), and per-worker load balance
at 43 workers (Fig 7).  The ``single`` placement column quantifies the
paper's contention pathology against the ``striped`` fix (§4.2).

Everything is parameterized so the unified harness (``benchmarks.run``)
can run the same sweeps at smoke sizes and on calibrated
:class:`~repro.core.costmodel.SCCParams`.
"""
from __future__ import annotations

from repro.core.costmodel import SCCParams
from repro.core.sim import sequential_time, simulate

from .workloads import WORKLOADS

WORKER_COUNTS = [1, 2, 4, 8, 12, 16, 22, 28, 36, 43]


def scalability(name: str, placement: str = "striped",
                p: SCCParams | None = None,
                worker_counts=None, gen_kwargs: dict | None = None) -> dict:
    p = p or SCCParams()
    gen = WORKLOADS[name]
    kw = gen_kwargs or {}
    seq = sequential_time(gen(placement, **kw), p)
    rows = []
    for w in worker_counts or WORKER_COUNTS:
        r = simulate(gen(placement, **kw), w, p)
        rows.append({
            "workers": w,
            "time_s": r.total_s,
            "speedup": seq / r.total_s,
            "idle_s": sum(r.worker_idle_s),
            "app_s": sum(r.worker_busy_s),
            "flush_s": sum(r.worker_flush_s),
        })
    return {"name": name, "placement": placement, "seq_s": seq,
            "rows": rows}


def load_balance(name: str, workers: int = 43,
                 p: SCCParams | None = None,
                 gen_kwargs: dict | None = None) -> dict:
    r = simulate(WORKLOADS[name]("striped", **(gen_kwargs or {})),
                 workers, p or SCCParams())
    return {
        "name": name,
        "busy": r.worker_busy_s,
        "flush": r.worker_flush_s,
        "idle": r.worker_idle_s,
        "tasks": r.worker_tasks,
    }


def peak(rows) -> tuple[int, float]:
    best = max(rows, key=lambda r: r["speedup"])
    return best["workers"], best["speedup"]


def run(report, *, p: SCCParams | None = None, worker_counts=None,
        sizes: dict | None = None):
    """Emit Fig 5/6/7 numbers; return the validation summary.

    ``sizes`` maps workload name -> generator kwargs (smoke profiles
    shrink the graphs); ``p`` is the (calibrated) cost model.
    """
    p = p or SCCParams()
    sizes = sizes or {}
    summary = {}
    for name in WORKLOADS:
        kw = sizes.get(name)
        res = scalability(name, p=p, worker_counts=worker_counts,
                          gen_kwargs=kw)
        for row in res["rows"]:
            report(f"fig5_{name}", f"w={row['workers']}",
                   row["speedup"])
        w_peak, s_peak = peak(res["rows"])
        report(f"fig5_{name}", "peak_workers", w_peak)
        report(f"fig5_{name}", "peak_speedup", s_peak)
        last = res["rows"][-1]
        report(f"fig6_{name}", "idle_frac_43",
               last["idle_s"] / max(last["idle_s"] + last["app_s"]
                                    + last["flush_s"], 1e-12))
        report(f"fig6_{name}", "flush_frac_43",
               last["flush_s"] / max(last["idle_s"] + last["app_s"]
                                     + last["flush_s"], 1e-12))
        summary[name] = {"peak_workers": w_peak, "peak_speedup": s_peak,
                         "speedup_43": last["speedup"],
                         "rows": res["rows"]}
        # contention pathology: same app homed on one controller
        last_w = (worker_counts or WORKER_COUNTS)[-1]
        res1 = scalability(name, placement="single", p=p,
                           worker_counts=[last_w], gen_kwargs=kw)
        report(f"fig5_{name}", "speedup_43_single_mc",
               res1["rows"][0]["speedup"])
        summary[name]["speedup_43_single_mc"] = res1["rows"][0]["speedup"]
    # Fig 7 load balance: coefficient of variation of busy time
    for name in WORKLOADS:
        lb = load_balance(name, p=p, gen_kwargs=sizes.get(name))
        import numpy as np
        busy = np.array(lb["busy"])
        cv = float(busy.std() / max(busy.mean(), 1e-12))
        report(f"fig7_{name}", "busy_cv_43", cv)
        summary[name]["busy_cv_43"] = cv
    return summary
