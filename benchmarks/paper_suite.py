"""Figures 5-7: the five applications on the simulated SCC runtime.

Per application: execution time + speedup vs worker count (Fig 5),
cumulative idle/app/flush breakdowns (Fig 6), and per-worker load balance
at 43 workers (Fig 7).  The ``single`` placement column quantifies the
paper's contention pathology against the ``striped`` fix (§4.2).
"""
from __future__ import annotations

from repro.core.costmodel import SCCParams
from repro.core.sim import sequential_time, simulate

from .workloads import WORKLOADS

WORKER_COUNTS = [1, 2, 4, 8, 12, 16, 22, 28, 36, 43]


def scalability(name: str, placement: str = "striped",
                p: SCCParams = SCCParams(),
                worker_counts=None) -> dict:
    gen = WORKLOADS[name]
    seq = sequential_time(gen(placement), p)
    rows = []
    for w in worker_counts or WORKER_COUNTS:
        r = simulate(gen(placement), w, p)
        rows.append({
            "workers": w,
            "time_s": r.total_s,
            "speedup": seq / r.total_s,
            "idle_s": sum(r.worker_idle_s),
            "app_s": sum(r.worker_busy_s),
            "flush_s": sum(r.worker_flush_s),
        })
    return {"name": name, "placement": placement, "seq_s": seq,
            "rows": rows}


def load_balance(name: str, workers: int = 43,
                 p: SCCParams = SCCParams()) -> dict:
    r = simulate(WORKLOADS[name]("striped"), workers, p)
    return {
        "name": name,
        "busy": r.worker_busy_s,
        "flush": r.worker_flush_s,
        "idle": r.worker_idle_s,
        "tasks": r.worker_tasks,
    }


def peak(rows) -> tuple[int, float]:
    best = max(rows, key=lambda r: r["speedup"])
    return best["workers"], best["speedup"]


def run(report):
    """Emit Fig 5/6/7 numbers; return the validation summary."""
    summary = {}
    for name in WORKLOADS:
        res = scalability(name)
        for row in res["rows"]:
            report(f"fig5_{name}", f"w={row['workers']}",
                   row["speedup"])
        w_peak, s_peak = peak(res["rows"])
        report(f"fig5_{name}", "peak_workers", w_peak)
        report(f"fig5_{name}", "peak_speedup", s_peak)
        last = res["rows"][-1]
        report(f"fig6_{name}", "idle_frac_43",
               last["idle_s"] / max(last["idle_s"] + last["app_s"]
                                    + last["flush_s"], 1e-12))
        report(f"fig6_{name}", "flush_frac_43",
               last["flush_s"] / max(last["idle_s"] + last["app_s"]
                                     + last["flush_s"], 1e-12))
        summary[name] = {"peak_workers": w_peak, "peak_speedup": s_peak,
                         "speedup_43": last["speedup"]}
        # contention pathology: same app homed on one controller
        res1 = scalability(name, placement="single",
                           worker_counts=[43])
        report(f"fig5_{name}", "speedup_43_single_mc",
               res1["rows"][0]["speedup"])
        summary[name]["speedup_43_single_mc"] = res1["rows"][0]["speedup"]
    # Fig 7 load balance: coefficient of variation of busy time
    for name in WORKLOADS:
        lb = load_balance(name)
        import numpy as np
        busy = np.array(lb["busy"])
        cv = float(busy.std() / max(busy.mean(), 1e-12))
        report(f"fig7_{name}", "busy_cv_43", cv)
        summary[name]["busy_cv_43"] = cv
    return summary
