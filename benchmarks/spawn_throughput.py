"""Spawn-throughput benchmark: central vs home-sharded dependence admission.

Measures the master-side task-initiation rate (tasks/sec) on synthetic
streaming graphs — the §5 master-bottleneck axis, and the measurement the
home-sharded dependence manager must win: admission throughput should
scale with manager count instead of serializing on one analyzer walk.

The driver exercises the *runtime front half only*: descriptor pool →
dependence analysis → graph insert, with windowed completion/release so
the live set stays bounded and ``forget_completed`` bookkeeping is part
of the measured loop (a streaming workload releases as it spawns).  No
executor runs — task bodies are never called, so the rate isolates
exactly the code the sharded refactor changed.

The synthetic graph is a wrap-around row stencil over a striped
``BlockArray``: task ``t`` rewrites row segment ``(t % G)`` and reads the
two neighbouring rows' segments, so every task carries a multi-block
footprint spanning several homes and RAW/WAR chains recur with period
``G`` — enough dependence structure that admission does real work.

Every column shares ONE driver: spawns proceed in chunks of ``CHUNK``
tasks, admissions drain at the chunk boundary (split-phase
``analyze_begin``/``admit_finish`` where the manager supports it, plain
``analyze`` per task where it doesn't), and retirement happens only
between chunks.  Identical retire interleaving is what makes the
dependence checksums comparable across central, sync-sharded and
threaded-sharded runs — and the stencil's dependence age (``grid + 1``
tasks) is far inside the ``WINDOW``-task live set, so the chunked
retire lag cannot change any dependence set.  The checksum assertion
against the central column verifies that empirically on every run.

Three columns per manager count:

* ``central``  — the §3.3 single-analyzer walk (one column total).
* ``sharded``  — per-home managers, synchronous pump, one descriptor per
  envelope (``batch_lines=1``): PR-7 wire behavior, the baseline the
  tentpole must beat.
* ``threaded`` — per-home managers behind pump threads with
  line-batched envelopes (``batch_lines=8``): descriptors pack
  ``DESCRIPTORS_PER_LINE`` per 32-byte line, one grant envelope answers
  each query envelope, and the master never executes manager logic
  inline.

A reconciliation pass replays the recorded logical descriptor stream
through ``sim.predict_dep_traffic`` and asserts the predicted envelope
and line counts equal the measured ``dep_batches``/``dep_lines`` for
both pump modes — the DES and the runtime charge the same wire traffic.

CLI::

    python -m benchmarks.spawn_throughput --tasks 100000 --homes 1 2 4 8
    python -m benchmarks.spawn_throughput --suite smoke      # small + fast

Bench integration: ``entry()`` emits a ``bddt-scc-bench/1`` entry whose
``metrics`` are the deterministic counters (tasks, deps, messages,
envelopes, lines, the reconciliation bit — gate-safe) and whose ``info``
carries the measured rates (machine-speed dependent, never gated),
matching how ``benchmarks.run`` treats wall times.
"""
from __future__ import annotations

import argparse
import time
from collections import deque

from repro import BlockArray, In, InOut
from repro.core.depman import ShardedDependenceManager
from repro.core.deps import DependenceAnalyzer
from repro.core.graph import DescriptorPool, TaskGraph
from repro.core.placement import assign_homes
from repro.core.sim import predict_dep_traffic

# live-set bound: tasks complete (in spawn order — a valid topological
# order of the stencil graph) once this many are in flight
WINDOW = 256
# spawn-chunk size: admissions drain (and the live window retires) at
# chunk boundaries; amortizes the split-phase sync cost over many tasks
CHUNK = 128
# the batched column's envelope capacity, in 32-byte MPB lines
BATCH_LINES = 8
# pump threads for the threaded column: on a single-CPU host the win
# comes from batching + amortized handoffs, not parallelism, so a small
# thread pool beats one-thread-per-home (fewer wake/park round-trips);
# the manager clamps this to [1, n_managers]
PUMP_THREADS = 1


def _noop(*_a, **_k):
    return None


def build_array(grid: int, homes: int, seg: int = 8) -> BlockArray:
    """A ``grid x seg`` block grid of 1-element tiles, row-banded over
    ``homes`` (each block row behind one home, the stencil-friendly
    layout) — footprints index blocks, bodies never run, so tiles are as
    small as the allocator permits."""
    ba = BlockArray.zeros((grid, seg), (1, 1))
    assign_homes(ba, "striped_rows", homes)
    return ba


def _retire(graph: TaskGraph, analyzer, pool: DescriptorPool,
            live: deque) -> None:
    td = live.popleft()
    graph.mark_executed(td)
    graph.release(td)
    analyzer.forget_completed(td)
    pool.release(td)


def run_stream(n_tasks: int, analyzer, ba: BlockArray,
               window: int = WINDOW, chunk: int = CHUNK) -> dict:
    """Push ``n_tasks`` stencil tasks through one manager; returns the
    measured rate plus the counters and dependence checksum.

    One driver for every manager: spawn ``chunk`` tasks, drain their
    admissions, insert + checksum in spawn order, then retire the live
    window down — so retire interleaving (and therefore the dependence
    stream) is identical whichever analyzer runs.  Chunks are clamped to
    half the window: the descriptor pool holds ``2 x window`` slots, so
    a chunk can never exhaust it and force a retire while admissions are
    still in flight (the determinism contract of the threaded pump)."""
    grid = ba.grid[0]
    seg = ba.grid[1]
    chunk = max(1, min(chunk, window // 2))
    split = hasattr(analyzer, "analyze_begin")
    pool = DescriptorPool(capacity=window * 2)
    graph = TaskGraph()
    live: deque = deque()
    csum = 0
    t0 = time.perf_counter()
    t = 0
    while t < n_tasks:
        n = min(chunk, n_tasks - t)
        tds = []
        for k in range(n):
            i = (t + k) % grid
            args = (InOut(ba[i, 0:seg]),
                    In(ba[(i + 1) % grid, 0:seg]),
                    In(ba[(i - 1) % grid, 0:seg]))
            td = pool.acquire(_noop, args)
            while td is None:            # pool pressure (clamp keeps
                _retire(graph, analyzer, pool, live)   # this path cold)
                td = pool.acquire(_noop, args)
            td.spawn_order = t + k
            if split:
                analyzer.analyze_begin(td)
            tds.append(td)
        if split:
            pairs = analyzer.admit_finish()
        else:
            pairs = [(td, analyzer.analyze(td)) for td in tds]
        for td, deps in pairs:
            graph.insert(td, deps)
            live.append(td)
            # rolling checksum of the discovered dependence set —
            # identical work on every manager, so rates stay comparable
            acc = len(deps)
            for d in deps:
                acc += d.tid
            csum = (csum * 1000003 + acc) % (1 << 61)
        while len(live) >= window:
            _retire(graph, analyzer, pool, live)
        t += n
    while live:
        _retire(graph, analyzer, pool, live)
    wall = time.perf_counter() - t0
    return {
        "tasks": n_tasks,
        "wall_s": wall,
        "tasks_per_s": n_tasks / wall if wall > 0 else 0.0,
        "deps_found": analyzer.deps_found,
        "blocks_walked": analyzer.blocks_walked,
        "dep_checksum": csum,
        "live_blocks": getattr(analyzer, "live_blocks",
                               len(getattr(analyzer, "_meta", ()))),
    }


def _best_of(reps: int, make_analyzer, ba: BlockArray,
             n_tasks: int) -> dict:
    """Best-of-``reps`` rate (fresh analyzer state per rep — dependence
    metadata is per-analyzer, the array only carries the home map); the
    counters and checksum are deterministic and asserted stable.  Each
    rep's analyzer is shut down (pump threads joined) before the next
    starts, so threaded reps never overlap."""
    best: dict | None = None
    for _ in range(reps):
        analyzer = make_analyzer()
        r = run_stream(n_tasks, analyzer, ba)
        shutdown = getattr(analyzer, "shutdown", None)
        if shutdown is not None:
            shutdown()
        r["analyzer"] = analyzer
        if best is not None and r["dep_checksum"] != best["dep_checksum"]:
            raise AssertionError("nondeterministic dependence stream")
        if best is None or r["tasks_per_s"] > best["tasks_per_s"]:
            best = r
    return best


def _sharded_column(h: int, ba: BlockArray, n_tasks: int, reps: int,
                    central: dict, *, batch_lines: int,
                    pump: str) -> dict:
    threads = PUMP_THREADS if pump == "threaded" else None

    def make():
        mgr = ShardedDependenceManager(n_managers=h,
                                       batch_lines=batch_lines, pump=pump,
                                       pump_threads=threads)
        mgr.register_array(ba)
        return mgr

    r = _best_of(reps, make, ba, n_tasks)
    mgr = r.pop("analyzer")
    r["dep_messages"] = mgr.dep_messages
    r["dep_batches"] = mgr.dep_batches
    r["dep_lines"] = mgr.dep_lines
    r["pump_wall_s"] = mgr.pump_wall_s
    r["admissions"] = list(mgr.admissions)
    if r["dep_checksum"] != central["dep_checksum"]:
        raise AssertionError(
            f"sharded manager ({h} homes, {pump}) found different "
            f"dependences than central: {r['dep_checksum']} != "
            f"{central['dep_checksum']}")
    return r


def reconcile_traffic(n_tasks: int = 5000, homes: int = 8, grid: int = 64,
                      seg: int = 8,
                      batch_lines: int = BATCH_LINES) -> dict:
    """Run the stream once per pump mode with traffic recording on and
    replay the logical stream through ``sim.predict_dep_traffic``: the
    flush policy depends only on the descriptor stream and the config,
    so predicted envelope/line counts must equal the measured ones for
    sync *and* threaded pumps — and the two pumps must agree with each
    other."""
    out: dict = {"batch_lines": batch_lines}
    for pump in ("sync", "threaded"):
        ba = build_array(grid, homes, seg)
        mgr = ShardedDependenceManager(n_managers=homes,
                                       batch_lines=batch_lines, pump=pump,
                                       pump_threads=PUMP_THREADS,
                                       record_traffic=True)
        mgr.register_array(ba)
        run_stream(n_tasks, mgr, ba)
        mgr.shutdown()
        pred = predict_dep_traffic(mgr.traffic_log, batch_lines,
                                   mgr.traffic_deps)
        out[pump] = {
            "dep_messages": mgr.dep_messages,
            "measured_batches": mgr.dep_batches,
            "predicted_batches": pred["dep_batches"],
            "measured_lines": mgr.dep_lines,
            "predicted_lines": pred["dep_lines"],
            "reconciled": (pred["dep_batches"] == mgr.dep_batches
                           and pred["dep_lines"] == mgr.dep_lines),
        }
    out["pumps_agree"] = (
        out["sync"]["measured_batches"] == out["threaded"]["measured_batches"]
        and out["sync"]["measured_lines"] == out["threaded"]["measured_lines"])
    out["reconciled"] = (out["sync"]["reconciled"]
                         and out["threaded"]["reconciled"]
                         and out["pumps_agree"])
    return out


def run_matrix(n_tasks: int, homes: list[int], grid: int = 64,
               seg: int = 8, reps: int = 3) -> dict:
    """Central, sync-sharded (``batch_lines=1``) and threaded-batched
    (``batch_lines=BATCH_LINES``) per manager count, best-of-``reps``
    each (the loop is wall-clock timed, so repetitions absorb scheduler
    noise); verifies every run found the same dependences before
    reporting rates."""
    results: dict = {"tasks": n_tasks, "grid": grid, "seg": seg}
    ba = build_array(grid, max(homes), seg)
    central = _best_of(reps, DependenceAnalyzer, ba, n_tasks)
    central.pop("analyzer")
    results["central"] = central
    results["sharded"] = {}
    results["threaded"] = {}
    for h in homes:
        ba_h = build_array(grid, h, seg)
        results["sharded"][h] = _sharded_column(
            h, ba_h, n_tasks, reps, central, batch_lines=1, pump="sync")
        results["threaded"][h] = _sharded_column(
            h, ba_h, n_tasks, reps, central, batch_lines=BATCH_LINES,
            pump="threaded")
    return results


def entry(suite: str = "smoke") -> dict:
    """One ``bddt-scc-bench/1`` entry: deterministic counters as gated
    metrics, measured rates as info (wall-clock — never gated)."""
    n_tasks = 100_000 if suite == "paper" else 10_000
    homes = [1, 2, 4, 8]
    res = run_matrix(n_tasks, homes)
    rec = reconcile_traffic(n_tasks=min(n_tasks, 5000))
    central = res["central"]
    at4 = res["sharded"][4]
    sync8 = res["sharded"][8]
    thr8 = res["threaded"][8]
    info = {
        "suite": suite,
        "grid": res["grid"],
        "central_tasks_per_s": central["tasks_per_s"],
        "speedup_at_4_homes": (at4["tasks_per_s"] /
                               central["tasks_per_s"]),
        "threaded_speedup_8_homes": (thr8["tasks_per_s"] /
                                     sync8["tasks_per_s"]),
        "threaded_pump_wall_s_8_homes": thr8["pump_wall_s"],
    }
    for h in homes:
        info[f"sharded_{h}_tasks_per_s"] = res["sharded"][h]["tasks_per_s"]
        info[f"threaded_{h}_tasks_per_s"] = res["threaded"][h]["tasks_per_s"]
    return {
        "id": f"spawn-throughput-{suite}",
        "kind": "spawn_throughput",
        "metrics": {
            "tasks": float(central["tasks"]),
            "deps_found": float(central["deps_found"]),
            "blocks_walked": float(central["blocks_walked"]),
            "dep_messages_4_homes": float(at4["dep_messages"]),
            "dep_messages_8_homes": float(thr8["dep_messages"]),
            "dep_batches_8_homes_threaded": float(thr8["dep_batches"]),
            "dep_lines_8_homes_threaded": float(thr8["dep_lines"]),
            "traffic_reconciled": 1.0 if rec["reconciled"] else 0.0,
        },
        "info": info,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tasks", type=int, default=None,
                    help="stream length (default: per --suite)")
    ap.add_argument("--homes", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="manager counts for the sharded runs")
    ap.add_argument("--grid", type=int, default=64,
                    help="stencil rows (live dependence window)")
    ap.add_argument("--suite", choices=("smoke", "paper"), default="smoke",
                    help="smoke = 10k tasks, paper = 100k (unless --tasks)")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per config (best rate reported)")
    args = ap.parse_args(argv)
    n_tasks = args.tasks or (100_000 if args.suite == "paper" else 10_000)
    res = run_matrix(n_tasks, args.homes, grid=args.grid, reps=args.reps)
    c = res["central"]
    print(f"central    : {c['tasks_per_s']:>12.0f} tasks/s  "
          f"({c['deps_found']} deps, {c['blocks_walked']} blocks)")
    for h in args.homes:
        s = res["sharded"][h]
        t = res["threaded"][h]
        print(f"sharded {h:>2} : {s['tasks_per_s']:>12.0f} tasks/s  "
              f"(x{s['tasks_per_s'] / c['tasks_per_s']:.2f} vs central, "
              f"{s['dep_messages']} msgs = {s['dep_batches']} envelopes)")
        print(f"threaded{h:>2} : {t['tasks_per_s']:>12.0f} tasks/s  "
              f"(x{t['tasks_per_s'] / s['tasks_per_s']:.2f} vs sync, "
              f"{t['dep_messages']} msgs in {t['dep_batches']} envelopes"
              f" / {t['dep_lines']} lines)")
    rec = reconcile_traffic(n_tasks=min(n_tasks, 5000))
    print(f"traffic reconciliation (sim vs measured, both pumps): "
          f"{'OK' if rec['reconciled'] else 'MISMATCH'} "
          f"({rec['threaded']['measured_batches']} envelopes, "
          f"{rec['threaded']['measured_lines']} lines)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
