"""Spawn-throughput benchmark: central vs home-sharded dependence admission.

Measures the master-side task-initiation rate (tasks/sec) on synthetic
streaming graphs — the §5 master-bottleneck axis, and the measurement the
home-sharded dependence manager must win: admission throughput should
scale with manager count instead of serializing on one analyzer walk.

The driver exercises the *runtime front half only*: descriptor pool →
dependence analysis → graph insert, with windowed completion/release so
the live set stays bounded and ``forget_completed`` bookkeeping is part
of the measured loop (a streaming workload releases as it spawns).  No
executor runs — task bodies are never called, so the rate isolates
exactly the code the sharded refactor changed.

The synthetic graph is a wrap-around row stencil over a striped
``BlockArray``: task ``t`` rewrites row segment ``(t % G)`` and reads the
two neighbouring rows' segments, so every task carries a multi-block
footprint spanning several homes and RAW/WAR chains recur with period
``G`` — enough dependence structure that admission does real work.

Both managers run the same stream; a rolling checksum over each task's
discovered dependence set (identical work charged to both) verifies they
found the *same* dependences before any rate is reported.

CLI::

    python -m benchmarks.spawn_throughput --tasks 100000 --homes 1 2 4 8
    python -m benchmarks.spawn_throughput --suite smoke      # small + fast

Bench integration: ``entry()`` emits a ``bddt-scc-bench/1`` entry whose
``metrics`` are the deterministic counters (tasks, deps, messages —
gate-safe) and whose ``info`` carries the measured rates (machine-speed
dependent, never gated), matching how ``benchmarks.run`` treats wall
times.
"""
from __future__ import annotations

import argparse
import time
from collections import deque

from repro import BlockArray, In, InOut
from repro.core.depman import ShardedDependenceManager
from repro.core.deps import DependenceAnalyzer
from repro.core.graph import DescriptorPool, TaskGraph
from repro.core.placement import assign_homes

# live-set bound: tasks complete (in spawn order — a valid topological
# order of the stencil graph) once this many are in flight
WINDOW = 256


def _noop(*_a, **_k):
    return None


def build_array(grid: int, homes: int, seg: int = 8) -> BlockArray:
    """A ``grid x seg`` block grid of 1-element tiles, row-banded over
    ``homes`` (each block row behind one home, the stencil-friendly
    layout) — footprints index blocks, bodies never run, so tiles are as
    small as the allocator permits."""
    ba = BlockArray.zeros((grid, seg), (1, 1))
    assign_homes(ba, "striped_rows", homes)
    return ba


def _retire(graph: TaskGraph, analyzer, pool: DescriptorPool,
            live: deque) -> None:
    td = live.popleft()
    graph.mark_executed(td)
    graph.release(td)
    analyzer.forget_completed(td)
    pool.release(td)


def run_stream(n_tasks: int, analyzer, ba: BlockArray,
               window: int = WINDOW) -> dict:
    """Push ``n_tasks`` stencil tasks through one manager; returns the
    measured rate plus the counters and dependence checksum."""
    grid = ba.grid[0]
    seg = ba.grid[1]
    pool = DescriptorPool(capacity=window * 2)
    graph = TaskGraph()
    live: deque = deque()
    csum = 0
    t0 = time.perf_counter()
    for t in range(n_tasks):
        i = t % grid
        args = (InOut(ba[i, 0:seg]),
                In(ba[(i + 1) % grid, 0:seg]),
                In(ba[(i - 1) % grid, 0:seg]))
        td = pool.acquire(_noop, args)
        while td is None:
            _retire(graph, analyzer, pool, live)
            td = pool.acquire(_noop, args)
        td.spawn_order = t
        deps = analyzer.analyze(td)
        graph.insert(td, deps)
        live.append(td)
        # rolling checksum of the discovered dependence set — identical
        # work on both managers, so rates stay comparable
        acc = len(deps)
        for d in deps:
            acc += d.tid
        csum = (csum * 1000003 + acc) % (1 << 61)
        if len(live) >= window:
            _retire(graph, analyzer, pool, live)
    while live:
        _retire(graph, analyzer, pool, live)
    wall = time.perf_counter() - t0
    return {
        "tasks": n_tasks,
        "wall_s": wall,
        "tasks_per_s": n_tasks / wall if wall > 0 else 0.0,
        "deps_found": analyzer.deps_found,
        "blocks_walked": analyzer.blocks_walked,
        "dep_checksum": csum,
        "live_blocks": getattr(analyzer, "live_blocks",
                               len(getattr(analyzer, "_meta", ()))),
    }


def _best_of(reps: int, make_analyzer, ba: BlockArray,
             n_tasks: int) -> dict:
    """Best-of-``reps`` rate (fresh analyzer state per rep — dependence
    metadata is per-analyzer, the array only carries the home map); the
    counters and checksum are deterministic and asserted stable."""
    best: dict | None = None
    for _ in range(reps):
        analyzer = make_analyzer()
        r = run_stream(n_tasks, analyzer, ba)
        r["analyzer"] = analyzer
        if best is not None and r["dep_checksum"] != best["dep_checksum"]:
            raise AssertionError("nondeterministic dependence stream")
        if best is None or r["tasks_per_s"] > best["tasks_per_s"]:
            best = r
    return best


def run_matrix(n_tasks: int, homes: list[int], grid: int = 64,
               seg: int = 8, reps: int = 3) -> dict:
    """Central and sharded per manager count, best-of-``reps`` each (the
    loop is wall-clock timed, so repetitions absorb scheduler noise);
    verifies every run found the same dependences before reporting
    rates."""
    results: dict = {"tasks": n_tasks, "grid": grid, "seg": seg}
    ba = build_array(grid, max(homes), seg)
    central = _best_of(reps, DependenceAnalyzer, ba, n_tasks)
    central.pop("analyzer")
    results["central"] = central
    results["sharded"] = {}
    for h in homes:
        ba_h = build_array(grid, h, seg)

        def make():
            mgr = ShardedDependenceManager(n_managers=h)
            mgr.register_array(ba_h)
            return mgr

        r = _best_of(reps, make, ba_h, n_tasks)
        mgr = r.pop("analyzer")
        r["dep_messages"] = mgr.dep_messages
        r["admissions"] = list(mgr.admissions)
        if r["dep_checksum"] != central["dep_checksum"]:
            raise AssertionError(
                f"sharded manager ({h} homes) found different dependences "
                f"than central: {r['dep_checksum']} != "
                f"{central['dep_checksum']}")
        results["sharded"][h] = r
    return results


def entry(suite: str = "smoke") -> dict:
    """One ``bddt-scc-bench/1`` entry: deterministic counters as gated
    metrics, measured rates as info (wall-clock — never gated)."""
    n_tasks = 100_000 if suite == "paper" else 10_000
    homes = [1, 2, 4, 8]
    res = run_matrix(n_tasks, homes)
    central = res["central"]
    at4 = res["sharded"][4]
    info = {
        "suite": suite,
        "grid": res["grid"],
        "central_tasks_per_s": central["tasks_per_s"],
        "speedup_at_4_homes": (at4["tasks_per_s"] /
                               central["tasks_per_s"]),
    }
    for h, r in res["sharded"].items():
        info[f"sharded_{h}_tasks_per_s"] = r["tasks_per_s"]
    return {
        "id": f"spawn-throughput-{suite}",
        "kind": "spawn_throughput",
        "metrics": {
            "tasks": float(central["tasks"]),
            "deps_found": float(central["deps_found"]),
            "blocks_walked": float(central["blocks_walked"]),
            "dep_messages_4_homes": float(at4["dep_messages"]),
        },
        "info": info,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tasks", type=int, default=None,
                    help="stream length (default: per --suite)")
    ap.add_argument("--homes", type=int, nargs="+", default=[1, 2, 4, 8],
                    help="manager counts for the sharded runs")
    ap.add_argument("--grid", type=int, default=64,
                    help="stencil rows (live dependence window)")
    ap.add_argument("--suite", choices=("smoke", "paper"), default="smoke",
                    help="smoke = 10k tasks, paper = 100k (unless --tasks)")
    ap.add_argument("--reps", type=int, default=3,
                    help="repetitions per config (best rate reported)")
    args = ap.parse_args(argv)
    n_tasks = args.tasks or (100_000 if args.suite == "paper" else 10_000)
    res = run_matrix(n_tasks, args.homes, grid=args.grid, reps=args.reps)
    c = res["central"]
    print(f"central : {c['tasks_per_s']:>12.0f} tasks/s  "
          f"({c['deps_found']} deps, {c['blocks_walked']} blocks)")
    for h, r in res["sharded"].items():
        print(f"sharded{h:>2}: {r['tasks_per_s']:>12.0f} tasks/s  "
              f"(x{r['tasks_per_s'] / c['tasks_per_s']:.2f} vs central, "
              f"{r['dep_messages']} msgs, admits {r['admissions']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
