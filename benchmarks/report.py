"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON records.  Run after ``repro.launch.dryrun``:

    PYTHONPATH=src:. python -m benchmarks.report > experiments/tables.md
"""
from __future__ import annotations

import json

from .roofline import build_table, load_all, model_params


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | policy | flops/dev | HBM GiB/dev | "
            "link GiB/dev | collectives (AR/AG/RS/A2A/CP) | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(load_all(), key=lambda r: (r["arch"], r["shape"],
                                               r["mesh"])):
        c = r["collectives"]["counts"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        mem = r["memory"].get("per_device_total_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{r['flops_per_device']:.3e} | {_fmt_bytes(mem)} | "
            f"{r['collectives']['total_link_bytes'] / 2**30:.2f} | {cc} | "
            f"{r['compile_s']} |")
    return "\n".join(rows)


def roofline_table(mesh="16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | 6ND/step | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(build_table(mesh=mesh),
                    key=lambda r: (r["arch"], r["shape"])):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{100 * r['roofline_fraction']:.1f}% | {r['remedy'][:58]} |")
    return "\n".join(rows)


def params_table() -> str:
    from repro.configs import ARCH_IDS
    rows = ["| arch | params total | non-embed | active (MoE) |",
            "|---|---|---|---|"]
    for a in ARCH_IDS:
        p = model_params(a)
        rows.append(f"| {a} | {p['total'] / 1e9:.2f}B | "
                    f"{p['non_embed'] / 1e9:.2f}B | "
                    f"{p['active'] / 1e9:.2f}B |")
    return "\n".join(rows)


def main():
    print("## Params\n")
    print(params_table())
    print("\n## Dry-run (all cells)\n")
    print(dryrun_table())
    print("\n## Roofline (single pod)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
