"""Generate the EXPERIMENTS.md §Dry-run, §Roofline and §Runtime tables.
The dry-run sections read JSON records produced by ``repro.launch.dryrun``;
the runtime section executes the paper's five applications on the task
runtime and tabulates their typed :class:`~repro.core.RuntimeStats`.

    PYTHONPATH=src:. python -m benchmarks.report > experiments/tables.md
"""
from __future__ import annotations

import json

from repro import RuntimeStats

from .roofline import build_table, load_all, model_params


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | policy | flops/dev | HBM GiB/dev | "
            "link GiB/dev | collectives (AR/AG/RS/A2A/CP) | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(load_all(), key=lambda r: (r["arch"], r["shape"],
                                               r["mesh"])):
        c = r["collectives"]["counts"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        mem = r["memory"].get("per_device_total_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{r['flops_per_device']:.3e} | {_fmt_bytes(mem)} | "
            f"{r['collectives']['total_link_bytes'] / 2**30:.2f} | {cc} | "
            f"{r['compile_s']} |")
    return "\n".join(rows)


def roofline_table(mesh="16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | 6ND/step | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(build_table(mesh=mesh),
                    key=lambda r: (r["arch"], r["shape"])):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{100 * r['roofline_fraction']:.1f}% | {r['remedy'][:58]} |")
    return "\n".join(rows)


def params_table() -> str:
    from repro.configs import ARCH_IDS
    rows = ["| arch | params total | non-embed | active (MoE) |",
            "|---|---|---|---|"]
    for a in ARCH_IDS:
        p = model_params(a)
        rows.append(f"| {a} | {p['total'] / 1e9:.2f}B | "
                    f"{p['non_embed'] / 1e9:.2f}B | "
                    f"{p['active'] / 1e9:.2f}B |")
    return "\n".join(rows)


def _fmt_mib(nbytes) -> str:
    return "-" if nbytes is None else f"{nbytes / 2**20:.2f}"


def runtime_stats_table(entries) -> str:
    """One row per (label, stats), where stats is a
    :class:`~repro.core.RuntimeStats` or its serialized dict/JSON form
    (``RuntimeStats.to_dict``/``to_json`` — the same schema the tracker's
    ``stats`` event carries), so trace post-processing feeds this table
    without re-running anything — feeds EXPERIMENTS.md §Runtime.  The
    transfer columns are the sharded executor's owner-computes accounting
    (cross-home = bytes a task reads from blocks homed away from its
    output's device; '-' under executors that do not place)."""
    rows = ["| app | tasks | deps | waves | grouped | spawn us/task | "
            "barrier s | waits (region/future) | xfer cross/local MiB | "
            "moves | staged B |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for label, s in entries:
        if isinstance(s, str):
            s = RuntimeStats.from_json(s)
        elif isinstance(s, dict):
            s = RuntimeStats.from_dict(s)
        rows.append(
            f"| {label} | {s.tasks_spawned} | {s.deps_found} | "
            f"{s.waves if s.waves is not None else '-'} | "
            f"{s.grouped_dispatches if s.grouped_dispatches is not None else '-'} | "
            f"{s.spawn_us_per_task:.1f} | {s.barrier_time_s:.3f} | "
            f"{s.region_waits}/{s.futures_resolved} | "
            f"{_fmt_mib(s.cross_home_bytes)}/{_fmt_mib(s.local_home_bytes)} | "
            f"{s.tile_moves if s.tile_moves is not None else '-'} | "
            f"{s.bytes_staged if s.bytes_staged is not None else '-'} |")
    return "\n".join(rows)


def collect_runtime_stats(executor: str = "staged") \
        -> list[tuple[str, RuntimeStats]]:
    """Run the five paper apps and collect their RuntimeStats."""
    from .apps import APPS, run_app
    return [(name, run_app(name, executor)) for name in sorted(APPS)]


def bench_table(doc: dict) -> str:
    """Render a BENCH JSON document (``bddt-scc-bench/1``, produced by
    ``python -m benchmarks.run --emit``) as the EXPERIMENTS §Bench
    section — the human view of the artifact the CI gate diffs."""
    by_kind: dict[str, list[dict]] = {}
    for e in doc["entries"]:
        by_kind.setdefault(e["kind"], []).append(e)
    out = [f"suite: `{doc['suite']}` · validation "
           f"{doc['validation']['passed']}/{doc['validation']['total']} · "
           f"harness {doc['wall_s']:.0f}s"]
    c = doc["calibration"]
    out.append(f"\ncalibrated SCCParams: base {c['dram_base_cycles']:.1f} "
               f"cyc, {c['dram_hop_cycles']:.2f} cyc/hop, "
               f"alpha {c['contention_alpha']:.3f} "
               f"(fit err {100 * c['fig3_max_rel_err']:.1f}% / "
               f"{100 * c['fig4_max_rel_err']:.1f}%)")
    out.append("\n| app | tasks | grouped | sim predicted s | "
               "single-MC s | cross-home MiB | staged B | tile moves | "
               "overrides | staged wall s |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for e in by_kind.get("app", []):
        m, i = e["metrics"], e["info"]
        # residency columns: measured staging (gated at zero), measured
        # mesh moves, and — when the owner override ran — spill counts
        out.append(
            f"| {e['id'].split('/', 1)[1]} | {m['tasks']} | "
            f"{m['grouped_dispatches']} | {m['sim_predicted_s']:.4f} | "
            f"{m['sim_predicted_single_mc_s']:.4f} | "
            f"{_fmt_mib(m['cross_home_bytes'])} | "
            f"{m.get('bytes_staged', '-')} | "
            f"{m.get('tile_moves', '-')} | "
            f"{m.get('owner_overrides', '-')} | "
            f"{i['wall_s_staged']:.2f} |")
    out.append("\n| workload | peak speedup | speedup@last | single-MC |")
    out.append("|---|---|---|---|")
    for e in by_kind.get("scalability", []):
        m = e["metrics"]
        last = e["checkpoints"][-1]
        out.append(f"| {e['id'].split('/', 1)[1]} | "
                   f"{m['peak_speedup']:.1f} | {last['speedup']:.1f} | "
                   f"{m['speedup_single_mc']:.1f} |")
    for e in by_kind.get("granularity", []):
        sweep = ", ".join(f"{r['tile']}→{r['speedup']:.1f}"
                          for r in e["rows"])
        out.append(f"\ngranularity (tile→speedup): {sweep} "
                   f"(best: {e['info']['best_tile']})")
    kb = by_kind.get("kernel_backend", [])
    if kb:
        # dispatch/fallback counts are the gated quantities; wall clocks
        # are informational (interpret-mode pallas on CPU runners)
        out.append("\n| kernel backend sweep | waves | fused dispatches "
                   "| fallbacks | xla wall s | pallas wall s |")
        out.append("|---|---|---|---|---|---|")
        for e in kb:
            m, i = e["metrics"], e["info"]
            out.append(
                f"| {e['id'].split('/', 1)[1]} | {m['waves']} | "
                f"{m['kernel_dispatches']} | {m['kernel_fallbacks']} | "
                f"{i['wall_s_xla']:.2f} | {i['wall_s_pallas']:.2f} |")
    t = doc.get("timings")
    if t:
        staged = ", ".join(f"{app} {v:.2f}s"
                           for app, v in sorted(t["staged_wall_s"].items()))
        out.append(f"\nstaged wall times (informational, never gated): "
                   f"{staged} · spawn {t['spawn_us_per_task']:.1f} us/task")
    return "\n".join(out)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", metavar="BENCH_JSON",
                    help="render a benchmarks.run --emit artifact instead "
                         "of executing the apps")
    args = ap.parse_args(argv)
    if args.bench:
        with open(args.bench, encoding="utf-8") as f:
            print("## Bench\n")
            print(bench_table(json.load(f)))
        return

    from repro import dist

    print("## Params\n")
    print(params_table())
    print("\n## Dry-run (all cells)\n")
    print(dryrun_table())
    print("\n## Roofline (single pod)\n")
    print(roofline_table())
    print("\n## Runtime (task-graph apps, staged executor)\n")
    print(runtime_stats_table(collect_runtime_stats()))
    # the sharded column: same apps, owner-computes placement over the
    # ambient mesh (the single-device fallback here), with the cross-home
    # transfer bytes the placement implies
    print("\n## Runtime (task-graph apps, sharded executor, "
          "owner-computes)\n")
    with dist.use_mesh(dist.single_device_mesh()):
        print(runtime_stats_table(collect_runtime_stats("sharded")))


if __name__ == "__main__":
    main()
