"""Streaming-serving benchmark: admission control + request latency.

Two phases over the same workload — a decode-style lookup against a
shared KV ``BlockArray`` (each request reads one context tile and writes
one output row through ``repro.serve.Session``):

* **Admission phase** (gated): burst-submits requests against a budget
  sized for exactly ``capacity`` in-flight requests with the ``reject``
  saturation policy on the staged executor.  Nothing completes between
  submits, so the admit/reject split per burst is a pure function of the
  byte budget — ``submitted``, ``admitted``, ``rejected`` and
  ``peak_in_flight_bytes`` are deterministic counters that
  ``tools/bench_gate.py`` diffs against the committed baseline
  (``validate_serving`` additionally pins ``admitted + rejected ==
  submitted`` and ``peak <= budget`` on every artifact).

* **Latency phase** (info-only): an open-loop arrival sweep on the host
  executor — requests arrive on a fixed schedule regardless of
  completion, ``Session.poll()`` retires them between arrivals, and the
  per-rate p50/p99 latency and delivered throughput land in the entry's
  ``info`` block.  Wall clocks are machine-speed dependent and never
  gated, matching how the harness treats every other timing.

CLI::

    PYTHONPATH=src python -m benchmarks.serving --suite smoke
    PYTHONPATH=src python -m benchmarks.serving --rates 100 400 1600
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import RuntimeConfig, task
from repro.serve import ServeConfig, Session

D = 64          # feature dimension of the KV rows
CTX_TILE = 16   # context rows per KV tile (the unit one request reads)

# per-suite shapes: smoke keeps the whole thing inside a CI job; paper
# streams the 10^3-request admission phase the acceptance bar names
PROFILES: dict = {
    "smoke": {"requests": 96, "burst": 8, "capacity": 4,
              "lat_requests": 48, "rates": (200, 800)},
    "paper": {"requests": 1000, "burst": 10, "capacity": 4,
              "lat_requests": 256, "rates": (100, 400, 1600)},
}


@task(in_="kv", out="dest", firstprivate=("q",))
def _attend(kv, q, dest=None):
    # one decode step against one context tile: softmax(q.kv^T).kv
    w = jax.nn.softmax(q @ kv.T / np.sqrt(D).astype(np.float32))
    return (w @ kv)[None, :]


def _arrays(session: Session, n_tiles: int, n_slots: int):
    rng = np.random.default_rng(7)
    kv = session.from_array(
        rng.standard_normal((n_tiles * CTX_TILE, D)).astype(np.float32),
        (CTX_TILE, D), name="kv")
    out = session.zeros((n_slots, D), (1, D), name="out", state=False)
    return kv, out


def _submit(session: Session, kv, out, i: int, slot: int, q):
    n_tiles = kv.grid[0]
    src, dst = kv[i % n_tiles, 0], out[slot, 0]
    return session.submit(lambda: _attend(src, q, dst), src, dst)


def request_bytes(capacity: int = 1) -> int:
    """Bytes one request holds in flight (KV tile + output row), times
    ``capacity`` — the byte budget that admits exactly that many."""
    return capacity * (CTX_TILE * D * 4 + D * 4)


def run_admission(n_requests: int, burst: int, capacity: int) -> dict:
    """Burst-submit ``n_requests`` against a ``capacity``-request budget
    with load shedding; returns the deterministic admission counters."""
    budget = request_bytes(capacity)
    with Session(RuntimeConfig(executor="staged"),
                 ServeConfig(budget_bytes=budget,
                             on_saturation="reject")) as s:
        kv, out = _arrays(s, n_tiles=8, n_slots=burst)
        q = np.ones(D, dtype=np.float32)
        t0 = time.perf_counter()
        i = 0
        while i < n_requests:
            handles = [_submit(s, kv, out, i + j, j, q)
                       for j in range(min(burst, n_requests - i))]
            i += len(handles)
            s.drain()               # retire the admitted burst
        wall = time.perf_counter() - t0
        st = s.stats()
    return {
        "submitted": st.admission_submitted,
        "admitted": st.admission_admitted,
        "rejected": st.admission_rejected,
        "peak_in_flight_bytes": st.admission_peak_bytes,
        "budget_bytes": budget,
        "wall_s": wall,
    }


def run_open_loop(n_requests: int, rate_rps: float, capacity: int = 8,
                  n_workers: int = 4) -> dict:
    """Open-loop arrival sweep: requests arrive every ``1/rate`` seconds
    whether or not earlier ones finished; the host executor's workers
    retire them concurrently via ``poll()``.  Queuing (never shedding),
    so every request completes and the latency sample is complete."""
    dt = 1.0 / rate_rps
    with Session(RuntimeConfig(executor="host", n_workers=n_workers),
                 ServeConfig(budget_bytes=request_bytes(capacity))) as s:
        kv, out = _arrays(s, n_tiles=8, n_slots=capacity)
        q = np.ones(D, dtype=np.float32)
        # warm the dispatch path so compilation stays out of the tail
        _submit(s, kv, out, 0, 0, q).wait()
        handles = []
        t0 = time.perf_counter()
        for i in range(n_requests):
            handles.append(_submit(s, kv, out, i, i % capacity, q))
            deadline = t0 + (i + 1) * dt
            while time.perf_counter() < deadline:
                s.poll()
        s.drain()
        wall = time.perf_counter() - t0
    lat_ms = np.asarray([h.latency_s for h in handles]) * 1e3
    return {
        "rate_rps": rate_rps,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "throughput_rps": len(handles) / wall,
    }


def entry(suite: str = "smoke") -> dict:
    """One ``bddt-scc-bench/1`` entry: the deterministic admission
    counters as gated metrics, the open-loop latency sweep as info."""
    cfg = PROFILES[suite]
    adm = run_admission(cfg["requests"], cfg["burst"], cfg["capacity"])
    rates = {}
    for r in cfg["rates"]:
        res = run_open_loop(cfg["lat_requests"], r)
        rates[str(r)] = {k: res[k] for k in
                         ("p50_ms", "p99_ms", "throughput_rps")}
    return {
        "id": f"serving-{suite}",
        "kind": "serving",
        "metrics": {
            "submitted": float(adm["submitted"]),
            "admitted": float(adm["admitted"]),
            "rejected": float(adm["rejected"]),
            "peak_in_flight_bytes": float(adm["peak_in_flight_bytes"]),
            "budget_bytes": float(adm["budget_bytes"]),
        },
        "info": {
            "suite": suite,
            "capacity": cfg["capacity"],
            "burst": cfg["burst"],
            "request_bytes": request_bytes(),
            "admission_wall_s": adm["wall_s"],
            "lat_requests": cfg["lat_requests"],
            "rates": rates,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--suite", choices=sorted(PROFILES), default="smoke",
                    help="problem-size profile")
    ap.add_argument("--rates", type=float, nargs="+", default=None,
                    help="open-loop arrival rates (req/s) to sweep")
    args = ap.parse_args(argv)
    cfg = PROFILES[args.suite]
    adm = run_admission(cfg["requests"], cfg["burst"], cfg["capacity"])
    print(f"admission: {adm['submitted']} submitted, "
          f"{adm['admitted']} admitted, {adm['rejected']} rejected, "
          f"peak {adm['peak_in_flight_bytes']}B / "
          f"budget {adm['budget_bytes']}B "
          f"({adm['wall_s']:.2f}s)")
    for r in (args.rates or cfg["rates"]):
        res = run_open_loop(cfg["lat_requests"], r)
        print(f"rate {r:>7.0f}/s: p50 {res['p50_ms']:7.2f}ms  "
              f"p99 {res['p99_ms']:7.2f}ms  "
              f"delivered {res['throughput_rps']:.0f}/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
