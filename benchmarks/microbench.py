"""Figures 3 & 4: DRAM latency vs hop distance, and contention vs number of
concurrently accessing cores — from the calibrated cost model.

The paper measures a microbenchmark that repeatedly accesses a 16 MB array
homed on controller 0.  Here the same experiment runs against the model:
Fig 3 sweeps the core's distance from MC0; Fig 4 fixes the reference core
at 9 hops (the paper's worst case) and sweeps how many other cores hammer
the same controller.
"""
from __future__ import annotations

from repro.core.costmodel import SCCParams, core_mc_hops

ARRAY_BYTES = 16 * 2 ** 20


def fig3_latency_vs_hops(p: SCCParams = SCCParams()):
    rows = []
    for hops in range(10):
        t = p.mem_time_s(ARRAY_BYTES, hops, concurrent=1)
        rows.append({"hops": hops, "time_s": t})
    return rows


def fig4_contention(p: SCCParams = SCCParams(), *, ref_hops: int = 9):
    rows = []
    for n_cores in range(1, 33):
        t = p.mem_time_s(ARRAY_BYTES, ref_hops, concurrent=n_cores)
        rows.append({"cores": n_cores, "time_s": t})
    return rows


def run(report, p: SCCParams | None = None):
    p = p or SCCParams()
    f3 = fig3_latency_vs_hops(p)
    for r in f3:
        report("fig3_latency", f"hops={r['hops']}", r["time_s"] * 1e6)
    ratio3 = f3[-1]["time_s"] / f3[0]["time_s"]
    report("fig3_latency", "far_vs_near_ratio", ratio3)

    f4 = fig4_contention(p=p)
    for r in f4[:32:4]:
        report("fig4_contention", f"cores={r['cores']}", r["time_s"] * 1e6)
    ratio4 = f4[-1]["time_s"] / f4[0]["time_s"]
    report("fig4_contention", "32core_vs_1core_ratio", ratio4)
    return {"fig3_far_near": ratio3, "fig4_32_1": ratio4}
