"""Roofline analysis from the dry-run records (§Roofline deliverable).

Per (arch x shape) single-pod cell:

* compute term    = jaxpr FLOPs / (chips x 197 TF/s bf16)
* memory term     = fusion-adjusted bytes / (chips x 819 GB/s HBM)
* collective term = ring-model link bytes / (chips x 50 GB/s ICI link)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE), the useful-compute ratio
MODEL_FLOPS / step FLOPs, the dominant term and a one-line remedy note.
Sources and caveats (XLA cost_analysis counts loop bodies once; we use
exact jaxpr accounting instead) are documented in the dry-run module.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from repro.configs import SHAPES, get_config
from repro.core.costmodel import TPUParams

HW = TPUParams()


def model_params(arch: str) -> dict:
    """Total and active (MoE) parameter counts, embeddings excluded from
    the 6ND convention."""
    from repro.models import api
    cfg = get_config(arch)
    abs_params = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    total = sum(int(x.size) for x in jax.tree_util.tree_leaves(abs_params))
    embed = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        embed *= 2
    non_embed = total - embed
    active = non_embed
    if cfg.moe:
        flat = jax.tree_util.tree_flatten_with_path(abs_params)[0]
        expert = sum(
            int(leaf.size) for path, leaf in flat
            if any(getattr(p, "key", None) in ("gate", "up", "down")
                   and "moe_blocks" in str(path) for p in path)
            and not any(getattr(p, "key", None) == "shared" for p in path)
            and not any(getattr(p, "key", None) == "router" for p in path))
        active = non_embed - expert + expert * cfg.top_k / cfg.n_experts
    return {"total": total, "non_embed": non_embed, "active": int(active)}


def analyze_record(rec: dict, params: dict) -> dict:
    chips = rec["n_devices"]
    flops_dev = rec["flops_per_device"]
    bytes_dev = rec["bytes_per_device"]
    link_dev = rec["collectives"]["total_link_bytes"]
    terms = {
        "compute_s": flops_dev / HW.peak_flops_bf16,
        "memory_s": bytes_dev / HW.hbm_bw,
        "collective_s": link_dev / HW.ici_link_bw,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = {k: (v / bound if bound else 0.0) for k, v in terms.items()}

    spec = SHAPES[rec["shape"]]
    if rec["kind"] == "train":
        tokens = spec.seq_len * spec.global_batch
        model_flops = 6.0 * params["active"] * tokens
    elif rec["kind"] == "prefill":
        tokens = spec.seq_len * spec.global_batch
        model_flops = 2.0 * params["active"] * tokens
    else:
        tokens = spec.global_batch
        model_flops = 2.0 * params["active"] * tokens
    step_flops = flops_dev * chips
    useful = model_flops / step_flops if step_flops else 0.0
    # roofline fraction: useful model flops vs what the dominant-term time
    # would allow at peak
    ideal_s = model_flops / (chips * HW.peak_flops_bf16)
    achieved = ideal_s / bound if bound else 0.0
    return {
        **{k: float(v) for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "bound_s": float(bound),
        "model_flops": float(model_flops),
        "useful_ratio": float(useful),
        "roofline_fraction": float(achieved),
        "fractions": {k.replace("_s", ""): round(v, 3)
                      for k, v in frac.items()},
    }


_REMEDY = {
    "compute": "reduce recompute (remat policy) / raise MXU utilization "
               "via larger per-chip tiles",
    "memory": "fuse bandwidth-bound chains, cache activations in bf16, "
              "cut optimizer-state traffic (ZeRO offload or lower-"
              "precision statistics)",
    "collective": "reshard to cut boundary collectives (SP<->TP "
                  "handoffs), overlap grad reduce-scatter with backward, "
                  "compress cross-pod gradients",
}


def load_all(dryrun_dir: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(f))
        if "error" not in r:
            recs.append(r)
    return recs


def build_table(dryrun_dir: str = "experiments/dryrun",
                mesh: str = "16x16") -> list[dict]:
    rows = []
    pcache: dict[str, dict] = {}
    for rec in load_all(dryrun_dir):
        if rec["mesh"] != mesh:
            continue
        if rec["arch"] not in pcache:
            pcache[rec["arch"]] = model_params(rec["arch"])
        a = analyze_record(rec, pcache[rec["arch"]])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "mesh": rec["mesh"], "kind": rec["kind"],
            "hbm_gib": round(rec["memory"].get(
                "per_device_total_bytes", 0) / 2**30, 2),
            **a,
            "remedy": _REMEDY[a["dominant"]],
        })
    return rows


def run(report):
    rows = build_table()
    for r in rows:
        cell = f"{r['arch']}/{r['shape']}"
        report("roofline", f"{cell}:compute_s", r["compute_s"])
        report("roofline", f"{cell}:memory_s", r["memory_s"])
        report("roofline", f"{cell}:collective_s", r["collective_s"])
        report("roofline", f"{cell}:dominant", r["dominant"])
        report("roofline", f"{cell}:roofline_fraction",
               round(r["roofline_fraction"], 4))
    return rows
