"""Task-graph generators for the paper's five applications (§4.2 sizes).

Each generator returns a list of :class:`repro.core.sim.SimTask` annotated
with per-task flops, DRAM bytes (scaled by a cache-locality factor — the
paper's observation that MM's tile reuse is what lets it scale), and the
memory-controller homes of its blocks under the chosen placement.

Placements mirror ``repro.core.placement``: ``striped`` distributes blocks
round-robin over the four controllers (the paper's padding/stride fix);
``single`` concentrates them on MC0 (the contention pathology).
"""
from __future__ import annotations

import math

from repro.core.sim import SimTask

F64 = 8
F32 = 4
C128 = 16


def _home(i: int, placement: str) -> int:
    return i % 4 if placement == "striped" else 0


def black_scholes(placement: str = "striped", *, n_options: int = 2_000_000,
                  task_options: int = 512) -> list[SimTask]:
    """2M options, 512 per task: independent, compute-bound, streaming."""
    n_tasks = n_options // task_options
    flops = task_options * 220.0           # erf/exp/log per option
    byts = task_options * 7 * F32 * 0.5    # streaming, prefetch-friendly
    return [SimTask(tid=i, flops=flops, mem_bytes=byts,
                    homes=(_home(i, placement),), n_blocks=2)
            for i in range(n_tasks)]


def matmul(placement: str = "striped", *, n: int = 1024,
           tile: int = 64) -> list[SimTask]:
    """1Kx1K floats in 64x64 tiles; C[i,j] accumulates over k (chained)."""
    g = n // tile
    tasks = []
    tid = 0
    cache_fraction = 0.15                   # tile reuse in L2 (paper: "good
    flops = 2.0 * tile ** 3                 #  cache locality")
    byts = 3 * tile * tile * F32 * cache_fraction
    for i in range(g):
        for j in range(g):
            prev = None
            for k in range(g):
                homes = tuple({_home(i * g + k, placement),
                               _home(k * g + j, placement),
                               _home(i * g + j, placement)})
                deps = (prev,) if prev is not None else ()
                tasks.append(SimTask(tid=tid, flops=flops, mem_bytes=byts,
                                     homes=homes, deps=deps, n_blocks=3))
                prev = tid
                tid += 1
    return tasks


def fft(placement: str = "striped", *, n: int = 1024,
        row_block: int = 32, tile: int = 32) -> list[SimTask]:
    """2-D FFT of n x n complex doubles: row-FFT phase, tiled transpose,
    row-FFT phase.  Memory-bound with all-to-all-ish dependencies."""
    tasks = []
    tid = 0
    n_row_tasks = n // row_block
    logn = math.log2(n)
    fft_flops = row_block * 5.0 * n * logn
    fft_bytes = 2 * row_block * n * C128    # read + write, no reuse
    # phase 1 row FFTs
    p1 = []
    for r in range(n_row_tasks):
        tasks.append(SimTask(tid=tid, flops=fft_flops, mem_bytes=fft_bytes,
                             homes=(_home(r, placement),), n_blocks=2))
        p1.append(tid)
        tid += 1
    # transpose tiles
    gt = n // tile
    tp = {}
    for i in range(gt):
        for j in range(gt):
            src_rows = {(i * tile) // row_block,
                        ((i + 1) * tile - 1) // row_block}
            deps = tuple(p1[r] for r in src_rows)
            homes = tuple({_home(i * gt + j, placement),
                           _home(j * gt + i, placement)})
            tasks.append(SimTask(tid=tid, flops=tile * tile * 2.0,
                                 mem_bytes=2 * tile * tile * C128,
                                 homes=homes, deps=deps, n_blocks=2))
            tp[(i, j)] = tid
            tid += 1
    # phase 2 row FFTs (on transposed data)
    for r in range(n_row_tasks):
        touched = tuple(tp[(i, j)] for i in range(
            (r * row_block) // tile, ((r + 1) * row_block - 1) // tile + 1)
            for j in range(gt))
        tasks.append(SimTask(tid=tid, flops=fft_flops, mem_bytes=fft_bytes,
                             homes=(_home(r, placement),), deps=touched,
                             n_blocks=2))
        tid += 1
    return tasks


def jacobi(placement: str = "striped", *, n: int = 4096, tile: int = 512,
           iters: int = 16) -> list[SimTask]:
    """4Kx4K floats, 512x512 tiles, 16 iterations of the 5-point stencil.
    Strongly memory-bound; neighbour dependencies across iterations."""
    g = n // tile
    tasks = []
    grid_prev = {}
    tid = 0
    flops = 4.0 * tile * tile
    byts = 2.2 * tile * tile * F32          # read + write + halo strips
    for it in range(iters):
        grid_now = {}
        for i in range(g):
            for j in range(g):
                deps = []
                if it > 0:
                    for di, dj in ((0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)):
                        key = (i + di, j + dj)
                        if key in grid_prev:
                            deps.append(grid_prev[key])
                tasks.append(SimTask(
                    tid=tid, flops=flops, mem_bytes=byts,
                    homes=(_home(i * g + j, placement),),
                    deps=tuple(deps), n_blocks=6))
                grid_now[(i, j)] = tid
                tid += 1
        grid_prev = grid_now
    return tasks


def cholesky(placement: str = "striped", *, n: int = 2048,
             tile: int = 128) -> list[SimTask]:
    """2Kx2K doubles, 128x128 tiles, right-looking factorization: deep
    dependency chains + fine tasks (the paper's master-bottleneck case)."""
    g = n // tile
    tasks = []
    tid = 0
    owner: dict[tuple[int, int], int] = {}
    cache_fraction = 0.8                    # 3 x 128KB tiles exceed L2

    def home(i, j):
        return _home(i * g + j, placement)

    def add(flops, byts, homes, deps, blocks):
        nonlocal tid
        tasks.append(SimTask(tid=tid, flops=flops,
                             mem_bytes=byts * cache_fraction,
                             homes=tuple(set(homes)), deps=tuple(deps),
                             n_blocks=blocks))
        tid += 1
        return tid - 1

    for k in range(g):
        d = owner.get((k, k))
        potrf = add(tile ** 3 / 3.0, tile * tile * F64, [home(k, k)],
                    [d] if d is not None else [], 1)
        owner[(k, k)] = potrf
        for i in range(k + 1, g):
            d = [potrf]
            if (i, k) in owner:
                d.append(owner[(i, k)])
            trsm = add(float(tile ** 3), 2 * tile * tile * F64,
                       [home(i, k), home(k, k)], d, 2)
            owner[(i, k)] = trsm
        for i in range(k + 1, g):
            for j in range(k + 1, i + 1):
                d = [owner[(i, k)], owner[(j, k)]]
                if (i, j) in owner:
                    d.append(owner[(i, j)])
                upd = add(2.0 * tile ** 3, 3 * tile * tile * F64,
                          [home(i, j), home(i, k), home(j, k)], d, 3)
                owner[(i, j)] = upd
    return tasks


WORKLOADS = {
    "black_scholes": black_scholes,
    "matmul": matmul,
    "fft": fft,
    "jacobi": jacobi,
    "cholesky": cholesky,
}
